//! Thread-count invariance: every parallel code path must produce the same
//! result at 1, 2, and N worker threads (the ISSUE tolerance is 1e-5
//! rel-err; the kernels are designed to be bit-identical because work is
//! split only across independent output regions, so the kernel checks
//! assert exact equality).

use std::sync::Mutex;

use mergemoe::merge::plan::MergePlan;
use mergemoe::merge::{self, Algorithm, NativeGram};
use mergemoe::model::native::{forward, forward_ws, moe_forward};
use mergemoe::model::testprops::tiny_moe;
use mergemoe::model::workspace::Workspace;
use mergemoe::tensor::{ops, Tensor};
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

/// Serializes tests that sweep the global thread knob.
static THREAD_KNOB: Mutex<()> = Mutex::new(());

const SWEEP: [usize; 3] = [1, 2, 8];

fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    par::set_max_threads(n);
    let out = f();
    par::set_max_threads(1);
    out
}

#[test]
fn kernels_bit_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let mut rng = Rng::new(0x9A11E1);
    for case in 0..12 {
        let m = rng.range(1, 70) as usize;
        let k = rng.range(1, 70) as usize;
        let n = rng.range(1, 70) as usize;
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
        let at = Tensor::randn(&[k, m], 1.0, &mut rng);
        let ref_mm = with_threads(1, || ops::matmul(&a, &b).unwrap());
        let ref_bt = with_threads(1, || ops::matmul_bt(&a, &bt).unwrap());
        let ref_at = with_threads(1, || ops::matmul_at(&at, &b).unwrap());
        let ref_tr = with_threads(1, || ops::transpose(&a).unwrap());
        for t in SWEEP {
            let mm = with_threads(t, || ops::matmul(&a, &b).unwrap());
            let mbt = with_threads(t, || ops::matmul_bt(&a, &bt).unwrap());
            let mat = with_threads(t, || ops::matmul_at(&at, &b).unwrap());
            let tr = with_threads(t, || ops::transpose(&a).unwrap());
            assert_eq!(mm.data(), ref_mm.data(), "matmul case {case} threads {t}");
            assert_eq!(mbt.data(), ref_bt.data(), "matmul_bt case {case} threads {t}");
            assert_eq!(mat.data(), ref_at.data(), "matmul_at case {case} threads {t}");
            assert_eq!(tr.data(), ref_tr.data(), "transpose case {case} threads {t}");
        }
    }
    par::set_max_threads(prev);
}

#[test]
fn degenerate_shapes_at_every_thread_count() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    for t in SWEEP {
        with_threads(t, || {
            // empty row/col/inner dimensions
            let z = ops::matmul(&Tensor::zeros(&[0, 3]), &Tensor::zeros(&[3, 4])).unwrap();
            assert_eq!(z.shape(), &[0, 4]);
            let z2 = ops::matmul(&Tensor::zeros(&[3, 0]), &Tensor::zeros(&[0, 4])).unwrap();
            assert!(z2.data().iter().all(|&v| v == 0.0));
            let z3 = ops::matmul_bt(&Tensor::zeros(&[2, 5]), &Tensor::zeros(&[0, 5])).unwrap();
            assert_eq!(z3.shape(), &[2, 0]);
            // single element
            let one = Tensor::from_vec(&[1, 1], vec![3.0]).unwrap();
            assert_eq!(ops::matmul(&one, &one).unwrap().data(), &[9.0]);
            // softmax / layernorm on a single row
            let s = ops::softmax_rows(&one);
            assert_eq!(s.data(), &[1.0]);
        });
    }
    par::set_max_threads(prev);
}

#[test]
fn fused_kernels_bit_identical_across_thread_counts() {
    // The kernel layer's fused epilogues (SwiGLU, scale-and-accumulate,
    // scatter, SYRK) and the packed A@B path must honor the same contract
    // as the plain kernels: bit-identical results at 1/2/8 threads.
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let mut rng = Rng::new(0x9A11E2);
    // past the AVX2 pack threshold (k·n ≥ 64K, m ≥ 16) so the blocked
    // packed path is exercised wherever that kernel is active
    let big_a = Tensor::randn(&[24, 310], 1.0, &mut rng);
    let big_b = Tensor::randn(&[310, 220], 1.0, &mut rng);
    let x = Tensor::randn(&[37, 24], 1.0, &mut rng);
    let wg = Tensor::randn(&[18, 24], 1.0, &mut rng);
    let wu = Tensor::randn(&[18, 24], 1.0, &mut rng);
    let wd = Tensor::randn(&[24, 18], 1.0, &mut rng);
    let p = Tensor::randn(&[40, 150], 1.0, &mut rng);
    let scales: Vec<f32> = (0..37).map(|i| 0.01 * i as f32 - 0.1).collect();
    let dst: Vec<usize> = (0..37).map(|i| i * 2).collect();
    let run = || {
        let packed = ops::matmul(&big_a, &big_b).unwrap();
        let mut h = Tensor::full(&[37, 18], f32::NAN);
        ops::swiglu_bt_into(&x, &wg, &wu, &mut h).unwrap();
        let mut acc = Tensor::zeros(&[37, 24]);
        ops::matmul_bt_scaled_add_into(&h, &wd, 0.4, &mut acc).unwrap();
        let mut scat = Tensor::zeros(&[74, 24]);
        ops::matmul_bt_scatter_add_into(&h, &wd, &scales, &dst, &mut scat).unwrap();
        let gram = ops::syrk_bt(&p).unwrap();
        (packed, h, acc, scat, gram)
    };
    let reference = with_threads(1, run);
    for t in SWEEP {
        let got = with_threads(t, run);
        assert_eq!(got.0.data(), reference.0.data(), "packed nn threads {t}");
        assert_eq!(got.1.data(), reference.1.data(), "swiglu threads {t}");
        assert_eq!(got.2.data(), reference.2.data(), "scaled_add threads {t}");
        assert_eq!(got.3.data(), reference.3.data(), "scatter threads {t}");
        assert_eq!(got.4.data(), reference.4.data(), "syrk threads {t}");
    }
    par::set_max_threads(prev);
}

#[test]
fn moe_forward_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let moe = tiny_moe(8, 2, 0xF00D);
    let x = Tensor::randn(&[65, 16], 1.0, &mut Rng::new(0xF00E));
    let (ref_y, ref_counts, ref_mass) = with_threads(1, || moe_forward(&moe, &x).unwrap());
    for t in SWEEP {
        let (y, counts, mass) = with_threads(t, || moe_forward(&moe, &x).unwrap());
        assert!(y.rel_err(&ref_y) < 1e-5, "threads {t}: rel err {}", y.rel_err(&ref_y));
        assert_eq!(counts, ref_counts, "threads {t}");
        assert_eq!(mass, ref_mass, "threads {t}");
    }
    par::set_max_threads(prev);
}

#[test]
fn full_forward_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let cfg = mergemoe::config::ModelConfig {
        name: "sweep".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: true,
        n_params: 0,
        merge_targets: vec![2],
    };
    let model = mergemoe::model::testprops::synth_model(&cfg, 0xCAFE);
    let tokens: Vec<i32> = (0..3 * 64).map(|i| (i % 47) as i32).collect();
    let ref_logits = with_threads(1, || forward(&model, &tokens, 3, 64, None).unwrap());
    for t in SWEEP {
        let logits = with_threads(t, || forward(&model, &tokens, 3, 64, None).unwrap());
        let rel = logits.rel_err(&ref_logits);
        assert!(rel < 1e-5, "threads {t}: rel err {rel}");
    }
    par::set_max_threads(prev);
}

#[test]
fn mergemoe_solve_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let moe = tiny_moe(6, 2, 0xD00D);
    let x = Tensor::randn(&[300, 16], 1.0, &mut Rng::new(0xD00E));
    let plan = MergePlan {
        n: 6,
        m: 3,
        clusters: vec![vec![0, 3], vec![1, 4], vec![2, 5]],
        assign: vec![0, 1, 2, 0, 1, 2],
        weights: vec![0.5, 0.4, 0.7, 0.5, 0.6, 0.3],
    };
    let reference = with_threads(1, || {
        merge::merge_layer(
            Algorithm::MergeMoe, &moe, &plan, Some(&x), &mut NativeGram, 1e-8,
            &mut Workspace::new(),
        )
        .unwrap()
    });
    for t in SWEEP {
        let merged = with_threads(t, || {
            merge::merge_layer(
                Algorithm::MergeMoe, &moe, &plan, Some(&x), &mut NativeGram, 1e-8,
                &mut Workspace::new(),
            )
            .unwrap()
        });
        for (ci, (got, want)) in merged.experts.iter().zip(&reference.experts).enumerate() {
            assert!(
                got.wd.rel_err(&want.wd) < 1e-5,
                "threads {t} cluster {ci}: wd rel err {}",
                got.wd.rel_err(&want.wd)
            );
            assert_eq!(got.wg.data(), want.wg.data(), "threads {t} cluster {ci}: wg");
            assert_eq!(got.wu.data(), want.wu.data(), "threads {t} cluster {ci}: wu");
        }
    }
    par::set_max_threads(prev);
}

#[test]
fn linalg_solves_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let mut rng = Rng::new(0x50151);
    let a = Tensor::randn(&[24, 24], 1.0, &mut rng);
    let mut spd = ops::matmul_bt(&a, &a).unwrap();
    for i in 0..24 {
        *spd.at2_mut(i, i) += 0.5;
    }
    let b = Tensor::randn(&[24, 17], 1.0, &mut rng);
    let reference = with_threads(1, || mergemoe::linalg::solve_spd(&spd, &b, 1e-9).unwrap());
    for t in SWEEP {
        let x = with_threads(t, || mergemoe::linalg::solve_spd(&spd, &b, 1e-9).unwrap());
        assert_eq!(x.data(), reference.data(), "threads {t}");
    }
    par::set_max_threads(prev);
}

#[test]
fn forward_ws_identical_across_thread_counts_through_one_workspace() {
    // The pool AND the workspace arena together: one warm workspace swept
    // across thread counts must reproduce the serial fresh-allocation run
    // bit for bit.
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let cfg = mergemoe::config::ModelConfig {
        name: "wssweep".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: true,
        n_params: 0,
        merge_targets: vec![2],
    };
    let model = mergemoe::model::testprops::synth_model(&cfg, 0xB0B5);
    let tokens: Vec<i32> = (0..2 * 64).map(|i| ((i * 11) % 47) as i32).collect();
    let ref_logits = with_threads(1, || forward(&model, &tokens, 2, 64, None).unwrap());
    let mut ws = Workspace::new();
    let mut logits = mergemoe::tensor::Tensor::default();
    for t in SWEEP {
        for round in 0..2 {
            with_threads(t, || {
                forward_ws(&model, &tokens, 2, 64, None, &mut ws, &mut logits).unwrap()
            });
            assert_eq!(
                logits.data(),
                ref_logits.data(),
                "threads {t} round {round}: workspace path diverged"
            );
        }
    }
    par::set_max_threads(prev);
}

#[test]
fn pool_persists_and_nested_regions_degrade() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    par::set_max_threads(8);
    // warm the pool, then verify no further growth across many regions
    let warm = par::par_map_range(64, |i| i * 2);
    assert_eq!(warm[63], 126);
    let size = par::pool_size();
    assert!(size >= 1, "8-thread region must have spawned workers");
    for _ in 0..50 {
        let out = par::par_map_range(32, |i| i + 1);
        assert_eq!(out[31], 32);
    }
    assert_eq!(par::pool_size(), size, "pool must not grow per region");
    // every lane of a multi-thread region runs with the in-pool flag set,
    // so nested regions degrade to serial instead of re-entering the pool
    let flags = par::par_map_range(8, |_| par::in_parallel_region());
    assert!(flags.iter().all(|&f| f), "lanes must be flagged in-pool");
    // nested fan-out still yields correct, ordered results
    let nested = par::par_map_range(4, |i| par::par_map_range(4, move |j| i * 4 + j));
    for (i, inner) in nested.iter().enumerate() {
        for (j, v) in inner.iter().enumerate() {
            assert_eq!(*v, i * 4 + j);
        }
    }
    // threads=1 never touches the pool: the serial path leaves the flag off
    par::set_max_threads(1);
    let serial_flags = par::par_map_range(4, |_| par::in_parallel_region());
    assert!(serial_flags.iter().all(|&f| !f));
    par::set_max_threads(prev);
}

#[test]
fn pool_shutdown_and_lazy_respawn() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    par::set_max_threads(4);
    let out = par::par_map_range(16, |i| i * i);
    assert_eq!(out[15], 225);
    par::shutdown_pool();
    assert_eq!(par::pool_size(), 0, "shutdown joins every worker");
    // the next region lazily respawns the pool and still computes correctly
    let out2 = par::par_map_range(16, |i| i * 3);
    assert_eq!(out2[15], 45);
    if par::max_threads() > 1 {
        assert!(par::pool_size() >= 1, "region must respawn workers");
    }
    par::set_max_threads(prev);
}
