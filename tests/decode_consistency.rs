//! Decode-vs-prefill bit-identity (the regression harness of the KV-cache
//! decode path): at every step t, `Engine::decode_step` must reproduce the
//! last logits row of a full forward over `prompt[..=t]` **bit for bit** —
//! across 1/2/8 worker threads, through warm-reused `KvScratch`/`Workspace`
//! buffers, on the base model and a compressed (routing-map redirect)
//! variant. On top of the forward identity, seeded generation must replay
//! the same token sequence across runs and thread counts, and running into
//! the trained context window must stop cleanly (typed `ContextOverflow`
//! only when the prompt alone does not fit).

use std::sync::Mutex;

use mergemoe::eval::{generate, generate_into, Sampler};
use mergemoe::model::native::{forward, ContextOverflow};
use mergemoe::model::testprops::synth_model;
use mergemoe::model::workspace::{KvScratch, Workspace};
use mergemoe::model::ModelWeights;
use mergemoe::runtime::{Engine, NativeEngine};
use mergemoe::tensor::Tensor;
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

/// Serializes tests that sweep the global thread knob.
static THREAD_KNOB: Mutex<()> = Mutex::new(());

const SWEEP: [usize; 3] = [1, 2, 8];

fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    par::set_max_threads(n);
    let out = f();
    par::set_max_threads(1);
    out
}

fn base_model() -> ModelWeights {
    let cfg = mergemoe::config::ModelConfig {
        name: "decode".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: true,
        n_params: 0,
        merge_targets: vec![2],
    };
    synth_model(&cfg, 0xDEC0)
}

/// A merged-style variant: 2 real experts under the 4-way router with a
/// (2, 4) summation map, so decode also exercises the routing-redirect
/// (`r2 = r · mapᵀ`) path compressed deployments run on.
fn compressed_model() -> ModelWeights {
    let mut m = base_model();
    for l in &mut m.layers {
        l.moe.experts.truncate(2);
        l.moe.map = Some(
            Tensor::from_vec(&[2, 4], vec![1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0]).unwrap(),
        );
    }
    m.touch();
    m
}

#[test]
fn decode_bit_identical_to_full_prefill_across_threads() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    for (which, model) in [("base", base_model()), ("compressed", compressed_model())] {
        let prompt: Vec<i32> = (0..16).map(|i| ((i * 7 + 3) % 47) as i32).collect();
        // reference: a fresh full-prefill forward over every prefix, serial
        let refs: Vec<Vec<f32>> = (0..prompt.len())
            .map(|t| {
                let full =
                    with_threads(1, || forward(&model, &prompt[..=t], 1, t + 1, None).unwrap());
                full.row(t).to_vec()
            })
            .collect();
        // one warm scratch set swept across thread counts and repeat rounds:
        // bit-identity must survive buffer reuse, not just a cold start
        let mut kv = KvScratch::new();
        let mut ws = Workspace::new();
        let mut out = Tensor::default();
        for t in SWEEP {
            for round in 0..2 {
                kv.reset();
                with_threads(t, || {
                    for step in 0..prompt.len() {
                        NativeEngine
                            .decode_step(&model, &prompt[..=step], &mut kv, &mut ws, &mut out)
                            .unwrap();
                        assert_eq!(
                            out.row(0),
                            &refs[step][..],
                            "{which} threads {t} round {round} step {step}: \
                             KV decode diverged from full prefill"
                        );
                    }
                });
                assert_eq!(kv.len, prompt.len(), "{which} threads {t} round {round}");
            }
        }
    }
    par::set_max_threads(prev);
}

#[test]
fn generate_reproduces_across_runs_and_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let prev = par::max_threads();
    let model = base_model();
    let prompt: Vec<i32> = (0..8).map(|i| ((i * 5 + 1) % 47) as i32).collect();
    let run = |threads: usize| {
        with_threads(threads, || {
            let mut sampler = Sampler::new(0.8, 8, 0.9);
            let mut rng = Rng::new(2026);
            generate(&mut NativeEngine, &model, &prompt, 24, &mut sampler, &mut rng).unwrap()
        })
    };
    let (ref_tokens, ref_stats) = run(1);
    assert_eq!(ref_stats.produced, 24);
    assert!(!ref_stats.hit_context_limit);
    assert_eq!(ref_tokens.len(), prompt.len() + 24);
    assert!(ref_tokens.iter().all(|&t| (0..47).contains(&t)));
    for t in SWEEP {
        // twice per thread count: across-run AND across-thread reproduction
        for round in 0..2 {
            let (tokens, stats) = run(t);
            assert_eq!(tokens, ref_tokens, "threads {t} round {round}");
            assert_eq!(stats, ref_stats, "threads {t} round {round}");
        }
    }
    par::set_max_threads(prev);
}

#[test]
fn warm_arena_generation_matches_allocating_path() {
    let model = compressed_model();
    let prompt: Vec<i32> = vec![1, 2, 3, 4, 5];
    let mut sampler = Sampler::new(1.1, 0, 0.95);
    let mut rng = Rng::new(9);
    let (want, want_stats) =
        generate(&mut NativeEngine, &model, &prompt, 20, &mut sampler, &mut rng).unwrap();
    let mut kv = KvScratch::new();
    let mut ws = Workspace::new();
    let mut logits = Tensor::default();
    let mut tokens = Vec::new();
    for round in 0..3 {
        let mut rng = Rng::new(9);
        let stats = generate_into(
            &mut NativeEngine, &model, &prompt, 20, &mut sampler, &mut rng,
            &mut kv, &mut ws, &mut logits, &mut tokens,
        )
        .unwrap();
        assert_eq!(tokens, want, "round {round}");
        assert_eq!(stats, want_stats, "round {round}");
    }
}

#[test]
fn generation_stops_cleanly_at_the_context_window() {
    let model = base_model();
    let context = model.pos_emb.shape()[0];
    let mut sampler = Sampler::greedy();
    let mut rng = Rng::new(1);
    // 4 positions of room: asks for 10, produces 4, reports the stop
    let prompt: Vec<i32> = (0..context as i32 - 4).map(|i| i % 47).collect();
    let (tokens, stats) =
        generate(&mut NativeEngine, &model, &prompt, 10, &mut sampler, &mut rng).unwrap();
    assert_eq!(stats.produced, 4);
    assert!(stats.hit_context_limit);
    assert_eq!(tokens.len(), context);
    // a prompt exactly filling the window: clean stop, zero produced
    let full: Vec<i32> = (0..context as i32).map(|i| i % 47).collect();
    let (tokens, stats) =
        generate(&mut NativeEngine, &model, &full, 10, &mut sampler, &mut rng).unwrap();
    assert_eq!(stats.produced, 0);
    assert!(stats.hit_context_limit);
    assert_eq!(tokens, full);
    // an over-long prompt surfaces the typed overflow instead of a silent
    // zero-token success
    let long: Vec<i32> = (0..context as i32 + 1).map(|i| i % 47).collect();
    let err = generate(&mut NativeEngine, &model, &long, 1, &mut sampler, &mut rng).unwrap_err();
    let ov = err.downcast_ref::<ContextOverflow>().unwrap_or_else(|| panic!("got {err:#}"));
    assert_eq!(*ov, ContextOverflow { pos: context, context });
}
