//! Scorer + sweep consistency (mirrors `tests/workspace_reuse.rs` and
//! `tests/par_consistency.rs` methodology for the evaluation stack):
//!
//! * the workspace-backed scorer is **bit-identical** to the seed repo's
//!   allocating path (reproduced verbatim below);
//! * scoring and whole sweeps are bit-identical across `--threads` 1/2/8
//!   and across repeated runs on a warm scratch;
//! * padding the sequence length (64 → 96) changes neither accuracy nor
//!   any per-option score;
//! * the compression-quality ordering — oracle ≥ mergemoe ≥ average mean
//!   correct-option log-likelihood on calibration-matched tasks — is a
//!   tier-1 regression gate instead of a silent science break.

use std::sync::Mutex;

use mergemoe::calib::CalibSource;
use mergemoe::config::ModelConfig;
use mergemoe::eval::scorer::{score_items_scored, score_prepared_ws, PreparedItems};
use mergemoe::eval::sweep::{run_sweep, SweepReport, SweepSpec};
use mergemoe::eval::tasks::{gen_items, Task, TaskItem};
use mergemoe::merge::{Algorithm, NativeGram};
use mergemoe::model::native::target_logprobs;
use mergemoe::model::testprops::synth_model;
use mergemoe::model::workspace::EvalScratch;
use mergemoe::model::ModelWeights;
use mergemoe::runtime::{Engine, NativeEngine};
use mergemoe::tensor::Tensor;
use mergemoe::util::par;

/// Serializes tests that sweep the global thread knob.
static THREAD_KNOB: Mutex<()> = Mutex::new(());

const SWEEP_THREADS: [usize; 3] = [1, 2, 8];

/// Run `f` under an `n`-thread budget, restoring the knob it found (safe
/// to use bare — no caller-side save/restore bookkeeping needed).
fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let prev = par::max_threads();
    par::set_max_threads(n);
    let out = f();
    par::set_max_threads(prev);
    out
}

fn test_model(e: usize, shared: bool, seed: u64) -> ModelWeights {
    let cfg = ModelConfig {
        name: "evalc".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: e,
        top_k: 2,
        shared_expert: shared,
        n_params: 0,
        merge_targets: vec![e / 2],
    };
    synth_model(&cfg, seed)
}

/// The seed repo's scorer, reproduced verbatim: allocating engine path,
/// per-item padded Vecs, round-*down* even chunking. The workspace rework
/// must match it bit for bit (use an even `batch`; odd batches only differ
/// in chunking, which the scorer's own unit tests prove is score-neutral).
fn seed_reference_scores(
    model: &ModelWeights,
    items: &[TaskItem],
    seq_len: usize,
    batch: usize,
) -> Vec<f64> {
    let pad = mergemoe::eval::tasks::encode("\n")[0];
    let mut seqs: Vec<(Vec<i32>, usize, usize)> = Vec::new();
    for item in items {
        for opt in 0..2 {
            let toks = item.full_tokens(opt);
            let plen = item.prompt_len();
            let olen = toks.len() - plen;
            let mut padded = toks;
            padded.resize(seq_len, pad);
            seqs.push((padded, plen, olen));
        }
    }
    let mut scores = Vec::new();
    for chunk in seqs.chunks(batch.max(2) / 2 * 2) {
        let b = chunk.len();
        let mut tokens = Vec::with_capacity(b * seq_len);
        for (t, _, _) in chunk {
            tokens.extend_from_slice(t);
        }
        let logits = NativeEngine.logits(model, &tokens, b, seq_len).unwrap();
        let lps = target_logprobs(&logits, &tokens, b, seq_len);
        for (bi, (_, plen, olen)) in chunk.iter().enumerate() {
            let mut sum = 0.0f64;
            for si in (*plen - 1)..(*plen + *olen - 1) {
                sum += lps[bi * seq_len + si] as f64;
            }
            scores.push(sum / *olen as f64);
        }
    }
    scores
}

#[test]
fn ws_scorer_bit_identical_to_seed_allocating_path() {
    for (e, shared, seed, task) in [
        (4usize, true, 0xA71u64, Task::Copy),
        (6, false, 0xA72, Task::Markov),
    ] {
        let model = test_model(e, shared, seed);
        let items = gen_items(task, 30, 3);
        let want = seed_reference_scores(&model, &items, 64, 16);
        let (acc, got) =
            score_items_scored(&mut NativeEngine, &model, &items, 64, 16).unwrap();
        assert_eq!(got, want, "{task:?}");
        let mut correct = 0;
        for (i, item) in items.iter().enumerate() {
            let pick = if want[2 * i] >= want[2 * i + 1] { 0 } else { 1 };
            if pick == item.correct {
                correct += 1;
            }
        }
        assert_eq!(acc.correct, correct, "{task:?}");
        assert_eq!(acc.total, items.len(), "{task:?}");
    }
}

#[test]
fn warm_scratch_rescoring_bit_identical_across_thread_counts() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let model = test_model(4, false, 0xE7A1);
    let items = gen_items(Task::Maj, 24, 7);
    let mut prep = PreparedItems::new();
    prep.prepare(&items, 64).unwrap();
    let (ref_acc, ref_scores) = with_threads(1, || {
        let mut es = EvalScratch::new();
        let acc = score_prepared_ws(&mut NativeEngine, &model, &prep, 8, &mut es).unwrap();
        (acc, es.scores.clone())
    });
    // one scratch carried across every thread count and round: reuse must
    // be numerically invisible (the workspace_reuse methodology)
    let mut es = EvalScratch::new();
    for t in SWEEP_THREADS {
        for round in 0..3 {
            let acc = with_threads(t, || {
                score_prepared_ws(&mut NativeEngine, &model, &prep, 8, &mut es).unwrap()
            });
            assert_eq!(acc, ref_acc, "threads {t} round {round}");
            assert_eq!(es.scores, ref_scores, "threads {t} round {round}");
        }
    }
}

fn assert_reports_identical(a: &SweepReport, b: &SweepReport, what: &str) {
    assert_eq!(a.calib_sources, b.calib_sources, "{what}");
    assert_eq!(a.n_calib_tokens, b.n_calib_tokens, "{what}");
    assert_eq!(a.variants.len(), b.variants.len(), "{what}");
    for (va, vb) in a.variants.iter().zip(&b.variants) {
        assert_eq!(va.label, vb.label, "{what}");
        assert_eq!(va.source, vb.source, "{what}: {}", va.label);
        assert_eq!(va.m, vb.m, "{what}");
        assert_eq!(va.params, vb.params, "{what}: {}", va.label);
        for (ca, cb) in va.cells.iter().zip(&vb.cells) {
            assert_eq!(
                ca.acc, cb.acc,
                "{what}: {} m={} {}", va.label, va.m, ca.task.name()
            );
            assert_eq!(
                ca.mean_correct_lp.to_bits(),
                cb.mean_correct_lp.to_bits(),
                "{what}: {} m={} {}", va.label, va.m, ca.task.name()
            );
        }
    }
}

#[test]
fn sweep_bit_identical_across_thread_counts_and_reruns() {
    let _guard = THREAD_KNOB.lock().unwrap();
    let model = test_model(4, true, 0x5EED1);
    let mut spec = SweepSpec::new(
        vec![Algorithm::Average, Algorithm::MergeMoe],
        vec![2],
        vec![Task::Copy, Task::Parity],
        vec![0, 1],
    );
    spec.items = 12;
    spec.n_calib_seqs = 6;
    spec.batch = 8;
    let run = || run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
    let reference = with_threads(1, run);
    for t in SWEEP_THREADS {
        for round in 0..2 {
            let rep = with_threads(t, run);
            assert_reports_identical(&reference, &rep, &format!("threads {t} round {round}"));
        }
    }
}

#[test]
fn multi_source_sweep_bit_identical_across_thread_counts() {
    // The four-axis grid (calibration source × method × ratio × task) must
    // be scheduling-invariant exactly like the three-axis one: the
    // pipelined execution (threads > 1: compression of variant k+1
    // overlapping scoring of variant k) reproduces the serial reference
    // bit for bit.
    let _guard = THREAD_KNOB.lock().unwrap();
    let model = test_model(4, false, 0x5EED2);
    let mut spec = SweepSpec::new(
        vec![Algorithm::Average, Algorithm::MSmoe],
        vec![2],
        vec![Task::Copy, Task::Parity],
        vec![0, 1],
    );
    spec.items = 10;
    spec.n_calib_seqs = 4;
    spec.batch = 8;
    spec.calib_sources = vec![
        CalibSource::mixture(),
        CalibSource::single(Task::Copy),
        CalibSource::parse("copy+parity").unwrap(),
    ];
    let run = || run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
    let reference = with_threads(1, run);
    assert_eq!(reference.calib_sources, vec!["mixture", "copy", "copy+parity"]);
    // Full + 3 sources × 2 methods × 1 target, one capture per source
    assert_eq!(reference.variants.len(), 7);
    assert_eq!(reference.n_calib_tokens, 3 * spec.n_calib_seqs * 64);
    for src in &reference.calib_sources {
        for label in ["Average", "M-SMoE"] {
            assert_eq!(
                reference
                    .variants
                    .iter()
                    .filter(|v| v.source == *src && v.label == label)
                    .count(),
                1,
                "{src}/{label}"
            );
        }
    }
    for t in SWEEP_THREADS {
        for round in 0..2 {
            let rep = with_threads(t, run);
            assert_reports_identical(&reference, &rep, &format!("threads {t} round {round}"));
        }
    }
}

#[test]
fn degenerate_sweep_grids_run_at_every_thread_count() {
    // 1-variant, 1-task grid without the Full row: the pipeline's smallest
    // possible stream still completes and matches the serial reference.
    let _guard = THREAD_KNOB.lock().unwrap();
    let model = test_model(4, false, 0x1D3);
    let mut spec = SweepSpec::new(
        vec![Algorithm::Average],
        vec![2],
        vec![Task::Copy],
        vec![0],
    );
    spec.items = 6;
    spec.n_calib_seqs = 2;
    spec.batch = 4;
    spec.include_full = false;
    let run = || run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
    let reference = with_threads(1, run);
    assert_eq!(reference.variants.len(), 1);
    assert_eq!(reference.variants[0].cells.len(), 1);
    for t in SWEEP_THREADS {
        let rep = with_threads(t, run);
        assert_reports_identical(&reference, &rep, &format!("threads {t}"));
    }
    // the empty grid is still rejected, at any thread count
    let mut bad = spec.clone();
    bad.tasks.clear();
    for t in SWEEP_THREADS {
        with_threads(t, || {
            assert!(run_sweep(&model, &bad, &mut NativeGram, &mut NativeEngine).is_err());
        });
    }
}

#[test]
fn pipeline_matches_serial_across_thread_counts() {
    // The handoff primitive itself: identical stage closures must yield
    // identical results whether the stages run back to back (threads = 1)
    // or overlapped (threads > 1) — the mechanism behind the sweep's
    // thread-count invariance.
    let _guard = THREAD_KNOB.lock().unwrap();
    let work = |threads: usize| -> Vec<u64> {
        with_threads(threads, || {
            par::pipeline(
                1,
                |tx| {
                    for i in 0..17u64 {
                        if !tx.push(i * i + 1) {
                            break;
                        }
                    }
                },
                |rx| {
                    let mut out = Vec::new();
                    while let Some(v) = rx.pop() {
                        out.push(v * 3);
                    }
                    out
                },
            )
            .1
        })
    };
    let reference = work(1);
    assert_eq!(reference.len(), 17);
    for t in SWEEP_THREADS {
        assert_eq!(work(t), reference, "threads {t}");
    }
}

#[test]
fn pipeline_overlaps_production_with_consumption() {
    // Pin the tentpole property: with threads > 1 and capacity 1, the
    // producer works on item k+1 while the consumer still holds item k.
    // While consuming item 0 we wait (generous timeout, no flakiness —
    // after our pop the producer is unblocked by construction) for the
    // producer to signal that production of item 2 has started; a serial
    // execution can never deliver that signal.
    let _guard = THREAD_KNOB.lock().unwrap();
    with_threads(4, || {
        let started = Mutex::new(0usize); // 1 + highest item index started
        let cv = std::sync::Condvar::new();
        let (_, consumed) = par::pipeline(
            1,
            |tx| {
                for i in 0..3usize {
                    {
                        let mut s = started.lock().unwrap();
                        *s = i + 1;
                        cv.notify_all();
                    }
                    if !tx.push(i) {
                        break;
                    }
                }
            },
            |rx| {
                let mut got = Vec::new();
                while let Some(i) = rx.pop() {
                    if i == 0 {
                        let deadline = std::time::Duration::from_secs(30);
                        let t0 = std::time::Instant::now();
                        let mut s = started.lock().unwrap();
                        while *s < 3 && t0.elapsed() < deadline {
                            let (back, _) = cv
                                .wait_timeout(s, std::time::Duration::from_millis(100))
                                .unwrap();
                            s = back;
                        }
                        assert!(
                            *s >= 3,
                            "production of item 2 never started while item 0 was \
                             being consumed — no overlap"
                        );
                    }
                    got.push(i);
                }
                got
            },
        );
        assert_eq!(consumed, vec![0, 1, 2]);
    });
}

#[test]
fn pipeline_consumer_exit_unblocks_producer() {
    // A consume stage that stops early (e.g. a scoring error) must turn
    // subsequent pushes into `false` instead of deadlocking the producer.
    let _guard = THREAD_KNOB.lock().unwrap();
    with_threads(4, || {
        let (pushed, consumed) = par::pipeline(
            1,
            |tx| {
                let mut n = 0u32;
                for i in 0..1000u32 {
                    if !tx.push(i) {
                        break;
                    }
                    n += 1;
                }
                n
            },
            |rx| {
                let mut got = Vec::new();
                for _ in 0..3 {
                    match rx.pop() {
                        Some(v) => got.push(v),
                        None => break,
                    }
                }
                got
            },
        );
        assert_eq!(consumed, vec![0, 1, 2]);
        // capacity 1 bounds the producer to the 3 consumed items plus at
        // most one queued item before it observes the abandonment
        assert!(
            (3..=4).contains(&pushed),
            "producer must stop right after the consumer leaves, pushed {pushed}"
        );
    });
}

#[test]
fn padding_invariance_seq_64_vs_96() {
    // the scorer module doc's promise at bucket scale: growing seq_len from
    // 64 to 96 (pad-only tail; position table zero-extended) changes
    // neither accuracy nor any per-option score — causal attention keeps
    // every scored position independent of trailing pad
    let mut model = test_model(4, true, 0x9AD);
    let d = model.cfg.d_model;
    let mut pos = model.pos_emb.data().to_vec();
    pos.resize(96 * d, 0.0);
    model.pos_emb = Tensor::from_vec(&[96, d], pos).unwrap();
    let items = gen_items(Task::Arith, 30, 9);
    let (acc64, s64) = score_items_scored(&mut NativeEngine, &model, &items, 64, 16).unwrap();
    let (acc96, s96) = score_items_scored(&mut NativeEngine, &model, &items, 96, 16).unwrap();
    assert_eq!(acc64, acc96);
    assert_eq!(s64, s96);
}

#[test]
fn method_ordering_on_calibration_distribution() {
    // Compression-quality regression gate: on calibration-matched tasks the
    // mean correct-option log-likelihood must order
    // oracle ≥ mergemoe ≥ average (tolerance-banded, seeded). The ordering
    // holds in expectation because a larger merge output error is a larger
    // logit perturbation, and E[logit - logsumexp(logits + ε)] falls with
    // the perturbation's size (Jensen on the convex logsumexp).
    let model = test_model(8, false, 0x0DE2);
    let tasks = vec![Task::Copy, Task::Parity, Task::Markov];
    let mut spec = SweepSpec::new(
        vec![Algorithm::Oracle, Algorithm::MergeMoe, Algorithm::Average],
        vec![3],
        tasks.clone(),
        vec![0, 1],
    );
    spec.items = 60;
    spec.n_calib_seqs = 24;
    spec.batch = 32;
    spec.calib_tasks = Some(tasks);
    spec.seed = 20260;
    let rep = run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
    let lp = |label: &str| rep.variant(label, 3).expect(label).mean_correct_lp();
    let (or, mm, av) = (lp("Oracle"), lp("MergeMoE"), lp("Average"));
    assert!(or + 0.05 >= mm, "oracle {or} must be >= mergemoe {mm} (band 0.05)");
    assert!(mm + 0.05 >= av, "mergemoe {mm} must be >= average {av} (band 0.05)");
    // and the uncompressed model sits at or above the oracle band
    let full = rep
        .variant("Full", model.cfg.n_experts)
        .expect("full row")
        .mean_correct_lp();
    assert!(full + 0.05 >= or, "full {full} must be >= oracle {or} (band 0.05)");
}
