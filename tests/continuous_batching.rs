//! Continuous-batching tests for the scoring server: the collector thread
//! forms batch k+1 while the compute lanes run batch k, and N lanes
//! (`ServerConfig::workers` / `MERGEMOE_WORKERS`) drain the formed-batch
//! queue concurrently. These pin the three ledger claims:
//!
//! * overlap — a batch *forms during* an in-flight forward pass (the
//!   `overlapped` counter is the witness);
//! * bit-identity — per-request scores are bit-identical whether the
//!   server runs 1 lane or many, serial or concurrent clients (sequences
//!   are independent rows of the forward pass);
//! * supervision + drain survive the collector/lane split — per-lane
//!   panics respawn under one *shared* restart budget, and shutdown
//!   completes every admitted request across all lanes.
//!
//! Native engine on a small synthetic model: runs on a bare checkout.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;
use mergemoe::config::ModelConfig;
use mergemoe::coordinator::{FaultSetting, ScoringServer, ServeError, ServerConfig};
use mergemoe::model::testprops::synth_model;
use mergemoe::model::workspace::Workspace;
use mergemoe::model::ModelWeights;
use mergemoe::runtime::{Engine, NativeEngine};
use mergemoe::tensor::Tensor;
use mergemoe::util::fault::{FaultAction, FaultPlan};

/// Same fixed model as tests/fault_injection.rs, so scores are comparable
/// across the two suites.
fn test_model() -> ModelWeights {
    let cfg = ModelConfig {
        name: "contbatch".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: false,
        n_params: 0,
        merge_targets: vec![2],
    };
    synth_model(&cfg, 77)
}

/// Base config: explicit `workers` per test (the env default would let
/// `MERGEMOE_WORKERS` change what a single-lane pin exercises).
fn cfg_with_workers(workers: usize) -> ServerConfig {
    ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        seq_len: 64,
        fault: FaultSetting::Off,
        retry_backoff: Duration::from_micros(200),
        drain_timeout: Duration::from_secs(5),
        workers,
        ..ServerConfig::default()
    }
}

/// Wait (bounded) until `pred` holds; panics on timeout so a broken
/// condition fails the test instead of hanging it.
fn wait_for(what: &str, mut pred: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !pred() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

// ---------------------------------------------------------------------------
// the overlap pin: batch k+1 forms while batch k computes
// ---------------------------------------------------------------------------

/// A gate the test holds closed while an engine call is in flight. The
/// engine-side wait is capped (8s) so a buggy test that never releases
/// fails loudly instead of wedging the lane thread forever.
struct Gate {
    entered: AtomicUsize,
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { entered: AtomicUsize::new(0), open: Mutex::new(false), cv: Condvar::new() })
    }

    fn pass(&self) {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let t0 = Instant::now();
        let mut open = self.open.lock().unwrap();
        while !*open && t0.elapsed() < Duration::from_secs(8) {
            let (g, _) = self.cv.wait_timeout(open, Duration::from_millis(50)).unwrap();
            open = g;
        }
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// Engine that parks every forward pass on the gate before delegating, so
/// the test controls exactly when "batch k is computing".
struct GatedEngine {
    gate: Arc<Gate>,
}

impl Engine for GatedEngine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        self.gate.pass();
        NativeEngine.logits(model, tokens, b, s)
    }

    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        self.gate.pass();
        NativeEngine.logits_ws(model, tokens, b, s, ws, out)
    }

    fn name(&self) -> &'static str {
        "gated"
    }
}

#[test]
fn next_batch_forms_while_previous_batch_computes() {
    let gate = Gate::new();
    let g2 = gate.clone();
    let server = ScoringServer::start(test_model(), cfg_with_workers(1), move || {
        Ok(GatedEngine { gate: g2.clone() })
    })
    .unwrap();
    let h = server.handle();
    let status = server.status();

    // request A reaches the (gated) engine: batch 1 is now computing
    let ha = h.clone();
    let a = std::thread::spawn(move || ha.score("c:abcd|", "abcd."));
    let ge = gate.clone();
    wait_for("batch 1 to enter the engine", move || ge.entered.load(Ordering::SeqCst) >= 1);

    // request B arrives mid-compute; the collector must form and hand off
    // batch 2 *now*, without waiting for batch 1 — the `overlapped`
    // counter only increments when a handoff sees a lane mid-forward
    let hb = h.clone();
    let b = std::thread::spawn(move || hb.score("r:abc|", "cba."));
    wait_for("batch 2 to form during batch 1's forward pass", || {
        status.metrics().overlapped >= 1
    });

    gate.release();
    assert!(a.join().unwrap().is_ok());
    assert!(b.join().unwrap().is_ok());
    let m = server.shutdown();
    assert_eq!(m.batches, 2, "A and B must be separate batches");
    assert_eq!(m.overlapped, 1, "exactly B's batch formed during compute");
    assert_eq!(m.requests, 2);
    assert_eq!(m.errors, 0);
}

// ---------------------------------------------------------------------------
// bit-identity: lane count and batch composition never change a score
// ---------------------------------------------------------------------------

/// The fixed request set every identity test scores (distinct tasks, so
/// a cross-wired reply would be caught by value, not just by count).
const REQS: [(&str, &str); 4] =
    [("c:abcd|", "abcd."), ("r:abc|", "cba."), ("c:xyxy|", "xyxy."), ("c:abab|", "abab.")];

/// Score 12 requests (3 cycles of `REQS`) from 12 concurrent clients on a
/// server with `workers` lanes; returns score bits indexed by request.
fn concurrent_bits(workers: usize) -> Vec<u64> {
    let server =
        ScoringServer::start(test_model(), cfg_with_workers(workers), || Ok(NativeEngine))
            .unwrap();
    let h = server.handle();
    let joins: Vec<_> = (0..12)
        .map(|i| {
            let hc = h.clone();
            let (p, c) = REQS[i % REQS.len()];
            std::thread::spawn(move || hc.score(p, c).unwrap().to_bits())
        })
        .collect();
    let bits = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let m = server.shutdown();
    assert_eq!(m.requests, 12);
    assert_eq!(m.errors, 0);
    bits
}

#[test]
fn scores_are_bit_identical_across_lane_counts() {
    // reference: one request per batch, single lane, serial client
    let server =
        ScoringServer::start(test_model(), cfg_with_workers(1), || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let want: Vec<u64> = (0..12)
        .map(|i| {
            let (p, c) = REQS[i % REQS.len()];
            h.score(p, c).unwrap().to_bits()
        })
        .collect();
    server.shutdown();

    // single lane under concurrency: batches coalesce, scores must not move
    assert_eq!(concurrent_bits(1), want, "workers=1 concurrent diverged from serial");
    // multi-lane: requests land on arbitrary lanes in arbitrary batch
    // compositions; every score still bit-identical (row independence)
    assert_eq!(concurrent_bits(4), want, "workers=4 diverged from workers=1");
}

// ---------------------------------------------------------------------------
// drain under multi-lane load: every admitted request completes
// ---------------------------------------------------------------------------

#[test]
fn drain_under_load_completes_all_admitted_across_lanes() {
    // lane count honors MERGEMOE_WORKERS (the ci.sh multi-lane sweep sets
    // it), clamped to >= 2 so the test is always genuinely multi-lane
    let workers = ServerConfig::default().workers.max(2);
    // the first `workers` batches stall (one per lane), so the backlog
    // behind them is deterministically still queued when shutdown lands
    let stalls: Vec<FaultAction> =
        (0..workers).map(|_| FaultAction::Slow(Duration::from_millis(300))).collect();
    let plan = Arc::new(FaultPlan::scripted(stalls));
    let cfg =
        ServerConfig { fault: FaultSetting::Plan(plan.clone()), ..cfg_with_workers(workers) };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();

    // stall every lane back to back: each send waits until a lane has
    // actually begun the stalled attempt before the next goes out, so two
    // stall requests cannot coalesce into one batch
    let mut stalled = Vec::new();
    for i in 0..workers {
        let hc = h.clone();
        stalled.push(std::thread::spawn(move || hc.score("c:abcd|", "abcd.")));
        let p = plan.clone();
        wait_for("a lane to begin the stalled attempt", move || p.attempts() >= (i + 1) as u64);
    }
    // pile a backlog up behind the stalled lanes
    let joins: Vec<_> = (0..8)
        .map(|i| {
            let hc = h.clone();
            let (p, c) = REQS[i % REQS.len()];
            std::thread::spawn(move || hc.score(p, c))
        })
        .collect();
    wait_for("backlog to be admitted", || h.queue_depth() == 8);

    // shut down while the backlog spans the collector, the formed-batch
    // queue, and the stalled lanes
    let shutdown = std::thread::spawn(move || server.shutdown());
    for s in stalled {
        assert!(s.join().unwrap().is_ok());
    }
    for j in joins {
        assert!(j.join().unwrap().is_ok(), "drain must complete every admitted request");
    }
    let m = shutdown.join().unwrap();
    assert_eq!(m.requests, (workers + 8) as u64);
    assert_eq!(m.errors, 0);
    assert_eq!(
        m.lane_batches.iter().sum::<u64>(),
        m.batches,
        "every batch is attributed to exactly one lane"
    );
    // ...and new work is refused through the still-live handle clone
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::ShuttingDown));
}

// ---------------------------------------------------------------------------
// supervision across lanes: respawn, then degrade, under ONE shared budget
// ---------------------------------------------------------------------------

#[test]
fn lane_panics_respawn_under_shared_budget() {
    let plan = Arc::new(FaultPlan::scripted(vec![FaultAction::Panic, FaultAction::Panic]));
    let cfg = ServerConfig {
        fault: FaultSetting::Plan(plan.clone()),
        restart_budget: 4,
        ..cfg_with_workers(2)
    };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    // two panics land on whichever lanes pop those batches; both respawn
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::WorkerPanicked));
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::WorkerPanicked));
    // the fleet is healthy again: fresh engines serve the next request
    assert!(h.score("c:abcd|", "abcd.").is_ok());
    assert!(!server.status().degraded());
    let m = server.shutdown();
    assert_eq!(m.restarted, 2);
    assert_eq!(m.errors, 2);
}

#[test]
fn shared_budget_exhaustion_degrades_the_whole_server() {
    // budget 1 across BOTH lanes: the first panic consumes it, the second
    // (wherever it lands) must find it spent and degrade — a per-lane
    // budget would have respawned a second time
    let plan = Arc::new(FaultPlan::scripted(vec![FaultAction::Panic, FaultAction::Panic]));
    let cfg = ServerConfig {
        fault: FaultSetting::Plan(plan.clone()),
        restart_budget: 1,
        ..cfg_with_workers(2)
    };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let status = server.status();
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::WorkerPanicked));
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::WorkerPanicked));
    wait_for("degraded flag", || status.degraded());
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::Degraded));
    let m = server.shutdown();
    assert_eq!(m.restarted, 1, "only the single budgeted respawn happened");
}
