//! Variant-cache routing tests: a routed `/score` request names a
//! `{method, ratio, calib_source}` triple and the server resolves it
//! through the memory-budgeted [`VariantCache`]. These pin the four
//! ledger claims:
//!
//! * single-flight — N concurrent cold requests for one variant trigger
//!   exactly ONE build; everyone else parks on the in-flight slot;
//! * eviction under budget pressure — with a budget that fits 2 of 3
//!   variants, round-robin traffic completes every request with the
//!   bit-exact score of the variant it asked for (evict/rebuild cycles
//!   never cross-wire weights), and peak cache bytes stay ≤ budget;
//! * quarantine + fallback — a fatally failing build quarantines the
//!   variant (typed fast-fail, no rebuild storm); `--route-fallback base`
//!   instead serves quarantined traffic on the boot weights with the
//!   `fallback` marker set;
//! * bit-identity — a routed score equals compressing the same spec
//!   directly (`capture_calibration_source` + `compress_with_calib`) and
//!   scoring the result, across 1 and 4 lanes.
//!
//! Native engine on a small synthetic model: runs on a bare checkout.

use std::sync::Arc;
use std::time::Duration;

use mergemoe::config::ModelConfig;
use mergemoe::coordinator::{
    capture_calibration_source, compress_with_calib, CacheConfig, CalibSource, FaultSetting,
    RouteFallback, ScoringServer, ServeError, ServerConfig, VariantCache, VariantKey,
};
use mergemoe::merge::NativeGram;
use mergemoe::model::testprops::synth_model;
use mergemoe::model::workspace::Workspace;
use mergemoe::model::ModelWeights;
use mergemoe::runtime::NativeEngine;
use mergemoe::util::fault::{FaultAction, FaultPlan};

/// Same shape as tests/continuous_batching.rs (4 experts, so ratio 0.5
/// resolves to m=2), under its own name/seed.
fn test_model() -> ModelWeights {
    let cfg = ModelConfig {
        name: "varcache".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: false,
        n_params: 0,
        merge_targets: vec![2],
    };
    synth_model(&cfg, 91)
}

/// Cache knobs every test shares: tiny calibration (speed), fast retries,
/// fixed seed so rebuilds after eviction are bit-identical.
fn test_cache_cfg(budget_bytes: usize) -> CacheConfig {
    CacheConfig {
        budget_bytes,
        max_retries: 1,
        retry_backoff: Duration::from_micros(100),
        n_calib_seqs: 8,
        seed: 0xC0FFEE,
    }
}

fn cfg_with_workers(workers: usize) -> ServerConfig {
    ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        seq_len: 64,
        queue_cap: 64,
        fault: FaultSetting::Off,
        retry_backoff: Duration::from_micros(200),
        drain_timeout: Duration::from_secs(5),
        workers,
        cache: test_cache_cfg(1 << 30),
        ..ServerConfig::default()
    }
}

/// The sweep path the cache's cold build must reproduce bit for bit:
/// the cache's own [`VariantCache::build_spec`] fed through
/// `capture_calibration_source` + `compress_with_calib` on `NativeGram`.
fn reference_model(key: &VariantKey, cache_cfg: &CacheConfig) -> ModelWeights {
    let probe = VariantCache::new(test_model(), None, cache_cfg.clone(), None);
    let spec = probe.build_spec(key);
    let source = CalibSource::parse(&key.calib).unwrap();
    let calib =
        capture_calibration_source(probe.base(), spec.n_calib_seqs, &source, spec.seed).unwrap();
    let mut ws = Workspace::new();
    let (model, _report) =
        compress_with_calib(probe.base(), &spec, &mut NativeGram, &calib, &mut ws).unwrap();
    model
}

/// Score `reqs` on `model` through an unrouted single-lane server — the
/// pre-routing serving path, used as the bit-identity reference.
fn direct_bits(model: ModelWeights, reqs: &[(&str, &str)]) -> Vec<u64> {
    let server = ScoringServer::start(model, cfg_with_workers(1), || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let bits = reqs.iter().map(|&(p, c)| h.score(p, c).unwrap().to_bits()).collect();
    server.shutdown();
    bits
}

/// Fixed request set (distinct tasks, so a cross-wired reply is caught by
/// value, not just by count).
const REQS: [(&str, &str); 4] =
    [("c:abcd|", "abcd."), ("r:abc|", "cba."), ("c:xyxy|", "xyxy."), ("c:abab|", "abab.")];

// ---------------------------------------------------------------------------
// single-flight: 8 concurrent cold requests, exactly 1 build
// ---------------------------------------------------------------------------

#[test]
fn eight_concurrent_cold_requests_build_exactly_once() {
    // max_batch 1 forces one checkout per request: 8 requests race for the
    // cold slot across 4 lanes instead of coalescing into one batch
    let cfg = ServerConfig { max_batch: 1, ..cfg_with_workers(4) };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let key = h.resolve_variant("average", 0.5, "copy").unwrap();

    let joins: Vec<_> = (0..8)
        .map(|_| {
            let hc = h.clone();
            let k = key.clone();
            std::thread::spawn(move || hc.score_routed("c:abcd|", "abcd.", Some(k)).unwrap())
        })
        .collect();
    let outs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    let bits = outs[0].score.to_bits();
    for o in &outs {
        assert!(!o.fallback, "no quarantine in play, nothing may be marked fallback");
        assert_eq!(o.score.to_bits(), bits, "all 8 scored the same variant");
    }
    let stats = server.status().cache_stats();
    assert_eq!(stats.builds, 1, "single-flight: 8 cold requests, ONE build");
    assert_eq!(stats.misses, 1, "only the builder took the cold path");
    assert_eq!(stats.build_failures, 0);
    assert_eq!(stats.quarantined, 0);
    let m = server.shutdown();
    assert_eq!(m.requests, 8);
    assert_eq!(m.errors, 0);
}

// ---------------------------------------------------------------------------
// eviction under budget pressure: right scores, bounded bytes
// ---------------------------------------------------------------------------

#[test]
fn eviction_under_budget_pressure_never_serves_wrong_variant() {
    // three variants, two of one size (m=2) and one smaller (m=1), with
    // pairwise-distinct scores; budget = 2 × the m=2 size, so any two fit
    // and the third always forces an eviction
    let cache_cfg = test_cache_cfg(1 << 30);
    let triples = [("mergemoe", 0.5, "mixture"), ("average", 0.5, "copy"), ("mergemoe", 0.25, "mixture")];
    let mut keys = Vec::new();
    let mut want = Vec::new(); // per-variant reference bits for REQS[0]
    let mut m2_bytes = 0usize;
    for &(method, ratio, calib) in &triples {
        let key = VariantKey::resolve(method, ratio, calib, 4).unwrap();
        let model = reference_model(&key, &cache_cfg);
        if key.m == 2 {
            m2_bytes = model.n_params() * 4;
        }
        want.push(direct_bits(model, &REQS[..1])[0]);
        keys.push(key);
    }
    assert!(m2_bytes > 0);
    assert_eq!(
        want.iter().collect::<std::collections::HashSet<_>>().len(),
        3,
        "the three variants must be distinguishable by score for this test to mean anything"
    );

    let budget = 2 * m2_bytes;
    let cfg = ServerConfig {
        cache: CacheConfig { budget_bytes: budget, ..cache_cfg },
        ..cfg_with_workers(2)
    };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();

    // one client per variant, hammering concurrently: evict/rebuild churn
    // with lanes pinning entries mid-batch
    const ROUNDS: usize = 6;
    let joins: Vec<_> = keys
        .iter()
        .zip(&want)
        .map(|(key, &want_bits)| {
            let hc = h.clone();
            let k = key.clone();
            std::thread::spawn(move || {
                for round in 0..ROUNDS {
                    let out =
                        hc.score_routed(REQS[0].0, REQS[0].1, Some(k.clone())).unwrap_or_else(
                            |e| panic!("round {round} of {} failed: {e}", k.label()),
                        );
                    assert!(!out.fallback);
                    assert_eq!(
                        out.score.to_bits(),
                        want_bits,
                        "round {round}: {} served some other variant's weights",
                        k.label()
                    );
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }

    // deterministic tail: one sequential round-robin cycle. The cache holds
    // at most 2 of the 3 variants, so at least one access here is a miss
    // that must evict + rebuild — churn is guaranteed even if the threads
    // above happened to run serially
    for (key, &want_bits) in keys.iter().zip(&want) {
        let out = h.score_routed(REQS[0].0, REQS[0].1, Some(key.clone())).unwrap();
        assert_eq!(out.score.to_bits(), want_bits, "{} after churn", key.label());
    }

    let stats = server.status().cache_stats();
    assert!(stats.evictions >= 2, "3 variants under a 2-variant budget must evict");
    assert!(
        stats.builds >= 4,
        "evicted variants were rebuilt on return (builds = {})",
        stats.builds
    );
    assert!(
        stats.bytes_peak <= budget as u64,
        "peak cache bytes {} exceeded the budget {}",
        stats.bytes_peak,
        budget
    );
    assert!(stats.bytes <= budget as u64);
    assert_eq!(stats.quarantined, 0);
    let m = server.shutdown();
    assert_eq!(m.requests, (3 * ROUNDS + 3) as u64);
    assert_eq!(m.errors, 0, "every admitted request completed");
    assert_eq!(m.fallbacks, 0);
}

// ---------------------------------------------------------------------------
// quarantine + fallback
// ---------------------------------------------------------------------------

#[test]
fn fatal_build_quarantines_and_fails_fast_typed() {
    let plan = Arc::new(FaultPlan::scripted(vec![]).with_build_script(vec![FaultAction::Fatal]));
    let cfg = ServerConfig {
        fault: FaultSetting::Plan(plan.clone()),
        ..cfg_with_workers(1)
    };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let key = h.resolve_variant("mergemoe", 0.5, "mixture").unwrap();

    // first request takes the builder role and hits the fatal injection
    let err = h.score_routed("c:abcd|", "abcd.", Some(key.clone())).unwrap_err();
    assert!(
        matches!(err, ServeError::VariantUnavailable(_)),
        "fatal build must surface typed, got {err:?}"
    );
    // second request fails fast from quarantine — no second build attempt
    let err2 = h.score_routed("c:abcd|", "abcd.", Some(key.clone())).unwrap_err();
    assert!(matches!(err2, ServeError::VariantUnavailable(_)));
    assert_eq!(plan.build_attempts(), 1, "quarantine must not re-trigger the build");

    let stats = server.status().cache_stats();
    assert_eq!(stats.quarantined, 1);
    assert_eq!(stats.build_failures, 1, "fatal = no retries");
    assert_eq!(stats.builds, 0);

    // boot-path traffic is untouched by the quarantine
    assert!(h.score("c:abcd|", "abcd.").is_ok());
    let m = server.shutdown();
    assert_eq!(m.fallbacks, 0, "RouteFallback::Reject never serves fallback traffic");
}

#[test]
fn route_fallback_base_serves_quarantined_traffic_on_boot_weights() {
    // reference: the boot score on an unrouted, fault-free server
    let boot_bits = direct_bits(test_model(), &REQS[..1])[0];

    let plan = Arc::new(FaultPlan::scripted(vec![]).with_build_script(vec![FaultAction::Fatal]));
    let cfg = ServerConfig {
        fault: FaultSetting::Plan(plan.clone()),
        route_fallback: RouteFallback::Base,
        ..cfg_with_workers(1)
    };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let key = h.resolve_variant("mergemoe", 0.5, "mixture").unwrap();

    // the build fails fatally; instead of a typed reject the request is
    // served on the boot weights, visibly marked
    let out = h.score_routed(REQS[0].0, REQS[0].1, Some(key.clone())).unwrap();
    assert!(out.fallback, "quarantined traffic under --route-fallback base must be marked");
    assert_eq!(out.score.to_bits(), boot_bits, "fallback scores on the boot weights");

    // a second routed request: still quarantined, still served + marked
    let out2 = h.score_routed(REQS[0].0, REQS[0].1, Some(key)).unwrap();
    assert!(out2.fallback);
    assert_eq!(plan.build_attempts(), 1);

    // unrouted traffic on the same server is NOT marked
    let plain = h.score_routed(REQS[0].0, REQS[0].1, None).unwrap();
    assert!(!plain.fallback);
    assert_eq!(plain.score.to_bits(), boot_bits);

    let m = server.shutdown();
    assert_eq!(m.fallbacks, 2, "exactly the two quarantined-variant requests fell back");
    assert_eq!(m.errors, 0);
}

// ---------------------------------------------------------------------------
// env-driven chaos: the ci.sh seeded sweep lands here
// ---------------------------------------------------------------------------

/// `FaultSetting::FromEnv` (the server default): ci.sh reruns this suite
/// under seeded `MERGEMOE_FAULT` plans with `build-fail:N` and `io-fail:N`
/// composed in. The contract under chaos: every admitted request resolves
/// to a typed outcome (an `Ok` score or a `ServeError` — the unwraps
/// below would panic on anything else), every `Ok` is bit-exact for the
/// variant it asked for, and peak cache bytes never exceed the budget.
/// With the env unset this runs fault-free and every request succeeds.
#[test]
fn seeded_chaos_round_robin_stays_typed_and_bit_exact() {
    let cache_cfg = test_cache_cfg(1 << 30);
    let triples = [("mergemoe", 0.5, "mixture"), ("average", 0.5, "copy"), ("mergemoe", 0.25, "mixture")];
    let mut keys = Vec::new();
    let mut want = Vec::new();
    let mut m2_bytes = 0usize;
    for &(method, ratio, calib) in &triples {
        let key = VariantKey::resolve(method, ratio, calib, 4).unwrap();
        let model = reference_model(&key, &cache_cfg);
        if key.m == 2 {
            m2_bytes = model.n_params() * 4;
        }
        want.push(direct_bits(model, &REQS[..1])[0]);
        keys.push(key);
    }

    let budget = 2 * m2_bytes;
    let cfg = ServerConfig {
        fault: FaultSetting::FromEnv,
        cache: CacheConfig { budget_bytes: budget, ..cache_cfg },
        ..cfg_with_workers(2)
    };
    let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();

    let joins: Vec<_> = keys
        .iter()
        .zip(&want)
        .map(|(key, &want_bits)| {
            let hc = h.clone();
            let k = key.clone();
            std::thread::spawn(move || {
                let mut ok = 0usize;
                for _ in 0..6 {
                    match hc.score_routed(REQS[0].0, REQS[0].1, Some(k.clone())) {
                        Ok(out) => {
                            assert!(!out.fallback, "Reject mode never serves fallback");
                            assert_eq!(
                                out.score.to_bits(),
                                want_bits,
                                "chaos must fail requests typed, never cross-wire {}",
                                k.label()
                            );
                            ok += 1;
                        }
                        // injected engine faults / exhausted retries /
                        // degradation surface typed — that IS the contract
                        Err(_) => {}
                    }
                }
                ok
            })
        })
        .collect();
    let ok: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();

    let stats = server.status().cache_stats();
    assert!(
        stats.bytes_peak <= budget as u64,
        "peak cache bytes {} exceeded the budget {} under chaos",
        stats.bytes_peak,
        budget
    );
    server.shutdown();
    if std::env::var("MERGEMOE_FAULT").is_err() {
        assert_eq!(ok, 18, "fault-free run must succeed every request");
    }
}

// ---------------------------------------------------------------------------
// bit-identity: routed score ≡ direct compression + scoring, lanes 1 and 4
// ---------------------------------------------------------------------------

#[test]
fn routed_scores_match_direct_compression_across_lane_counts() {
    let cache_cfg = test_cache_cfg(1 << 30);
    let key = VariantKey::resolve("mergemoe", 0.5, "mixture", 4).unwrap();
    // the sweep path: compress with the cache's own spec, score directly
    let want = direct_bits(reference_model(&key, &cache_cfg), &REQS);

    for workers in [1usize, 4] {
        let cfg = ServerConfig { cache: cache_cfg.clone(), ..cfg_with_workers(workers) };
        let server = ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        let hk = h.resolve_variant("MergeMoE", 0.5, "mixture").unwrap();
        assert_eq!(hk, key, "resolve canonicalizes spellings to one cache identity");

        // concurrent clients: arbitrary lanes, arbitrary batch splits
        let joins: Vec<_> = (0..12)
            .map(|i| {
                let hc = h.clone();
                let k = hk.clone();
                let (p, c) = REQS[i % REQS.len()];
                std::thread::spawn(move || {
                    let out = hc.score_routed(p, c, Some(k)).unwrap();
                    assert!(!out.fallback);
                    out.score.to_bits()
                })
            })
            .collect();
        let bits: Vec<u64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(
                b,
                want[i % REQS.len()],
                "workers={workers}: routed score diverged from the sweep path"
            );
        }
        let stats = server.status().cache_stats();
        assert_eq!(stats.builds, 1, "one cold build serves all 12 requests");
        let m = server.shutdown();
        assert_eq!(m.errors, 0);
    }
}
