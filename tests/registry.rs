//! Chaos suite for the crash-safe variant registry and the hot-swap /
//! hot-reload serving path built on it:
//!
//! * torn-write sweep — the writer is killed (via the `io_gate` fail-point)
//!   at *every* fsync/rename crossing of `Registry::add`; after each kill
//!   the registry must reopen clean, with either the prior version intact
//!   or the new one fully committed (the kill landed after the atomic
//!   rename), never anything in between;
//! * bit-flip — corrupting a stored blob yields a typed
//!   `RegistryError::Corrupt`, quarantine (never deletion), and fallback to
//!   the last good version;
//! * hot-swap under live traffic — zero dropped or failed requests across
//!   the swap (the ARCHITECTURE.md swap-atomicity ledger row);
//! * failed swap — a probe-rejected candidate rolls back with the
//!   incumbent untouched and still serving;
//! * the full admin flow over HTTP — `/healthz` JSON shape, registry swap,
//!   validate-then-commit reload and its rejection reporting;
//! * an env-driven chaos run honoring `MERGEMOE_FAULT` (the ci.sh 3-seed
//!   sweep), with registry writes and a mid-run swap in the mix.
//!
//! Everything runs on small synthetic models (no artifacts needed). Tests
//! that arm the process-global IO fail-point or write through `io_gate`
//! serialize on one mutex so parallel test threads cannot perturb each
//! other's schedules.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use mergemoe::config::ModelConfig;
use mergemoe::coordinator::{
    AdminState, FaultSetting, HttpServer, Registry, RegistryError, ScoringServer, ServerConfig,
    VariantSpec,
};
use mergemoe::model::testprops::synth_model;
use mergemoe::model::ModelWeights;
use mergemoe::runtime::NativeEngine;
use mergemoe::tensor::Tensor;
use mergemoe::util::fault::{arm_io_fail, io_crossings, FaultPlan, InjectedIoFault};
use mergemoe::util::json::Json;

/// Serializes every test that arms or crosses the process-global IO
/// fail-point.
fn io_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn tmp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("mergemoe_registry_chaos")
        .join(format!("{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cfg4() -> ModelConfig {
    ModelConfig {
        name: "regchaos".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: false,
        n_params: 0,
        merge_targets: vec![2],
    }
}

fn model(seed: u64) -> ModelWeights {
    synth_model(&cfg4(), seed)
}

fn spec() -> VariantSpec {
    VariantSpec { method: "mergemoe".into(), ratio: 0.8, calib_source: "mixture".into() }
}

fn base_cfg() -> ServerConfig {
    ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        seq_len: 64,
        fault: FaultSetting::Off,
        retry_backoff: Duration::from_micros(200),
        drain_timeout: Duration::from_secs(5),
        ..ServerConfig::default()
    }
}

// ---------------------------------------------------------------------------
// crash safety: kill the writer at every fsync/rename crossing
// ---------------------------------------------------------------------------

#[test]
fn torn_write_at_every_io_crossing_leaves_registry_clean() {
    let _g = io_lock();
    let root = tmp_root("torn");
    let m = model(21);

    // clean run: installs v1 and counts the gate crossings of one add
    arm_io_fail(None);
    let reg = Registry::open(&root).unwrap();
    reg.add("var", &m, &spec()).unwrap();
    let n = io_crossings();
    assert!(n >= 6, "expected at least the six named registry gates, saw {n}");

    let mut committed = 1u64;
    for kill in 0..n {
        // "crash" the writer exactly at crossing `kill`
        arm_io_fail(Some(kill));
        let reg = Registry::open(&root).unwrap();
        let err = reg.add("var", &m, &spec()).unwrap_err();
        assert!(
            err.downcast_ref::<InjectedIoFault>().is_some(),
            "kill point {kill} must surface the injected fault, got: {err:#}"
        );
        arm_io_fail(None);

        // recovery: reopen sweeps any staging leftovers to quarantine...
        let reg = Registry::open(&root).unwrap();
        let staged = std::fs::read_dir(root.join(".tmp")).unwrap().count();
        assert_eq!(staged, 0, "kill point {kill} left files in .tmp after reopen");
        // ...every published entry verifies clean...
        for e in reg.verify().unwrap() {
            assert!(
                e.problem.is_none(),
                "kill point {kill} left corrupt entry {}: {:?}",
                e.label,
                e.problem
            );
        }
        // ...and the variant is loadable: prior version intact, or the kill
        // landed after the atomic rename and the new version is complete
        let (_, meta) = reg.load_latest_good("var").unwrap();
        assert!(
            meta.version == committed || meta.version == committed + 1,
            "kill point {kill}: latest good v{} but last commit was v{committed}",
            meta.version
        );
        committed = meta.version;
    }
    std::fs::remove_dir_all(&root).ok();
}

// ---------------------------------------------------------------------------
// integrity: bit-flip detection, quarantine, fallback
// ---------------------------------------------------------------------------

#[test]
fn corrupt_blob_quarantines_and_falls_back_to_last_good() {
    let _g = io_lock();
    arm_io_fail(None);
    let root = tmp_root("flip");
    let reg = Registry::open(&root).unwrap();
    let m1 = model(31);
    reg.add("var", &m1, &spec()).unwrap();
    reg.add("var", &model(32), &spec()).unwrap();

    // flip one byte deep inside v2's stored weights
    let wpath = root.join("var").join("v2").join("weights.npz");
    let mut bytes = std::fs::read(&wpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&wpath, &bytes).unwrap();

    // a pinned load reports typed corruption and quarantines the entry...
    let err = reg.load("var", 2).unwrap_err();
    assert!(
        matches!(err.downcast_ref::<RegistryError>(), Some(RegistryError::Corrupt { .. })),
        "want Corrupt, got {err:#}"
    );
    assert!(!root.join("var").join("v2").exists(), "corrupt entry must leave the store");
    let quarantined = std::fs::read_dir(root.join(".quarantine")).unwrap().count();
    assert!(quarantined >= 1, "corrupt entry must be preserved, not deleted");

    // ...and latest-good falls back to v1 with the original bytes
    let (back, meta) = reg.load_latest_good("var").unwrap();
    assert_eq!(meta.version, 1);
    assert_eq!(back.tok_emb.data(), m1.tok_emb.data());
    std::fs::remove_dir_all(&root).ok();
}

// ---------------------------------------------------------------------------
// hot-swap atomicity under live traffic
// ---------------------------------------------------------------------------

#[test]
fn hot_swap_under_load_drops_nothing() {
    let server = ScoringServer::start(model(41), base_cfg(), || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let stop = Arc::new(AtomicBool::new(false));

    // three clients hammer the server for the whole swap window; every
    // request must succeed — in-flight batches finish on the old weights,
    // later ones run on the new ones, nothing is dropped in between
    let joins: Vec<_> = (0..3)
        .map(|c| {
            let hc = h.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut done = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let (p, comp) =
                        if c % 2 == 0 { ("c:abcd|", "abcd.") } else { ("r:abc|", "cba.") };
                    let s = hc.score(p, comp).expect("no request may fail across the swap");
                    assert!(s.is_finite());
                    done += 1;
                }
                done
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(30));
    server.admin().swap_in(model(42), "regchaos@v2").unwrap();
    std::thread::sleep(Duration::from_millis(30));
    stop.store(true, Ordering::Relaxed);

    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert!(total > 0, "load generator produced no traffic");
    assert_eq!(server.status().variant(), "regchaos@v2");
    let m = server.shutdown();
    assert_eq!(m.errors, 0, "zero failed requests across the swap");
    assert_eq!(m.swaps, 1);
    assert_eq!(m.swap_rollbacks, 0);
}

#[test]
fn failed_swap_rolls_back_and_serving_continues() {
    let server = ScoringServer::start(model(51), base_cfg(), || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    assert!(h.score("c:abcd|", "abcd.").unwrap().is_finite());

    // NaN embeddings score non-finite: the smoke probe must reject them
    let mut bad = model(52);
    let d = bad.cfg.d_model;
    let v = bad.tok_emb.shape()[0];
    bad.tok_emb = Tensor::from_vec(&[v, d], vec![f32::NAN; v * d]).unwrap();
    bad.touch();
    let err = server.admin().swap_in(bad, "regchaos@bad").unwrap_err();
    assert!(format!("{err:#}").contains("rolled back"), "{err:#}");

    // incumbent untouched: label unchanged, serving keeps working
    assert_eq!(server.status().variant(), "regchaos@local");
    assert!(h.score("c:abcd|", "abcd.").unwrap().is_finite());
    let m = server.shutdown();
    assert_eq!(m.swaps, 0);
    assert_eq!(m.swap_rollbacks, 1);
    assert_eq!(m.errors, 0);
}

// ---------------------------------------------------------------------------
// the full admin flow over HTTP (healthz JSON shape pinned here)
// ---------------------------------------------------------------------------

fn http_req(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let code = buf.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap_or(0);
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (code, body)
}

fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    http_req(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
}

fn http_post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    http_req(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

#[test]
fn healthz_json_and_admin_flow_end_to_end() {
    let _g = io_lock();
    arm_io_fail(None);
    let root = tmp_root("e2e");
    let reg = Registry::open(&root).unwrap();
    reg.add("served", &model(61), &spec()).unwrap();
    reg.add("served", &model(62), &spec()).unwrap();
    let reg = Arc::new(reg);
    let cfg_path = root.join("tuning.json");
    std::fs::write(&cfg_path, r#"{"queue_cap": 8, "deadline_ms": 200}"#).unwrap();

    let server = ScoringServer::start(model(61), base_cfg(), || Ok(NativeEngine)).unwrap();
    let admin = AdminState {
        admin: server.admin(),
        registry: Some(reg.clone()),
        config_file: Some(cfg_path.clone()),
    };
    let mut http =
        HttpServer::bind_with_admin("127.0.0.1:0", server.handle(), server.status(), admin)
            .unwrap();
    let addr = http.addr();

    // the /healthz document shape (operators and probes depend on these keys)
    let (code, body) = http_get(addr, "/healthz");
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(j.get("variant").unwrap().as_str().unwrap(), "regchaos@local");
    assert_eq!(j.get("queue_depth").unwrap().as_usize().unwrap(), 0);
    assert_eq!(j.get("restarts_used").unwrap().as_usize().unwrap(), 0);
    assert!(j.get("restart_budget").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(j.get("last_reload").unwrap().as_str().unwrap(), "never");
    assert!(j.opt("degraded_reason").is_none(), "healthy server reports no reason");

    // swap to the latest good registry version, then pin an older one
    let (code, body) = http_post(addr, "/admin/swap", r#"{"name": "served"}"#);
    assert_eq!(code, 200, "{body}");
    let (_, body) = http_get(addr, "/healthz");
    assert_eq!(
        Json::parse(&body).unwrap().get("variant").unwrap().as_str().unwrap(),
        "served@v2"
    );
    let (code, body) = http_post(addr, "/admin/swap", r#"{"name": "served", "version": 1}"#);
    assert_eq!(code, 200, "{body}");
    let (_, body) = http_get(addr, "/healthz");
    assert_eq!(
        Json::parse(&body).unwrap().get("variant").unwrap().as_str().unwrap(),
        "served@v1"
    );
    // unknown variants are typed 404s and change nothing
    let (code, _) = http_post(addr, "/admin/swap", r#"{"name": "ghost"}"#);
    assert_eq!(code, 404);

    // config reload: validate-then-commit, rejection visible on /healthz
    let (code, body) = http_post(addr, "/admin/reload", "");
    assert_eq!(code, 200, "{body}");
    let (_, body) = http_get(addr, "/healthz");
    assert_eq!(
        Json::parse(&body).unwrap().get("last_reload").unwrap().as_str().unwrap(),
        "ok"
    );
    std::fs::write(&cfg_path, r#"{"queue_cap": 0}"#).unwrap();
    let (code, _) = http_post(addr, "/admin/reload", "");
    assert_eq!(code, 422);
    let (_, body) = http_get(addr, "/healthz");
    assert!(
        Json::parse(&body)
            .unwrap()
            .get("last_reload")
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("rejected:"),
        "{body}"
    );

    // scoring worked through the whole admin session
    let (code, body) =
        http_post(addr, "/score", r#"{"prompt": "c:abcd|", "completion": "abcd."}"#);
    assert_eq!(code, 200, "{body}");
    assert!(Json::parse(&body).unwrap().get("score").unwrap().as_f64().unwrap().is_finite());

    http.stop();
    let m = server.shutdown();
    assert_eq!(m.swaps, 2);
    assert_eq!(m.swap_rollbacks, 0);
    assert_eq!(m.reloads, 1);
    assert_eq!(m.reload_failures, 1);
    assert_eq!(m.errors, 0);
    std::fs::remove_dir_all(&root).ok();
}

// ---------------------------------------------------------------------------
// seeded chaos: the ci.sh MERGEMOE_FAULT sweep entry point
// ---------------------------------------------------------------------------

#[test]
fn env_fault_chaos_with_registry_and_swap_survives() {
    let _g = io_lock();
    let spec_str = std::env::var("MERGEMOE_FAULT")
        .ok()
        .filter(|s| !s.trim().is_empty())
        .unwrap_or_else(|| "seed:11,transient:0.25,slow:0.05,slow-ms:2,io-fail:9".into());
    let plan = Arc::new(FaultPlan::parse(&spec_str).unwrap());

    // registry writes under the plan's IO fail-point (if it has one): each
    // add either fully commits or fails typed, and recovery is always clean
    plan.arm_io();
    let root = tmp_root("envchaos");
    {
        let reg = Registry::open(&root).unwrap();
        for seed in 0..3u64 {
            if let Err(e) = reg.add("chaos", &model(70 + seed), &spec()) {
                // only the injected fault may interrupt a write
                assert!(
                    e.downcast_ref::<InjectedIoFault>().is_some(),
                    "unexpected add failure: {e:#}"
                );
            }
        }
    }
    arm_io_fail(None);
    let reg = Registry::open(&root).unwrap();
    for e in reg.verify().unwrap() {
        assert!(e.problem.is_none(), "chaos writes left corruption: {}: {:?}", e.label, e.problem);
    }

    // serving chaos with a mid-run hot-swap from whatever committed
    let cfg = ServerConfig {
        fault: FaultSetting::Plan(plan.clone()),
        restart_budget: 64,
        ..base_cfg()
    };
    let server = ScoringServer::start(model(71), cfg, || Ok(NativeEngine)).unwrap();
    let h = server.handle();
    let n_clients = 3;
    let per = 8;
    let joins: Vec<_> = (0..n_clients)
        .map(|c| {
            let hc = h.clone();
            std::thread::spawn(move || {
                let mut replied = 0usize;
                for i in 0..per {
                    let (p, comp) =
                        if (c + i) % 2 == 0 { ("c:abcd|", "abcd.") } else { ("r:abc|", "cba.") };
                    // liveness: every request gets a *typed* reply, never a
                    // hang — success or failure both count
                    match hc.score(p, comp) {
                        Ok(s) => assert!(s.is_finite()),
                        Err(e) => {
                            let _ = e.to_string();
                        }
                    }
                    replied += 1;
                }
                replied
            })
        })
        .collect();
    if let Ok((m, meta)) = reg.load_latest_good("chaos") {
        // the swap may be rejected (e.g. mid-degrade probe trouble) but must
        // never wedge the serving loop
        let _ = server.admin().swap_in(m, &meta.label());
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, n_clients * per, "every request must get a reply");
    let m = server.shutdown();
    assert_eq!(
        m.requests + m.shed,
        (n_clients * per) as u64,
        "admitted + shed must account for every submission"
    );
    std::fs::remove_dir_all(&root).ok();
}
