//! Randomized property tests over the paper's mathematical claims
//! (proptest is unavailable offline; these use the crate's deterministic
//! RNG and explicit case sweeps — every failure reproduces from the seed).

use mergemoe::merge::plan::MergePlan;
use mergemoe::merge::{self, Algorithm, NativeGram};
use mergemoe::model::native::moe_forward;
use mergemoe::model::testprops::tiny_moe;
use mergemoe::model::workspace::Workspace;
use mergemoe::tensor::{ops, Tensor};
use mergemoe::util::rng::Rng;

/// Theorem 1's objective:  Σ_i f_i (u_i − e_i)ᵀ W (u_i − e_i)
/// with W = Y₀ᵀY₀ and u_i = B a_i (the column of BA for expert i).
fn theorem1_objective(y0: &Tensor, plan: &MergePlan, freqs: &[f64]) -> f64 {
    let n = plan.n;
    let w = ops::matmul_at(y0, y0).unwrap(); // (n, n) — Y0 is (k, n)
    let ba = plan.matrix_ba();
    let mut total = 0.0;
    for i in 0..n {
        // u_i − e_i
        let mut v = vec![0.0f64; n];
        for j in 0..n {
            v[j] = ba.at2(j, i) as f64;
        }
        v[i] -= 1.0;
        // quadratic form
        let mut q = 0.0;
        for a in 0..n {
            if v[a] == 0.0 {
                continue;
            }
            for b in 0..n {
                q += v[a] * w.at2(a, b) as f64 * v[b];
            }
        }
        total += freqs[i] * q;
    }
    total
}

fn random_plan_with_weights(n: usize, m: usize, weights: &[f64], rng: &mut Rng) -> MergePlan {
    let mut assign: Vec<usize> = (0..m).collect();
    assign.extend((m..n).map(|_| rng.below(m as u64) as usize));
    rng.shuffle(&mut assign);
    let mut clusters = vec![Vec::new(); m];
    for (j, &c) in assign.iter().enumerate() {
        clusters[c].push(j);
    }
    let mut w = vec![0.0; n];
    for members in &clusters {
        let total: f64 = members.iter().map(|&j| weights[j]).sum();
        for &j in members {
            w[j] = weights[j] / total;
        }
    }
    MergePlan { n, m, clusters, assign, weights: w }
}

/// `a (m,k) @ b (k,n)` by the textbook triple loop in f64 — the reference
/// every GEMM variant is fuzzed against, independent of kernel family,
/// blocking, packing and epilogue fusion.
fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f64;
            for kk in 0..k {
                s += a.at2(i, kk) as f64 * b.at2(kk, j) as f64;
            }
            *out.at2_mut(i, j) = s as f32;
        }
    }
    out
}

#[test]
fn gemm_variants_match_naive_triple_loop() {
    // All three GEMM forms against the naive reference over random ragged
    // shapes, including the degenerate edges (k=0, 1×N, N×1) and a shape
    // past the AVX2 pack threshold — run under whatever kernel this host
    // dispatches to, so the property covers scalar, AVX2 (direct + packed)
    // and NEON wherever the suite runs.
    let mut rng = Rng::new(0x6E6E);
    let mut cases: Vec<(usize, usize, usize)> =
        vec![(1, 0, 6), (4, 0, 1), (1, 57, 1), (1, 3, 80), (80, 3, 1), (1, 1, 1), (24, 310, 220)];
    for _ in 0..18 {
        cases.push((
            rng.range(1, 60) as usize,
            rng.range(1, 100) as usize,
            rng.range(1, 60) as usize,
        ));
    }
    for (ci, &(m, k, n)) in cases.iter().enumerate() {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let want = naive_matmul(&a, &b);

        let nn = ops::matmul(&a, &b).unwrap();
        assert!(
            nn.rel_err(&want) < 1e-4,
            "case {ci} nn m={m} k={k} n={n}: rel err {}",
            nn.rel_err(&want)
        );

        // a @ btᵀ with bt = bᵀ must equal a @ b
        let bt = ops::transpose(&b).unwrap();
        let nt = ops::matmul_bt(&a, &bt).unwrap();
        assert!(
            nt.rel_err(&want) < 1e-4,
            "case {ci} nt m={m} k={k} n={n}: rel err {}",
            nt.rel_err(&want)
        );

        // atᵀ @ b with at = aᵀ must equal a @ b (zero-skip path)
        let at = ops::transpose(&a).unwrap();
        let tn = ops::matmul_at(&at, &b).unwrap();
        assert!(
            tn.rel_err(&want) < 1e-4,
            "case {ci} tn m={m} k={k} n={n}: rel err {}",
            tn.rel_err(&want)
        );

        if k == 0 {
            // empty inner dimension: exactly zero everywhere, every variant
            for (which, t) in [("nn", &nn), ("nt", &nt), ("tn", &tn)] {
                assert!(
                    t.data().iter().all(|&v| v == 0.0),
                    "case {ci} {which}: k=0 must produce exact zeros"
                );
            }
        }
    }
}

#[test]
fn theorem1_frequency_weights_minimize_objective() {
    // For 40 random instances: frequency weights never lose to 20 random
    // perturbed weightings of the same clustering.
    let mut rng = Rng::new(0x7EE7_0001);
    for case in 0..40 {
        let n = rng.range(3, 10) as usize;
        let m = rng.range(1, n as i64 - 1).max(1) as usize;
        let k = rng.range(2, 8) as usize;
        let y0 = Tensor::randn(&[k, n], 1.0, &mut rng);
        let freqs: Vec<f64> = (0..n).map(|_| rng.f64() + 0.05).collect();
        let plan = random_plan_with_weights(n, m, &freqs, &mut rng);
        let opt = theorem1_objective(&y0, &plan, &freqs);
        for _ in 0..20 {
            let mut perturbed = plan.clone();
            // random reweighting within each cluster (still summing to 1)
            for members in &perturbed.clusters.clone() {
                let raw: Vec<f64> = members.iter().map(|_| rng.f64() + 0.01).collect();
                let s: f64 = raw.iter().sum();
                for (&j, w) in members.iter().zip(raw) {
                    perturbed.weights[j] = w / s;
                }
            }
            let other = theorem1_objective(&y0, &perturbed, &freqs);
            assert!(
                opt <= other + 1e-9,
                "case {case}: frequency weights {opt} lost to perturbation {other}"
            );
        }
    }
}

#[test]
fn merged_layer_preserves_routing_mass() {
    // For any plan and any algorithm, the total routing mass dispatched to
    // real experts equals the original top-K mass (A has one 1 per column).
    let mut rng = Rng::new(0xA11CE);
    for case in 0..15 {
        let n = rng.range(4, 10) as usize;
        let m = rng.range(2, n as i64 - 1) as usize;
        let moe = tiny_moe(n, 2, case);
        let freqs: Vec<f64> = (0..n).map(|_| rng.f64() + 0.1).collect();
        let plan = random_plan_with_weights(n, m, &freqs, &mut rng);
        let x = Tensor::randn(&[20, 16], 1.0, &mut rng);
        for alg in [Algorithm::Average, Algorithm::MSmoe, Algorithm::MergeMoe] {
            let merged =
                merge::merge_layer(alg, &moe, &plan, Some(&x), &mut NativeGram, 1e-6,
                                   &mut Workspace::new())
                    .unwrap();
            let (_, _, mass_merged) = moe_forward(&merged, &x).unwrap();
            let (_, _, mass_orig) = moe_forward(&moe, &x).unwrap();
            let total_merged: f64 = mass_merged.iter().sum();
            let total_orig: f64 = mass_orig.iter().sum();
            assert!(
                (total_merged - total_orig).abs() < 1e-3,
                "case {case} {alg:?}: mass {total_merged} vs {total_orig}"
            );
        }
    }
}

#[test]
fn mergemoe_never_worse_than_msmoe_against_merge_target() {
    // Least-squares optimality, fuzzed over layer shapes and plans: on the
    // calibration batch, each MergeMoE merged expert approximates the
    // output-merge target Ŷ = Σ_j w_j E_j(X̂) at least as well as M-SMoE's
    // fixed-T1 expert (Eq. 5-6's guarantee — it is stated per cluster
    // against Ŷ, not on the routing-weighted layer output).
    use mergemoe::model::native::expert_forward;
    let mut rng = Rng::new(0xBEEF);
    for case in 0..10 {
        let n = rng.range(4, 9) as usize;
        let m = rng.range(2, n as i64 - 1) as usize;
        let moe = tiny_moe(n, 2, 100 + case);
        let freqs: Vec<f64> = (0..n).map(|_| rng.f64() + 0.1).collect();
        let plan = random_plan_with_weights(n, m, &freqs, &mut rng);
        let x = Tensor::randn(&[160, 16], 1.0, &mut rng);
        let mm = merge::merge_layer(Algorithm::MergeMoe, &moe, &plan, Some(&x),
                                    &mut NativeGram, 1e-10, &mut Workspace::new())
            .unwrap();
        let ms = merge::merge_layer(Algorithm::MSmoe, &moe, &plan, Some(&x),
                                    &mut NativeGram, 1e-10, &mut Workspace::new())
            .unwrap();
        for (ci, members) in plan.clusters.iter().enumerate() {
            let mut target = Tensor::zeros(&[160, 16]);
            for &j in members {
                let yj = expert_forward(&moe.experts[j], &x).unwrap();
                target.axpy(plan.weights[j] as f32, &yj).unwrap();
            }
            let e_mm = expert_forward(&mm.experts[ci], &x).unwrap()
                .sub(&target).unwrap().frob_norm();
            let e_ms = expert_forward(&ms.experts[ci], &x).unwrap()
                .sub(&target).unwrap().frob_norm();
            assert!(
                e_mm <= e_ms + 1e-6,
                "case {case} cluster {ci}: mergemoe {e_mm} vs msmoe {e_ms}"
            );
        }
    }
}

#[test]
fn lstsq_solution_is_stationary_under_scaling_of_samples() {
    // Duplicating the calibration batch must not change the solution
    // (normal equations scale linearly on both sides).
    let mut rng = Rng::new(0x5CA1E);
    let a = Tensor::randn(&[8, 64], 1.0, &mut rng);
    let b = Tensor::randn(&[4, 64], 1.0, &mut rng);
    let x1 = mergemoe::linalg::lstsq_rows(&a, &b, 1e-9).unwrap();
    // duplicate columns
    let dup = |t: &Tensor| {
        let (r, c) = (t.shape()[0], t.shape()[1]);
        let mut out = Tensor::zeros(&[r, 2 * c]);
        for i in 0..r {
            out.row_mut(i)[..c].copy_from_slice(t.row(i));
            out.row_mut(i)[c..].copy_from_slice(t.row(i));
        }
        out
    };
    let x2 = mergemoe::linalg::lstsq_rows(&dup(&a), &dup(&b), 1e-9).unwrap();
    assert!(x1.rel_err(&x2) < 1e-3);
}
