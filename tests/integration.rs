//! Integration tests across the whole stack: real artifacts + trained
//! weights, cross-engine numerics, end-to-end compression, serving.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! vacuously, with a note) when artifacts are absent so `cargo test` works
//! on a fresh checkout.

use std::path::PathBuf;

use mergemoe::calib;
use mergemoe::config::Manifest;
use mergemoe::coordinator::{compress, CompressSpec, ScoringServer, ServerConfig};
use mergemoe::eval::tasks::Task;
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::merge::{Algorithm, NativeGram};
use mergemoe::model::ModelWeights;
use mergemoe::runtime::{Engine, NativeEngine, PjrtEngine};

fn artifacts() -> Option<PathBuf> {
    let dir = mergemoe::config::artifacts_dir();
    let ok = dir.join("manifest.json").exists()
        && dir.join("weights_beta.npz").exists();
    if !ok {
        eprintln!("[skip] artifacts not built — run `make artifacts`");
        return None;
    }
    Some(dir)
}

fn load(dir: &PathBuf, name: &str) -> (Manifest, ModelWeights) {
    let manifest = Manifest::load(dir).expect("manifest");
    let model = ModelWeights::load(dir, manifest.model(name).unwrap()).expect("weights");
    (manifest, model)
}

#[test]
fn native_and_pjrt_agree_on_trained_model() {
    let Some(dir) = artifacts() else { return };
    let (manifest, model) = load(&dir, "beta");
    let s = manifest.seq_len;
    let tokens = calib::sample_sequences(None, 2, s, 5);
    let native = NativeEngine.logits(&model, &tokens, 2, s).unwrap();
    let mut pjrt = PjrtEngine::new(manifest).unwrap();
    let pj = pjrt.logits(&model, &tokens, 2, s).unwrap();
    let rel = pj.rel_err(&native);
    assert!(rel < 1e-4, "engines disagree: rel err {rel}");
}

#[test]
fn monolith_equals_layered_path() {
    let Some(dir) = artifacts() else { return };
    let (manifest, model) = load(&dir, "beta");
    let s = manifest.seq_len;
    let tokens = calib::sample_sequences(None, 1, s, 6);
    let mut pjrt = PjrtEngine::new(manifest).unwrap();
    let layered = pjrt.logits_bucketed(&model, &tokens, 1, s, false).unwrap();
    let mono = pjrt.logits_bucketed(&model, &tokens, 1, s, true).unwrap();
    assert!(mono.rel_err(&layered) < 1e-4);
}

#[test]
fn bucket_padding_does_not_change_logits() {
    let Some(dir) = artifacts() else { return };
    let (manifest, model) = load(&dir, "beta");
    let s = manifest.seq_len;
    let tokens = calib::sample_sequences(None, 3, s, 7);
    let mut pjrt = PjrtEngine::new(manifest).unwrap();
    // b=3 pads to bucket 8; compare against running the identical 3
    // sequences as the first rows of an explicit bucket-8 batch
    let got = pjrt.logits(&model, &tokens, 3, s).unwrap();
    let mut padded = tokens.clone();
    padded.resize(8 * s, 0);
    let full = pjrt.logits(&model, &padded, 8, s).unwrap();
    let want = full.rows_slice(0, 3 * s);
    assert!(got.rel_err(&want) < 1e-5);
}

#[test]
fn compressed_model_runs_on_pjrt_and_matches_native() {
    let Some(dir) = artifacts() else { return };
    let (manifest, model) = load(&dir, "beta");
    let mut spec = CompressSpec::new(vec![2, 3], 6, Algorithm::MergeMoe);
    spec.n_calib_seqs = 16;
    let (merged, rep) = compress(&model, &spec, &mut NativeGram).unwrap();
    assert!(rep.params_after < rep.params_before);
    let s = manifest.seq_len;
    let tokens = calib::sample_sequences(None, 2, s, 8);
    let native = NativeEngine.logits(&merged, &tokens, 2, s).unwrap();
    let mut pjrt = PjrtEngine::new(manifest).unwrap();
    let pj = pjrt.logits(&merged, &tokens, 2, s).unwrap();
    assert!(pj.rel_err(&native) < 1e-4);
}

#[test]
fn pjrt_gram_matches_native_gram() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let mut engine = PjrtEngine::new(manifest).unwrap();
    use mergemoe::merge::GramBackend;
    use mergemoe::tensor::Tensor;
    use mergemoe::util::rng::Rng;
    let mut rng = Rng::new(9);
    // non-bucket column count exercises padding; > max bucket exercises split
    for s_cols in [100usize, 256, 3000] {
        let p = Tensor::randn(&[64, s_cols], 1.0, &mut rng);
        let y = Tensor::randn(&[64, s_cols], 1.0, &mut rng);
        let (pp_n, yp_n) = NativeGram.gram(&p, &y).unwrap();
        let mut pg = mergemoe::runtime::pjrt::PjrtGram {
            engine: &mut engine,
            model: "beta".to_string(),
        };
        let (pp_p, yp_p) = pg.gram(&p, &y).unwrap();
        assert!(pp_p.rel_err(&pp_n) < 1e-4, "cols={s_cols}");
        assert!(yp_p.rel_err(&yp_n) < 1e-4, "cols={s_cols}");
    }
}

#[test]
fn oracle_beats_or_ties_mergemoe_on_task_error() {
    let Some(dir) = artifacts() else { return };
    let (_, model) = load(&dir, "beta");
    let mk = |alg| {
        let mut spec = CompressSpec::new(vec![3], 6, alg);
        spec.n_calib_seqs = 24;
        let (_, rep) = compress(&model, &spec, &mut NativeGram).unwrap();
        rep.layers[0].output_rel_err
    };
    let e_oracle = mk(Algorithm::Oracle);
    let e_mm = mk(Algorithm::MergeMoe);
    let e_ms = mk(Algorithm::MSmoe);
    assert!(e_oracle <= e_mm + 1e-9, "oracle {e_oracle} vs mergemoe {e_mm}");
    assert!(e_mm <= e_ms + 1e-9, "mergemoe {e_mm} vs msmoe {e_ms}");
}

#[test]
fn full_model_beats_chance_on_every_task() {
    let Some(dir) = artifacts() else { return };
    let mut ctx = Ctx::new(dir, EngineSel::Native).unwrap();
    ctx.items = 40;
    let model = ctx.load_model("beta").unwrap();
    let mut engine = NativeEngine;
    // markov is the easiest task — a trained model must be far above chance
    let accs = ctx
        .eval_suite(&mut engine, &model, &[Task::Markov])
        .unwrap();
    assert!(
        accs["markov"].percent() > 70.0,
        "trained model near chance on markov: {}",
        accs["markov"].percent()
    );
}

#[test]
fn server_on_pjrt_answers_concurrent_clients() {
    let Some(dir) = artifacts() else { return };
    let (_, model) = load(&dir, "beta");
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait: std::time::Duration::from_millis(5),
        seq_len: 64,
        ..ServerConfig::default()
    };
    let dir2 = dir.clone();
    let server = ScoringServer::start(model, cfg, move || {
        PjrtEngine::new(Manifest::load(&dir2)?)
    })
    .expect("server start");
    let h = server.handle();
    let mut joins = Vec::new();
    for i in 0..6 {
        let h = h.clone();
        joins.push(std::thread::spawn(move || {
            h.score("a:12+34=", if i % 2 == 0 { "46." } else { "99." }).unwrap()
        }));
    }
    let scores: Vec<f64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert!(scores.iter().all(|s| s.is_finite()));
    drop(h);
    let m = server.shutdown();
    assert_eq!(m.requests, 6);
}
