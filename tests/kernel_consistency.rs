//! SIMD-vs-scalar kernel agreement (the tolerance half of the kernel
//! layer's determinism contract; the bit-identity half across thread
//! counts lives in `tests/par_consistency.rs`):
//!
//! * every GEMM form — nn (direct and packed), nt, tn, fused SwiGLU,
//!   scale-and-accumulate, scatter, SYRK — agrees between the scalar and
//!   detected SIMD family to tight relative tolerance across ragged and
//!   degenerate shapes (k=0, 1×N, N×1);
//! * the dispatch knob resolves, can be forced for tests/benches, and an
//!   unsupported forced kind degrades to scalar;
//! * a forced kernel is *self-consistent*: repeated runs (warm per-thread
//!   pack buffers included) are bit-identical;
//! * the scorer pipeline agrees across kernels (accuracy-critical scores
//!   move by no more than numeric noise), keeping the eval-sweep
//!   method-ordering gate meaningful on every host;
//! * the KV-cache decode step agrees across kernels (its decode-vs-prefill
//!   bit-identity *within* a kernel lives in `tests/decode_consistency.rs`).
//!
//! Every test takes the same knob mutex: the kernel choice is process-wide
//! state, exactly like the thread knob in the sibling suites.

use std::sync::Mutex;

use mergemoe::kernel::{self, Kind};
use mergemoe::model::native::expert_forward;
use mergemoe::model::testprops::tiny_moe;
use mergemoe::tensor::{ops, Tensor};
use mergemoe::util::rng::Rng;

/// Serializes tests that toggle the process-wide kernel knob.
static KERNEL_KNOB: Mutex<()> = Mutex::new(());

/// Run `f` under a forced kernel, restoring the entry kernel afterwards.
fn with_kernel<R>(k: Kind, f: impl FnOnce() -> R) -> R {
    let prev = kernel::active();
    kernel::set_kernel(k);
    let out = f();
    kernel::set_kernel(prev);
    out
}

/// The SIMD family this host detects, if any (`set_kernel` would degrade
/// an unavailable kind to scalar, so probe by forcing-and-reading).
fn detected_simd() -> Option<Kind> {
    let prev = kernel::active();
    let mut found = None;
    for k in [Kind::Avx2, Kind::Neon] {
        kernel::set_kernel(k);
        if kernel::active() == k {
            found = Some(k);
            break;
        }
    }
    kernel::set_kernel(prev);
    found
}

fn rel_err(a: &Tensor, b: &Tensor) -> f64 {
    a.rel_err(b)
}

#[test]
fn dispatch_knob_forces_and_degrades() {
    let _guard = KERNEL_KNOB.lock().unwrap();
    let entry = kernel::active();
    kernel::set_kernel(Kind::Scalar);
    assert_eq!(kernel::active(), Kind::Scalar);
    assert_eq!(kernel::name(), "scalar");
    // forcing the kind the other architecture owns degrades to scalar
    #[cfg(target_arch = "x86_64")]
    {
        kernel::set_kernel(Kind::Neon);
        assert_eq!(kernel::active(), Kind::Scalar, "neon must degrade on x86_64");
    }
    #[cfg(target_arch = "aarch64")]
    {
        kernel::set_kernel(Kind::Avx2);
        assert_eq!(kernel::active(), Kind::Scalar, "avx2 must degrade on aarch64");
    }
    kernel::set_kernel(entry);
    assert_eq!(kernel::active(), entry);
}

#[test]
fn gemm_family_simd_matches_scalar_on_ragged_shapes() {
    let _guard = KERNEL_KNOB.lock().unwrap();
    let Some(simd) = detected_simd() else {
        return; // scalar-only host: nothing to compare
    };
    let mut rng = Rng::new(0x51D0);
    // ragged sweep plus degenerate edges: k=0, 1×N, N×1, single element
    let mut cases: Vec<(usize, usize, usize)> = vec![
        (1, 0, 5),
        (1, 7, 1),
        (5, 0, 1),
        (1, 1, 1),
        (1, 300, 1),
        (64, 1, 64),
    ];
    for _ in 0..14 {
        cases.push((
            rng.range(1, 70) as usize,
            rng.range(1, 90) as usize,
            rng.range(1, 70) as usize,
        ));
    }
    // and one past the AVX2 pack threshold (k·n ≥ 64K, m ≥ 16)
    cases.push((24, 310, 220));
    for &(m, k, n) in &cases {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
        let at = Tensor::randn(&[k, m], 1.0, &mut rng);
        let sc = with_kernel(Kind::Scalar, || {
            (
                ops::matmul(&a, &b).unwrap(),
                ops::matmul_bt(&a, &bt).unwrap(),
                ops::matmul_at(&at, &b).unwrap(),
            )
        });
        let si = with_kernel(simd, || {
            (
                ops::matmul(&a, &b).unwrap(),
                ops::matmul_bt(&a, &bt).unwrap(),
                ops::matmul_at(&at, &b).unwrap(),
            )
        });
        for (which, (s, v)) in
            [("nn", (&sc.0, &si.0)), ("nt", (&sc.1, &si.1)), ("tn", (&sc.2, &si.2))]
        {
            let err = rel_err(v, s);
            assert!(err < 1e-4, "{which} m={m} k={k} n={n}: rel err {err}");
        }
        // k = 0 must be exactly zero under every kernel
        if k == 0 {
            assert!(si.0.data().iter().all(|&v| v == 0.0), "m={m} n={n}");
            assert!(si.1.data().iter().all(|&v| v == 0.0), "m={m} n={n}");
        }
    }
}

#[test]
fn fused_epilogues_simd_match_scalar() {
    let _guard = KERNEL_KNOB.lock().unwrap();
    let Some(simd) = detected_simd() else {
        return;
    };
    let mut rng = Rng::new(0x51D1);
    for &(t, d, f) in &[(9usize, 21usize, 13usize), (1, 40, 1), (17, 1, 6), (3, 0, 4)] {
        let x = Tensor::randn(&[t, d], 1.0, &mut rng);
        let wg = Tensor::randn(&[f, d], 1.0, &mut rng);
        let wu = Tensor::randn(&[f, d], 1.0, &mut rng);
        let wd = Tensor::randn(&[d, f], 1.0, &mut rng);
        let run = || {
            let mut h = Tensor::full(&[t, f], f32::NAN);
            ops::swiglu_bt_into(&x, &wg, &wu, &mut h).unwrap();
            let mut acc = Tensor::zeros(&[t, d]);
            ops::matmul_bt_scaled_add_into(&h, &wd, 0.75, &mut acc).unwrap();
            let p = Tensor::randn(&[f.max(1), 33], 1.0, &mut Rng::new(7));
            let gram = ops::syrk_bt(&p).unwrap();
            (h, acc, gram)
        };
        let sc = with_kernel(Kind::Scalar, run);
        let si = with_kernel(simd, run);
        assert!(rel_err(&si.0, &sc.0) < 1e-4, "swiglu t={t} d={d} f={f}");
        assert!(rel_err(&si.1, &sc.1) < 1e-4, "scaled_add t={t} d={d} f={f}");
        assert!(rel_err(&si.2, &sc.2) < 1e-4, "syrk t={t} d={d} f={f}");
        // SYRK symmetry is exact under every kernel
        for i in 0..si.2.shape()[0] {
            for j in 0..i {
                assert_eq!(si.2.at2(i, j), si.2.at2(j, i));
            }
        }
    }
}

#[test]
fn scatter_recombination_simd_matches_scalar() {
    let _guard = KERNEL_KNOB.lock().unwrap();
    let Some(simd) = detected_simd() else {
        return;
    };
    let mut rng = Rng::new(0x51D2);
    let a = Tensor::randn(&[6, 18], 1.0, &mut rng);
    let b = Tensor::randn(&[10, 18], 1.0, &mut rng);
    let scales: Vec<f32> = (0..6).map(|i| 0.25 * (i as f32 + 1.0)).collect();
    let dst: Vec<usize> = vec![0, 2, 3, 7, 8, 11];
    let run = || {
        let mut out = Tensor::zeros(&[12, 10]);
        ops::matmul_bt_scatter_add_into(&a, &b, &scales, &dst, &mut out).unwrap();
        out
    };
    let sc = with_kernel(Kind::Scalar, run);
    let si = with_kernel(simd, run);
    assert!(rel_err(&si, &sc) < 1e-4);
    // untouched rows stay exactly zero under both kernels
    for miss in [1usize, 4, 9] {
        assert!(sc.row(miss).iter().all(|&v| v == 0.0));
        assert!(si.row(miss).iter().all(|&v| v == 0.0));
    }
}

#[test]
fn forced_kernel_is_bit_stable_across_reruns() {
    // Self-consistency: a fixed kernel must reproduce itself bit for bit,
    // including the packed path through a warm per-thread pack buffer.
    let _guard = KERNEL_KNOB.lock().unwrap();
    let entry = kernel::active();
    let mut rng = Rng::new(0x51D3);
    let a = Tensor::randn(&[24, 310], 1.0, &mut rng);
    let b = Tensor::randn(&[310, 220], 1.0, &mut rng);
    let mut kinds = vec![Kind::Scalar];
    kinds.extend(detected_simd());
    for kind in kinds {
        kernel::set_kernel(kind);
        let first = ops::matmul(&a, &b).unwrap();
        for round in 0..3 {
            let again = ops::matmul(&a, &b).unwrap();
            assert_eq!(
                again.data(),
                first.data(),
                "{} round {round} diverged",
                kernel::name()
            );
        }
    }
    kernel::set_kernel(entry);
}

#[test]
fn expert_forward_agrees_across_kernels() {
    // The full fused expert pipeline (SwiGLU + down-projection) through the
    // model layer, scalar vs SIMD.
    let _guard = KERNEL_KNOB.lock().unwrap();
    let Some(simd) = detected_simd() else {
        return;
    };
    let moe = tiny_moe(4, 2, 0x51D4);
    let x = Tensor::randn(&[33, 16], 1.0, &mut Rng::new(0x51D5));
    for ex in &moe.experts {
        let sc = with_kernel(Kind::Scalar, || expert_forward(ex, &x).unwrap());
        let si = with_kernel(simd, || expert_forward(ex, &x).unwrap());
        assert!(rel_err(&si, &sc) < 1e-4);
    }
}

#[test]
fn kv_decode_agrees_across_kernels() {
    // The KV-cache decode step drives the same GEMM family as prefill on
    // one-row shapes; scalar vs the detected SIMD family must agree to the
    // same tolerance as the rest of the forward pipeline. (Bit-identity of
    // decode vs prefill *within* a kernel lives in
    // `tests/decode_consistency.rs`.)
    use mergemoe::model::testprops::synth_model;
    use mergemoe::model::workspace::{KvScratch, Workspace};
    use mergemoe::runtime::{Engine, NativeEngine};
    let _guard = KERNEL_KNOB.lock().unwrap();
    let Some(simd) = detected_simd() else {
        return;
    };
    let cfg = mergemoe::config::ModelConfig {
        name: "kerneld".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: true,
        n_params: 0,
        merge_targets: vec![2],
    };
    let model = synth_model(&cfg, 0x51D7);
    let prompt: Vec<i32> = (0..12).map(|i| ((i * 9 + 2) % 47) as i32).collect();
    let run = || {
        let mut kv = KvScratch::new();
        let mut ws = Workspace::new();
        let mut out = Tensor::default();
        let mut rows = Vec::new();
        for t in 0..prompt.len() {
            NativeEngine
                .decode_step(&model, &prompt[..=t], &mut kv, &mut ws, &mut out)
                .unwrap();
            rows.extend_from_slice(out.row(0));
        }
        Tensor::from_vec(&[prompt.len(), out.cols()], rows).unwrap()
    };
    let sc = with_kernel(Kind::Scalar, run);
    let si = with_kernel(simd, run);
    let err = rel_err(&si, &sc);
    assert!(err < 1e-4, "decode scalar-vs-simd rel err {err}");
}

#[test]
fn scorer_scores_agree_across_kernels() {
    // Kernel choice must not move the evaluation science: per-option scores
    // shift by at most numeric noise, so the oracle ≥ mergemoe ≥ average
    // ordering gate in tests/eval_consistency.rs is meaningful on every
    // host regardless of which kernel it detects.
    use mergemoe::eval::scorer::score_items_scored;
    use mergemoe::eval::tasks::{gen_items, Task};
    use mergemoe::model::testprops::synth_model;
    use mergemoe::runtime::NativeEngine;
    let _guard = KERNEL_KNOB.lock().unwrap();
    let Some(simd) = detected_simd() else {
        return;
    };
    let cfg = mergemoe::config::ModelConfig {
        name: "kernelc".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: true,
        n_params: 0,
        merge_targets: vec![2],
    };
    let model = synth_model(&cfg, 0x51D6);
    let items = gen_items(Task::Copy, 16, 5);
    let (_, sc) = with_kernel(Kind::Scalar, || {
        score_items_scored(&mut NativeEngine, &model, &items, 64, 8).unwrap()
    });
    let (_, si) = with_kernel(simd, || {
        score_items_scored(&mut NativeEngine, &model, &items, 64, 8).unwrap()
    });
    assert_eq!(sc.len(), si.len());
    for (i, (a, b)) in sc.iter().zip(&si).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * (1.0 + a.abs()),
            "score {i}: scalar {a} vs simd {b}"
        );
    }
}
