//! Deterministic fault-injection tests for the overload-hardened scoring
//! server: every robustness behavior (shedding, deadlines, retry + batch
//! split, panic respawn, graceful drain) is exercised as a reproducible
//! scenario driven by `util::fault::FaultPlan` — scripted plans for
//! surgical single-path tests, seeded plans for whole-workload chaos runs
//! whose outcome sequence is pinned bit-for-bit per seed.
//!
//! These tests use the native engine on a small synthetic model, so they
//! run on a bare checkout (no `make artifacts` needed).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use mergemoe::config::ModelConfig;
use mergemoe::coordinator::{
    FaultSetting, ScoringServer, ServeError, ServerConfig, ServerHandle,
};
use mergemoe::eval::tasks;
use mergemoe::model::testprops::synth_model;
use mergemoe::model::workspace::Workspace;
use mergemoe::model::ModelWeights;
use mergemoe::runtime::{Engine, NativeEngine};
use mergemoe::tensor::Tensor;
use mergemoe::util::fault::{FaultAction, FaultPlan};

/// One fixed model for every scenario, so fault-run scores can be compared
/// against clean-run references.
fn test_model() -> ModelWeights {
    let cfg = ModelConfig {
        name: "faultinj".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: false,
        n_params: 0,
        merge_targets: vec![2],
    };
    synth_model(&cfg, 77)
}

/// Base config for these tests: no env-sourced faults (each test scripts
/// its own), short drain, tiny backoff so retries don't dominate runtime,
/// and a single compute lane so scripted fault schedules hit the one lane
/// they were written for even when `MERGEMOE_WORKERS` is exported (the CI
/// multi-lane sweep does; `env_fault_workload_survives` honors it).
fn base_cfg() -> ServerConfig {
    ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        seq_len: 64,
        fault: FaultSetting::Off,
        retry_backoff: Duration::from_micros(200),
        drain_timeout: Duration::from_secs(5),
        workers: 1,
        ..ServerConfig::default()
    }
}

fn start_clean() -> ScoringServer {
    ScoringServer::start(test_model(), base_cfg(), || Ok(NativeEngine)).unwrap()
}

fn start_with_plan(cfg: ServerConfig, plan: &Arc<FaultPlan>) -> ScoringServer {
    let cfg = ServerConfig { fault: FaultSetting::Plan(plan.clone()), ..cfg };
    ScoringServer::start(test_model(), cfg, || Ok(NativeEngine)).unwrap()
}

/// Wait (bounded) until `pred` holds; panics on timeout so a broken
/// condition fails the test instead of hanging it.
fn wait_for(what: &str, mut pred: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !pred() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Stall the worker: scores on a background thread while the plan holds
/// attempt 0 in a `Slow` action, and waits until the worker has actually
/// begun that attempt (the plan's attempt cursor advancing is the
/// race-free signal). Requests sent afterwards queue up behind the stall.
fn stall_worker(
    h: &ServerHandle,
    plan: &Arc<FaultPlan>,
) -> std::thread::JoinHandle<Result<f64, ServeError>> {
    let hc = h.clone();
    let j = std::thread::spawn(move || hc.score("c:abcd|", "abcd."));
    let p = plan.clone();
    wait_for("worker to begin the stalled attempt", move || p.attempts() >= 1);
    j
}

// ---------------------------------------------------------------------------
// determinism of the schedule itself (the ARCHITECTURE.md ledger row)
// ---------------------------------------------------------------------------

#[test]
fn same_seed_same_fault_schedule() {
    let spec = "seed:2026,transient:0.2,fatal:0.02,panic:0.05,slow:0.1,slow-ms:3";
    let a = FaultPlan::parse(spec).unwrap();
    let b = FaultPlan::parse(spec).unwrap();
    assert_eq!(a.schedule(2048), b.schedule(2048), "same seed must give same schedule");
    // and the schedule is a pure function of the attempt index — consuming
    // it does not perturb later entries
    let c = FaultPlan::parse(spec).unwrap();
    for _ in 0..100 {
        c.next();
    }
    assert_eq!(c.action_at(1000), a.action_at(1000));
}

// ---------------------------------------------------------------------------
// bounded admission: queue-full shedding under a stalled worker
// ---------------------------------------------------------------------------

#[test]
fn full_queue_sheds_with_typed_overloaded() {
    let cfg = ServerConfig { queue_cap: 2, ..base_cfg() };
    let plan =
        Arc::new(FaultPlan::scripted(vec![FaultAction::Slow(Duration::from_millis(600))]));
    let server = start_with_plan(cfg, &plan);
    let h = server.handle();

    let stalled = stall_worker(&h, &plan);
    // fill the bounded queue behind the stalled worker
    let mut queued = Vec::new();
    for _ in 0..2 {
        let hc = h.clone();
        queued.push(std::thread::spawn(move || hc.score("c:abcd|", "abcd.")));
    }
    wait_for("queue to fill", || h.queue_depth() == 2);
    // the queue is full: admission sheds immediately with the typed error
    let r = h.score("c:abcd|", "abcd.");
    assert_eq!(r, Err(ServeError::Overloaded));
    assert!(server.queue_depth() <= 2, "shed request must not occupy a slot");

    // once the stall clears, everything admitted completes fine
    assert!(stalled.join().unwrap().is_ok());
    for j in queued {
        assert!(j.join().unwrap().is_ok());
    }
    let m = server.shutdown();
    assert_eq!(m.shed, 1);
    assert_eq!(m.requests, 3, "shed requests are not admitted requests");
    assert_eq!(m.errors, 0);
}

// ---------------------------------------------------------------------------
// deadlines: expiry fails the request before its forward pass
// ---------------------------------------------------------------------------

/// Engine wrapper that counts forward passes, so the test can prove an
/// expired request never reached compute.
struct CountingEngine {
    n: Arc<AtomicUsize>,
}

impl Engine for CountingEngine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        self.n.fetch_add(1, Ordering::SeqCst);
        NativeEngine.logits(model, tokens, b, s)
    }

    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        self.n.fetch_add(1, Ordering::SeqCst);
        NativeEngine.logits_ws(model, tokens, b, s, ws, out)
    }

    fn name(&self) -> &'static str {
        "counting"
    }
}

#[test]
fn expired_deadline_fails_without_forward_pass() {
    let forwards = Arc::new(AtomicUsize::new(0));
    let f2 = forwards.clone();
    let plan =
        Arc::new(FaultPlan::scripted(vec![FaultAction::Slow(Duration::from_millis(500))]));
    let cfg = ServerConfig { fault: FaultSetting::Plan(plan.clone()), ..base_cfg() };
    let server = ScoringServer::start(test_model(), cfg, move || {
        Ok(CountingEngine { n: f2.clone() })
    })
    .unwrap();
    let h = server.handle();

    // request A (no deadline) stalls the worker for 500ms
    let stalled = stall_worker(&h, &plan);
    // request B carries a 50ms deadline that expires mid-stall
    let hb = h.clone();
    let b = std::thread::spawn(move || {
        hb.score_with_deadline("c:abcd|", "abcd.", Some(Duration::from_millis(50)))
    });
    assert_eq!(b.join().unwrap(), Err(ServeError::DeadlineExceeded));
    assert!(stalled.join().unwrap().is_ok());

    let m = server.shutdown();
    assert_eq!(
        forwards.load(Ordering::SeqCst),
        1,
        "only the stall request may reach the engine — the expired one must not"
    );
    assert_eq!(m.expired, 1);
    assert_eq!(m.errors, 1, "expired requests are counted errors");
    assert_eq!(m.requests, 2, "failed requests still count as requests");
    assert!(m.total_latency.count() >= 2, "failures must record latency too");
}

// ---------------------------------------------------------------------------
// retry layer: transient errors retry; fatal errors fail fast
// ---------------------------------------------------------------------------

#[test]
fn transient_failure_retries_to_bit_identical_success() {
    // clean reference score on the same model
    let clean = start_clean();
    let want = clean.handle().score("c:abcd|", "abcd.").unwrap();
    clean.shutdown();

    // first attempt fails transiently, retry runs clean
    let plan = Arc::new(FaultPlan::scripted(vec![FaultAction::Transient]));
    let server = start_with_plan(base_cfg(), &plan);
    let got = server.handle().score("c:abcd|", "abcd.").unwrap();
    assert_eq!(got.to_bits(), want.to_bits(), "retried result must be bit-identical");
    let m = server.shutdown();
    assert_eq!(m.retried, 1);
    assert_eq!(m.errors, 0);
    assert_eq!(m.batches, 2, "failed attempt + successful retry");
}

#[test]
fn fatal_failure_fails_fast_without_retry() {
    let plan = Arc::new(FaultPlan::scripted(vec![FaultAction::Fatal]));
    let server = start_with_plan(base_cfg(), &plan);
    let r = server.handle().score("c:abcd|", "abcd.");
    assert!(matches!(r, Err(ServeError::Engine(_))), "want Engine error, got {r:?}");
    let m = server.shutdown();
    assert_eq!(m.retried, 0, "fatal errors must not burn retries");
    assert_eq!(m.batches, 1, "exactly one attempt");
    assert_eq!(m.errors, 1);
}

// ---------------------------------------------------------------------------
// batch split: one poison request cannot fail its batchmates
// ---------------------------------------------------------------------------

#[test]
fn poison_request_fails_alone_after_batch_split() {
    let poison_tok = tasks::encode("#")[0];
    let clean_reqs = [("c:abcd|", "abcd."), ("r:abc|", "cba."), ("c:xyxy|", "xyxy.")];
    let poison_req = ("c:a#a#|", "a#a#.");

    // clean reference scores on the same model (each as its own batch)
    let clean = start_clean();
    let want: Vec<f64> = clean_reqs
        .iter()
        .map(|(p, c)| clean.handle().score(p, c).unwrap())
        .collect();
    clean.shutdown();

    // stall the worker so all four requests coalesce into one batch, with
    // the poison token tripping a transient failure on every attempt that
    // contains it
    let plan = Arc::new(
        FaultPlan::scripted(vec![FaultAction::Slow(Duration::from_millis(400))])
            .with_poison(poison_tok),
    );
    // max_wait is generous here: the collector forms batches continuously,
    // so the window must stay open long enough for all four requests to
    // coalesce into one batch behind the stall
    let cfg = ServerConfig {
        max_retries: 2,
        max_wait: Duration::from_millis(200),
        ..base_cfg()
    };
    let server = start_with_plan(cfg, &plan);
    let h = server.handle();
    let stalled = stall_worker(&h, &plan);

    let clean_joins: Vec<_> = clean_reqs
        .iter()
        .map(|&(p, c)| {
            let hc = h.clone();
            std::thread::spawn(move || hc.score(p, c))
        })
        .collect();
    let hp = h.clone();
    let poison_join = std::thread::spawn(move || hp.score(poison_req.0, poison_req.1));
    wait_for("all four to queue into one batch", || h.queue_depth() == 4);
    assert!(stalled.join().unwrap().is_ok());

    // the three clean batchmates succeed — and, because sequences are
    // independent rows of the forward pass, match the single-request
    // reference scores
    for (j, want) in clean_joins.into_iter().zip(&want) {
        let got = j.join().unwrap().expect("clean batchmate must survive the split");
        assert!(
            (got - want).abs() < 1e-9,
            "batchmate score diverged after split: {got} vs {want}"
        );
    }
    // ...and only the poison request fails
    let r = poison_join.join().unwrap();
    assert!(matches!(r, Err(ServeError::Engine(_))), "poison must fail alone, got {r:?}");

    let m = server.shutdown();
    assert!(m.splits >= 2, "batch of 4 must split at least twice, got {}", m.splits);
    assert_eq!(m.errors, 1, "exactly the poison request fails");
    assert_eq!(m.requests, 5, "stall + 3 clean + 1 poison");
}

// ---------------------------------------------------------------------------
// supervision: panic respawn, then degraded past the restart budget
// ---------------------------------------------------------------------------

#[test]
fn worker_panic_respawns_and_next_request_succeeds() {
    let plan = Arc::new(FaultPlan::scripted(vec![FaultAction::Panic]));
    let server = start_with_plan(base_cfg(), &plan);
    let h = server.handle();
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::WorkerPanicked));
    // the respawned worker (fresh engine + workspace) serves the next one
    assert!(h.score("c:abcd|", "abcd.").is_ok());
    assert!(!server.status().degraded());
    let m = server.shutdown();
    assert_eq!(m.restarted, 1);
    assert_eq!(m.errors, 1);
}

#[test]
fn restart_budget_exhaustion_degrades_to_fast_reject() {
    let cfg = ServerConfig { restart_budget: 1, ..base_cfg() };
    let plan = Arc::new(FaultPlan::scripted(vec![FaultAction::Panic, FaultAction::Panic]));
    let server = start_with_plan(cfg, &plan);
    let h = server.handle();
    let status = server.status();
    // panic #1 consumes the budget; panic #2 exhausts it
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::WorkerPanicked));
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::WorkerPanicked));
    wait_for("degraded flag", || status.degraded());
    // now the server fast-rejects without touching the worker
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::Degraded));
    let m = server.shutdown();
    assert_eq!(m.restarted, 1, "only the budgeted respawn happened");
}

// ---------------------------------------------------------------------------
// graceful drain + shutdown-never-hangs
// ---------------------------------------------------------------------------

#[test]
fn drain_completes_admitted_work_and_rejects_new() {
    let plan =
        Arc::new(FaultPlan::scripted(vec![FaultAction::Slow(Duration::from_millis(300))]));
    let server = start_with_plan(base_cfg(), &plan);
    let h = server.handle();

    // stall the worker, then queue two more requests behind the stall
    let stalled = stall_worker(&h, &plan);
    let queued: Vec<_> = (0..2)
        .map(|_| {
            let hc = h.clone();
            std::thread::spawn(move || hc.score("r:abc|", "cba."))
        })
        .collect();
    wait_for("both to queue", || h.queue_depth() == 2);

    // shut down while all three are in flight
    let shutdown = std::thread::spawn(move || server.shutdown());

    // every admitted request completes successfully...
    assert!(stalled.join().unwrap().is_ok());
    for j in queued {
        assert!(j.join().unwrap().is_ok(), "drain must finish admitted work");
    }
    let m = shutdown.join().unwrap();
    assert_eq!(m.requests, 3);
    assert_eq!(m.errors, 0);
    // ...and new work is refused through the still-live handle clone
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::ShuttingDown));
}

#[test]
fn shutdown_does_not_hang_while_handle_clones_live() {
    let server = start_clean();
    let h = server.handle();
    let h2 = h.clone(); // clones stay alive across the whole shutdown
    let (done_tx, done_rx) = channel();
    std::thread::spawn(move || {
        server.shutdown();
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("shutdown hung with live ServerHandle clones");
    assert_eq!(h.score("c:abcd|", "abcd."), Err(ServeError::ShuttingDown));
    drop(h2);
}

// ---------------------------------------------------------------------------
// seeded chaos: whole-workload reproducibility + the ci.sh seed sweep
// ---------------------------------------------------------------------------

/// One serial chaos workload: `n` requests against a seeded fault plan.
/// Returns the exact outcome sequence (score bits or error rendering).
fn chaos_outcomes(fault_seed: u64, n: usize) -> Vec<Result<u64, String>> {
    let plan =
        Arc::new(FaultPlan::parse(&format!("seed:{fault_seed},transient:0.35")).unwrap());
    let cfg = ServerConfig { max_retries: 1, ..base_cfg() };
    let server = start_with_plan(cfg, &plan);
    let h = server.handle();
    let reqs = [("c:abcd|", "abcd."), ("r:abc|", "cba."), ("c:xyxy|", "xyxy.")];
    let out = (0..n)
        .map(|i| {
            let (p, c) = reqs[i % reqs.len()];
            h.score(p, c).map(f64::to_bits).map_err(|e| format!("{e:?}"))
        })
        .collect();
    server.shutdown();
    out
}

#[test]
fn seeded_chaos_run_is_bit_reproducible() {
    // a serial client makes the attempt order deterministic, so the seeded
    // schedule fully determines every outcome — scores AND failures
    let a = chaos_outcomes(1234, 12);
    let b = chaos_outcomes(1234, 12);
    assert_eq!(a, b, "same fault seed must reproduce the exact outcome sequence");
    assert_eq!(a.len(), 12);
    // transient:0.35 with a retry must still let most requests through
    let ok = a.iter().filter(|r| r.is_ok()).count();
    assert!(ok >= 6, "chaos run lost too many requests: {ok}/12");
}

/// The ci.sh seed-sweep entry point: honors `MERGEMOE_FAULT` when set
/// (ci.sh exports a different seed per run), falls back to a fixed chaotic
/// plan otherwise. Asserts liveness — every request gets a reply and the
/// server drains cleanly no matter what the schedule injects.
#[test]
fn env_fault_workload_survives() {
    let spec = std::env::var("MERGEMOE_FAULT")
        .ok()
        .filter(|s| !s.trim().is_empty())
        .unwrap_or_else(|| "seed:7,transient:0.2,panic:0.05,slow:0.05,slow-ms:2".into());
    let plan = Arc::new(FaultPlan::parse(&spec).unwrap());
    // lane count comes from the environment here (MERGEMOE_WORKERS), so
    // the ci.sh sweep exercises the same chaos workload multi-lane
    let cfg = ServerConfig {
        restart_budget: 64,
        workers: ServerConfig::default().workers,
        ..base_cfg()
    };
    let server = start_with_plan(cfg, &plan);
    let h = server.handle();
    let n_clients = 3;
    let per = 8;
    let joins: Vec<_> = (0..n_clients)
        .map(|c| {
            let hc = h.clone();
            std::thread::spawn(move || {
                let mut replied = 0;
                for i in 0..per {
                    let (p, comp) =
                        if (c + i) % 2 == 0 { ("c:abcd|", "abcd.") } else { ("r:abc|", "cba.") };
                    // any *typed* outcome counts as liveness; what must
                    // never happen is a hang or a dropped reply
                    match hc.score(p, comp) {
                        Ok(s) => assert!(s.is_finite()),
                        Err(e) => {
                            let _ = e.to_string();
                        }
                    }
                    replied += 1;
                }
                replied
            })
        })
        .collect();
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, n_clients * per, "every request must get a reply");
    let m = server.shutdown();
    assert_eq!(
        m.requests + m.shed,
        (n_clients * per) as u64,
        "admitted + shed must account for every submission"
    );
}
