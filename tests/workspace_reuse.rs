//! Workspace-arena correctness: reusing one `Workspace` across calls (and
//! across changing batch shapes) must be numerically invisible — every
//! `*_ws` path reproduces its fresh-allocation wrapper bit for bit.

use mergemoe::config::ModelConfig;
use mergemoe::model::native::{
    forward, forward_ws, moe_forward, moe_forward_ws, target_logprobs, target_logprobs_into,
};
use mergemoe::model::testprops::{synth_model, tiny_moe};
use mergemoe::model::workspace::Workspace;
use mergemoe::tensor::Tensor;
use mergemoe::util::rng::Rng;

fn test_model(shared: bool, seed: u64) -> mergemoe::model::ModelWeights {
    let cfg = ModelConfig {
        name: "wsreuse".into(),
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 8,
        n_experts: 4,
        top_k: 2,
        shared_expert: shared,
        n_params: 0,
        merge_targets: vec![2],
    };
    synth_model(&cfg, seed)
}

#[test]
fn repeated_forward_through_one_workspace_is_bit_identical() {
    let model = test_model(true, 0xA11CE);
    let mut ws = Workspace::new();
    let mut logits = Tensor::default();
    // alternating batch shapes stress buffer resizing in both directions
    for &(b, reps) in &[(1usize, 3usize), (4, 3), (1, 2), (3, 2)] {
        for r in 0..reps {
            let tokens: Vec<i32> =
                (0..b * 64).map(|i| ((i * 7 + r + b) % 47) as i32).collect();
            forward_ws(&model, &tokens, b, 64, None, &mut ws, &mut logits).unwrap();
            let fresh = forward(&model, &tokens, b, 64, None).unwrap();
            assert_eq!(logits.shape(), fresh.shape(), "b={b} rep={r}");
            assert_eq!(logits.data(), fresh.data(), "b={b} rep={r}");
        }
    }
}

#[test]
fn capture_through_reused_workspace_matches_fresh() {
    let model = test_model(false, 0xCAB);
    let tokens: Vec<i32> = (0..2 * 64).map(|i| ((i * 13) % 47) as i32).collect();
    let mut fresh_cap = Vec::new();
    forward(&model, &tokens, 2, 64, Some(&mut fresh_cap)).unwrap();
    let mut ws = Workspace::new();
    let mut logits = Tensor::default();
    // warm the arena with a different batch first
    let warm: Vec<i32> = (0..64).map(|i| (i % 47) as i32).collect();
    forward_ws(&model, &warm, 1, 64, None, &mut ws, &mut logits).unwrap();
    let mut ws_cap = Vec::new();
    forward_ws(&model, &tokens, 2, 64, Some(&mut ws_cap), &mut ws, &mut logits).unwrap();
    assert_eq!(fresh_cap.len(), ws_cap.len());
    for (a, b) in fresh_cap.iter().zip(&ws_cap) {
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.weight_mass, b.weight_mass);
    }
}

#[test]
fn moe_forward_ws_reuse_matches_wrapper() {
    let moe = tiny_moe(8, 2, 0xBEE);
    let mut ws = Workspace::new();
    for round in 0..3usize {
        let x = Tensor::randn(&[40 + round, 16], 1.0, &mut Rng::new(100 + round as u64));
        let (want_y, want_counts, want_mass) = moe_forward(&moe, &x).unwrap();
        moe_forward_ws(&moe, &x, &mut ws).unwrap();
        assert_eq!(ws.moe_out.data(), want_y.data(), "round {round}");
        assert_eq!(ws.counts, want_counts, "round {round}");
        assert_eq!(ws.mass, want_mass, "round {round}");
    }
}

#[test]
fn logprob_buffer_reuse_matches_wrapper() {
    let model = test_model(true, 0x10C);
    let mut out = Vec::new();
    for b in [1usize, 3, 2] {
        let tokens: Vec<i32> = (0..b * 64).map(|i| ((i * 5 + b) % 47) as i32).collect();
        let logits = forward(&model, &tokens, b, 64, None).unwrap();
        let want = target_logprobs(&logits, &tokens, b, 64);
        target_logprobs_into(&logits, &tokens, b, 64, &mut out);
        assert_eq!(out, want, "b={b}");
    }
}
