//! Compare two directories of `BENCH_<name>.json` reports (as written by
//! `mergemoe::bench::write_report`) and print per-benchmark speedup or
//! regression — the perf-trajectory check every PR runs.
//!
//! Usage:
//!
//! ```text
//! bench_diff [--max-regress <pct>] <baseline_dir> <current_dir>
//! ```
//!
//! Reports present on only one side are listed but not compared. Without
//! `--max-regress` the exit code is always 0: perf deltas on shared CI
//! machines are informative and the human reading the PR decides. With
//! `--max-regress <pct>` the diff becomes a gate — it fails the run
//! (ci.sh passes 15) when any benchmark regressed by more than `pct`
//! percent, or when nothing could be compared at all (a vacuous gate
//! gates nothing). To keep the gate usable on shared quick-mode CI
//! machines, it judges the **p50** (mean is still what the human-readable
//! lines show — it is the long-term trajectory number, but a single noisy
//! outlier iteration can drag it arbitrarily) and skips entries whose
//! baseline p50 is under [`GATE_MIN_SECONDS`], where timer and scheduler
//! noise dominate real signal.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use mergemoe::util::json::Json;

/// Entries whose baseline p50 sits under this are excluded from the
/// `--max-regress` gate: at micro durations a quick-mode run's jitter
/// routinely exceeds any sane threshold.
const GATE_MIN_SECONDS: f64 = 100e-6;

/// `name -> (mean, p50) seconds` for every result entry of one report file.
fn load_report(path: &Path) -> Result<BTreeMap<String, (f64, f64)>> {
    let json = Json::parse_file(path)?;
    let mut out = BTreeMap::new();
    for entry in json.get("results")?.as_arr()? {
        let name = entry.get("name")?.as_str()?.to_string();
        let mean = entry.get("mean_s")?.as_f64()?;
        let p50 = entry.get("p50_s")?.as_f64()?;
        out.insert(name, (mean, p50));
    }
    Ok(out)
}

/// `BENCH_<x>.json` files in a directory, keyed by `<x>`.
fn reports_in(dir: &Path) -> Result<BTreeMap<String, PathBuf>> {
    let mut out = BTreeMap::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading report dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(stem) = name.strip_prefix("BENCH_").and_then(|n| n.strip_suffix(".json")) {
            out.insert(stem.to_string(), path);
        }
    }
    Ok(out)
}

fn human(mean_s: f64) -> String {
    if mean_s >= 1.0 {
        format!("{mean_s:.3}s")
    } else if mean_s >= 1e-3 {
        format!("{:.3}ms", mean_s * 1e3)
    } else {
        format!("{:.1}µs", mean_s * 1e6)
    }
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let mut max_regress: Option<f64> = None;
    let mut dirs: Vec<String> = Vec::new();
    let mut it = raw.into_iter();
    while let Some(arg) = it.next() {
        if arg == "--max-regress" {
            let val = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("--max-regress needs a percent value"))?;
            max_regress = Some(
                val.parse::<f64>()
                    .with_context(|| format!("--max-regress: bad percent {val:?}"))?,
            );
        } else {
            dirs.push(arg);
        }
    }
    if dirs.len() != 2 {
        bail!("usage: bench_diff [--max-regress <pct>] <baseline_dir> <current_dir>");
    }
    let base_dir = Path::new(&dirs[0]);
    let cur_dir = Path::new(&dirs[1]);
    let base = reports_in(base_dir)?;
    let cur = reports_in(cur_dir)?;
    if cur.is_empty() {
        bail!("no BENCH_*.json reports in {}", cur_dir.display());
    }

    let mut improved = 0usize;
    let mut regressed = 0usize;
    let mut compared = 0usize;
    let mut gated = 0usize;
    // (entry, old p50, new p50, regression pct) past the gate threshold
    let mut violations: Vec<(String, f64, f64, f64)> = Vec::new();
    for (bench, cur_path) in &cur {
        let Some(base_path) = base.get(bench) else {
            println!("[new]  BENCH_{bench}: no baseline — skipping comparison");
            continue;
        };
        let old = load_report(base_path)?;
        let new = load_report(cur_path)?;
        println!("== {bench} ==");
        for (name, (new_mean, new_p50)) in &new {
            let Some((old_mean, old_p50)) = old.get(name) else {
                println!("  [new entry]   {name:<44} {}", human(*new_mean));
                continue;
            };
            compared += 1;
            let speedup = old_mean / new_mean;
            // >10% either way is signal; in between is machine noise
            let tag = if speedup >= 1.10 {
                improved += 1;
                "FASTER "
            } else if speedup <= 0.90 {
                regressed += 1;
                "SLOWER "
            } else {
                "  ~    "
            };
            println!(
                "  {tag} {name:<44} {:>10} -> {:>10}  ({speedup:.2}x)",
                human(*old_mean),
                human(*new_mean)
            );
            if let Some(pct) = max_regress {
                if *old_p50 >= GATE_MIN_SECONDS {
                    gated += 1;
                    let regress_pct = (new_p50 / old_p50 - 1.0) * 100.0;
                    if regress_pct > pct {
                        violations.push((
                            format!("{bench}/{name}"),
                            *old_p50,
                            *new_p50,
                            regress_pct,
                        ));
                    }
                }
            }
        }
        for name in old.keys() {
            if !new.contains_key(name) {
                println!("  [dropped]     {name}");
            }
        }
    }
    for bench in base.keys() {
        if !cur.contains_key(bench) {
            println!("[gone] BENCH_{bench}: present in baseline only");
        }
    }
    println!(
        "\nbench_diff: {compared} compared, {improved} faster (>1.10x), {regressed} slower (<0.90x)"
    );
    if let Some(pct) = max_regress {
        if !violations.is_empty() {
            for (name, old_p50, new_p50, regress_pct) in &violations {
                eprintln!(
                    "REGRESSED {name}: p50 {} -> {} (+{regress_pct:.1}%)",
                    human(*old_p50),
                    human(*new_p50)
                );
            }
            bail!(
                "bench_diff: {} benchmark(s) regressed more than {pct}% (p50)",
                violations.len()
            );
        }
        // A gate that judged nothing gated nothing: disjoint entry sets
        // (renamed benches, a baseline from a machine with a different
        // core count / kernel in its entry names) or only sub-threshold
        // micro entries must fail loudly, not pass vacuously while a real
        // regression scrolls by as [gone] or below the noise floor.
        if gated == 0 {
            bail!(
                "bench_diff: --max-regress gated 0 entries ({compared} compared, \
                 none with baseline p50 >= {GATE_MIN_SECONDS}s) — stale or \
                 mismatched baseline?"
            );
        }
        println!(
            "bench_diff: gate passed ({compared} compared, {gated} gated at p50, \
             no regression over {pct}%)"
        );
    }
    Ok(())
}
