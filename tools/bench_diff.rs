//! Compare two directories of `BENCH_<name>.json` reports (as written by
//! `mergemoe::bench::write_report`) and print per-benchmark speedup or
//! regression — the perf-trajectory check every PR runs.
//!
//! Usage:
//!
//! ```text
//! bench_diff <baseline_dir> <current_dir>
//! ```
//!
//! Reports present on only one side are listed but not compared. The exit
//! code is always 0: perf deltas on shared CI machines are informative, not
//! a gate (the human reading the PR decides).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use mergemoe::util::json::Json;

/// `name -> mean seconds` for every result entry of one report file.
fn load_report(path: &Path) -> Result<BTreeMap<String, f64>> {
    let json = Json::parse_file(path)?;
    let mut out = BTreeMap::new();
    for entry in json.get("results")?.as_arr()? {
        let name = entry.get("name")?.as_str()?.to_string();
        let mean = entry.get("mean_s")?.as_f64()?;
        out.insert(name, mean);
    }
    Ok(out)
}

/// `BENCH_<x>.json` files in a directory, keyed by `<x>`.
fn reports_in(dir: &Path) -> Result<BTreeMap<String, PathBuf>> {
    let mut out = BTreeMap::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading report dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(stem) = name.strip_prefix("BENCH_").and_then(|n| n.strip_suffix(".json")) {
            out.insert(stem.to_string(), path);
        }
    }
    Ok(out)
}

fn human(mean_s: f64) -> String {
    if mean_s >= 1.0 {
        format!("{mean_s:.3}s")
    } else if mean_s >= 1e-3 {
        format!("{:.3}ms", mean_s * 1e3)
    } else {
        format!("{:.1}µs", mean_s * 1e6)
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() != 2 {
        bail!("usage: bench_diff <baseline_dir> <current_dir>");
    }
    let base_dir = Path::new(&args[0]);
    let cur_dir = Path::new(&args[1]);
    let base = reports_in(base_dir)?;
    let cur = reports_in(cur_dir)?;
    if cur.is_empty() {
        bail!("no BENCH_*.json reports in {}", cur_dir.display());
    }

    let mut improved = 0usize;
    let mut regressed = 0usize;
    let mut compared = 0usize;
    for (bench, cur_path) in &cur {
        let Some(base_path) = base.get(bench) else {
            println!("[new]  BENCH_{bench}: no baseline — skipping comparison");
            continue;
        };
        let old = load_report(base_path)?;
        let new = load_report(cur_path)?;
        println!("== {bench} ==");
        for (name, new_mean) in &new {
            let Some(old_mean) = old.get(name) else {
                println!("  [new entry]   {name:<44} {}", human(*new_mean));
                continue;
            };
            compared += 1;
            let speedup = old_mean / new_mean;
            // >10% either way is signal; in between is machine noise
            let tag = if speedup >= 1.10 {
                improved += 1;
                "FASTER "
            } else if speedup <= 0.90 {
                regressed += 1;
                "SLOWER "
            } else {
                "  ~    "
            };
            println!(
                "  {tag} {name:<44} {:>10} -> {:>10}  ({speedup:.2}x)",
                human(*old_mean),
                human(*new_mean)
            );
        }
        for name in old.keys() {
            if !new.contains_key(name) {
                println!("  [dropped]     {name}");
            }
        }
    }
    for bench in base.keys() {
        if !cur.contains_key(bench) {
            println!("[gone] BENCH_{bench}: present in baseline only");
        }
    }
    println!(
        "\nbench_diff: {compared} compared, {improved} faster (>1.10x), {regressed} slower (<0.90x)"
    );
    Ok(())
}
