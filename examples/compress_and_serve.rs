//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full production
//! story on a real workload —
//!
//!   1. load the trained Qwen1.5-analogue MoE model,
//!   2. run the MergeMoE compression pipeline (calibration capture →
//!      clustering → frequency weighting → least-squares T1) on the back
//!      half of the layers,
//!   3. cross-check the native and PJRT engines on the compressed model,
//!   4. deploy the compressed model behind the dynamic batcher and serve
//!      several hundred concurrent scoring requests,
//!   5. report accuracy, latency percentiles, throughput, and memory saved.
//!
//! Run with:  cargo run --release --offline --example compress_and_serve

use std::time::{Duration, Instant};

use anyhow::Result;
use mergemoe::config::Manifest;
use mergemoe::coordinator::{compress, CompressSpec, ScoringServer, ServerConfig};
use mergemoe::eval::tasks::{gen_items, ALL_TASKS};
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::merge::Algorithm;
use mergemoe::runtime::{Engine, NativeEngine, PjrtEngine};
use mergemoe::util::rng::Rng;

fn main() -> Result<()> {
    let artifacts = mergemoe::config::artifacts_dir();
    let ctx = Ctx::new(artifacts.clone(), EngineSel::Pjrt)?;

    // ---- 1+2: compress ----------------------------------------------------
    let model = ctx.load_model("beta")?;
    let mut spec = CompressSpec::new(vec![2, 3], 6, Algorithm::MergeMoe);
    spec.n_calib_seqs = 64;
    let mut gram = ctx.make_gram("beta")?;
    let t0 = Instant::now();
    let (merged, report) = compress(&model, &spec, &mut gram.as_backend())?;
    println!(
        "[compress] {:.2}M -> {:.2}M params ({:.1}%), calib {:.2}s + merge {:.2}s",
        report.params_before as f64 / 1e6,
        report.params_after as f64 / 1e6,
        100.0 * report.compression_ratio(),
        report.calib_seconds,
        report.merge_seconds
    );

    // ---- 3: engine cross-check on the compressed model --------------------
    let s = ctx.manifest.seq_len;
    let tokens = mergemoe::calib::sample_sequences(None, 4, s, 99);
    let native = NativeEngine.logits(&merged, &tokens, 4, s)?;
    let mut pjrt = PjrtEngine::new(Manifest::load(&artifacts)?)?;
    let pj = pjrt.logits(&merged, &tokens, 4, s)?;
    let rel = pj.rel_err(&native);
    println!("[selfcheck] native vs pjrt on compressed model: rel err {rel:.2e}");
    anyhow::ensure!(rel < 1e-3, "engines disagree on the compressed model");

    // ---- 4: serve ----------------------------------------------------------
    let cfg = ServerConfig {
        max_batch: 32,
        max_wait: Duration::from_millis(3),
        seq_len: s,
        ..ServerConfig::default()
    };
    let art2 = artifacts.clone();
    let server = ScoringServer::start(merged, cfg, move || {
        PjrtEngine::new(Manifest::load(&art2)?)
    })?;
    let handle = server.handle();
    let n_clients = 4;
    let per_client = 60;
    let t1 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || -> Result<(usize, usize)> {
            let mut rng = Rng::new(880 + c as u64);
            let mut correct = 0;
            for i in 0..per_client {
                let t = ALL_TASKS[(c + i) % ALL_TASKS.len()];
                let item = gen_items(t, 1, rng.next_u64()).pop().unwrap();
                let s0 = h.score(&item.prompt, &item.options[0])?;
                let s1 = h.score(&item.prompt, &item.options[1])?;
                if (if s0 >= s1 { 0 } else { 1 }) == item.correct {
                    correct += 1;
                }
            }
            Ok((correct, per_client))
        }));
    }
    let mut correct = 0;
    let mut total = 0;
    for j in joins {
        let (c, t) = j.join().unwrap()?;
        correct += c;
        total += t;
    }
    drop(handle);
    let metrics = server.shutdown();
    let wall = t1.elapsed().as_secs_f64();

    // ---- 5: report ----------------------------------------------------------
    println!("[serve] {}", metrics.report());
    println!(
        "[serve] online accuracy {:.1}% over {total} items, wall {wall:.1}s, \
         end-to-end (compress+serve) {:.1}s",
        100.0 * correct as f64 / total as f64,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
