//! Compression-ratio sweep: how each merge algorithm degrades as the expert
//! count shrinks — the full Figure-2a story, but for *all four* algorithms
//! side by side (the paper shows only MergeMoE). Driven by the
//! `eval::sweep` subsystem: one tokenization pass, one calibration capture,
//! one compression per (method, ratio), parallel (model, task) scoring.
//!
//! Run with:  cargo run --release --offline --example sweep_ratios
//!            [-- --items 100 --engine native]

use anyhow::Result;
use mergemoe::eval::tasks::Task;
use mergemoe::eval::{run_sweep, SweepSpec};
use mergemoe::exp::{self, Ctx, EngineSel};
use mergemoe::merge::{NativeGram, COMPARED};
use mergemoe::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>(), &[])?;
    let engine_sel = EngineSel::parse(args.get_or("engine", "pjrt"))?;
    let ctx = Ctx::new(mergemoe::config::artifacts_dir(), engine_sel)?;
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;

    let mut spec = SweepSpec::new(
        COMPARED.to_vec(),
        vec![10, 8, 6, 4, 2],
        vec![Task::Parity],
        vec![2, 3],
    );
    spec.items = args.usize("items", 100)?;
    spec.seq_len = ctx.manifest.seq_len;
    let rep = run_sweep(&model, &spec, &mut NativeGram, engine.as_mut())?;
    exp::tables::sweep_table(&rep).print();
    println!("\n(task: parity — the WinoGrande analogue; layers 2-3 merged)");
    Ok(())
}
