//! Compression-ratio sweep: how each merge algorithm degrades as the expert
//! count shrinks — the full Figure-2a story, but for *all four* algorithms
//! side by side (the paper shows only MergeMoE).
//!
//! Run with:  cargo run --release --offline --example sweep_ratios
//!            [-- --items 100 --engine native]

use anyhow::Result;
use mergemoe::coordinator::{compress, CompressSpec};
use mergemoe::eval::tasks::Task;
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::merge::COMPARED;
use mergemoe::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>(), &[])?;
    let engine_sel = EngineSel::parse(args.get_or("engine", "pjrt"))?;
    let mut ctx = Ctx::new(mergemoe::config::artifacts_dir(), engine_sel)?;
    ctx.items = args.usize("items", 100)?;

    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;
    let sweep = [10usize, 8, 6, 4, 2];

    println!("{:<10} {}", "experts",
             COMPARED.map(|a| format!("{:>10}", a.name())).join(" "));
    let full = ctx.eval_suite(engine.as_mut(), &model, &[Task::Parity])?["parity"];
    println!("{:<10} {}", format!("12 (full)"),
             COMPARED.map(|_| format!("{:>9.1}%", full.percent())).join(" "));
    for &m in &sweep {
        let mut row = Vec::new();
        for alg in COMPARED {
            let mut spec = CompressSpec::new(vec![2, 3], m, alg);
            spec.n_calib_seqs = 64;
            let mut gram = ctx.make_gram("beta")?;
            let (merged, _) = compress(&model, &spec, &mut gram.as_backend())?;
            let acc = ctx.eval_suite(engine.as_mut(), &merged, &[Task::Parity])?["parity"];
            row.push(format!("{:>9.1}%", acc.percent()));
        }
        println!("{:<10} {}", m, row.join(" "));
    }
    println!("\n(task: parity — the WinoGrande analogue; layers 2-3 merged)");
    Ok(())
}
