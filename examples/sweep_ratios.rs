//! Compression-ratio sweep with a calibration-source ablation: how each
//! merge algorithm degrades as the expert count shrinks — the full
//! Figure-2a story for *all four* algorithms side by side — and, on the
//! fourth sweep axis, whether calibrating on the evaluated task beats the
//! uniform mixture (the Table-4 question). Driven by the `eval::sweep`
//! subsystem: one tokenization pass, one calibration capture per source,
//! one compression per (source, method, ratio), with compression of the
//! next variant overlapping the scoring of the current one on the worker
//! pool.
//!
//! Run with:  cargo run --release --offline --example sweep_ratios
//!            [-- --items 100 --engine native]

use anyhow::Result;
use mergemoe::calib::CalibSource;
use mergemoe::eval::tasks::Task;
use mergemoe::eval::{run_sweep, SweepSpec};
use mergemoe::exp::{self, Ctx, EngineSel};
use mergemoe::merge::{NativeGram, COMPARED};
use mergemoe::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>(), &[])?;
    let engine_sel = EngineSel::parse(args.get_or("engine", "pjrt"))?;
    let ctx = Ctx::new(mergemoe::config::artifacts_dir(), engine_sel)?;
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;

    let mut spec = SweepSpec::new(
        COMPARED.to_vec(),
        vec![10, 8, 6, 4, 2],
        vec![Task::Parity],
        vec![2, 3],
    );
    // Calibration-source axis: the uniform mixture vs calibration drawn
    // from the evaluated task itself (Table 4's "self-sourced" row).
    spec.calib_sources = vec![CalibSource::mixture(), CalibSource::single(Task::Parity)];
    spec.items = args.usize("items", 100)?;
    spec.seq_len = ctx.manifest.seq_len;
    let rep = run_sweep(&model, &spec, &mut NativeGram, engine.as_mut())?;
    print!("{}", exp::tables::sweep_markdown(&rep));
    println!("\n(task: parity — the WinoGrande analogue; layers 2-3 merged; \
              self-sourced calibration vs mixture)");
    Ok(())
}
