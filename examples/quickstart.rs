//! Quickstart: load a trained MoE model, compress it with MergeMoE, and
//! compare the paper's headline numbers (accuracy before/after, memory
//! saved) in under a minute.
//!
//! Run with:  cargo run --release --offline --example quickstart

use anyhow::Result;
use mergemoe::coordinator::{compress, CompressSpec};
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::merge::Algorithm;

fn main() -> Result<()> {
    // Artifacts (weights + HLO + manifest) come from `make artifacts`.
    let ctx = {
        let mut c = Ctx::new(mergemoe::config::artifacts_dir(), EngineSel::Pjrt)?;
        c.items = 100; // items per task
        c
    };

    // 1. Load the Qwen1.5-analogue model (12 experts, shared expert).
    let model = ctx.load_model("beta")?;
    println!(
        "loaded beta: {} layers, {} experts (top-{}), {:.2}M params",
        model.cfg.n_layers, model.cfg.n_experts, model.cfg.top_k,
        model.n_params() as f64 / 1e6
    );

    // 2. Compress the back half of the layers 12 -> 6 experts with MergeMoE.
    let mut spec = CompressSpec::new(vec![2, 3], 6, Algorithm::MergeMoe);
    spec.n_calib_seqs = 64;
    let mut gram = ctx.make_gram("beta")?;
    let (merged, report) = compress(&model, &spec, &mut gram.as_backend())?;
    println!(
        "compressed to {:.2}M params ({:.1}% of original) in {:.2}s",
        report.params_after as f64 / 1e6,
        100.0 * report.compression_ratio(),
        report.merge_seconds
    );
    for l in &report.layers {
        println!(
            "  layer {}: {} -> {} experts, output rel-err {:.4}",
            l.layer, l.n_before, l.n_after, l.output_rel_err
        );
    }

    // 3. Evaluate both models on the seven benchmark tasks (PJRT engine —
    //    the same compiled executables the serving path uses).
    let mut engine = ctx.make_engine()?;
    let tasks = mergemoe::exp::paper_task_order();
    let before = ctx.eval_suite(engine.as_mut(), &model, &tasks)?;
    let after = ctx.eval_suite(engine.as_mut(), &merged, &tasks)?;
    println!("\n{:<10} {:>8} {:>10}", "task", "full", "compressed");
    for t in &tasks {
        println!(
            "{:<10} {:>7.2}% {:>9.2}%",
            t.name(),
            before[t.name()].percent(),
            after[t.name()].percent()
        );
    }
    let mean = |m: &std::collections::BTreeMap<&'static str, mergemoe::eval::Accuracy>| {
        m.values().map(|a| a.percent()).sum::<f64>() / m.len() as f64
    };
    println!("{:<10} {:>7.2}% {:>9.2}%", "mean", mean(&before), mean(&after));
    Ok(())
}
