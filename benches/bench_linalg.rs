//! Numerical-substrate benchmark: the tiled matmul variants at 1 thread vs
//! all threads, Cholesky/QR, and the ridge least-squares solve at the shapes
//! the MergeMoE pipeline hits. Emits `BENCH_linalg.json`.

use mergemoe::bench::{self, Bencher};
use mergemoe::linalg;
use mergemoe::tensor::{ops, Tensor};
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let threads = par::max_threads();
    println!("bench_linalg: {threads} threads");
    let b = Bencher::default();
    let mut rng = Rng::new(11);
    let mut out = Vec::new();

    for &(m, k, n) in &[(64usize, 64usize, 64usize), (256, 64, 64), (2048, 64, 64)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let bm = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
        let flops = (2 * m * k * n) as f64;
        par::set_max_threads(1);
        out.push(b.run_items(&format!("matmul/serial/{m}x{k}x{n} (items=flops)"), flops, || {
            ops::matmul(&a, &bm).unwrap()
        }));
        out.push(b.run_items(&format!("matmul_bt/serial/{m}x{k}x{n}"), flops, || {
            ops::matmul_bt(&a, &bt).unwrap()
        }));
        par::set_max_threads(threads);
        out.push(b.run_items(&format!("matmul/t{threads}/{m}x{k}x{n}"), flops, || {
            ops::matmul(&a, &bm).unwrap()
        }));
        out.push(b.run_items(&format!("matmul_bt/t{threads}/{m}x{k}x{n}"), flops, || {
            ops::matmul_bt(&a, &bt).unwrap()
        }));
        // zero-alloc steady-state path
        let mut pre = Tensor::zeros(&[m, n]);
        out.push(b.run_items(&format!("matmul_bt_into/t{threads}/{m}x{k}x{n}"), flops, || {
            ops::matmul_bt_into(&a, &bt, &mut pre).unwrap()
        }));
    }

    let spd = {
        let a = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let mut m = ops::matmul_bt(&a, &a).unwrap();
        for i in 0..64 {
            *m.at2_mut(i, i) += 1.0;
        }
        m
    };
    out.push(b.run("cholesky/64", || linalg::cholesky(&spd).unwrap()));
    let rhs = Tensor::randn(&[64, 64], 1.0, &mut rng);
    out.push(b.run("solve_spd/64x64", || linalg::solve_spd(&spd, &rhs, 1e-8).unwrap()));
    let tall = Tensor::randn(&[256, 64], 1.0, &mut rng);
    out.push(b.run("qr/256x64", || linalg::qr(&tall).unwrap()));
    let p = Tensor::randn(&[64, 4096], 1.0, &mut rng);
    let y = Tensor::randn(&[64, 4096], 1.0, &mut rng);
    par::set_max_threads(1);
    out.push(b.run("lstsq_rows/serial/64x4096", || linalg::lstsq_rows(&p, &y, 1e-8).unwrap()));
    par::set_max_threads(threads);
    out.push(b.run(&format!("lstsq_rows/t{threads}/64x4096"), || {
        linalg::lstsq_rows(&p, &y, 1e-8).unwrap()
    }));

    println!("\n=== bench_linalg ===");
    for s in &out {
        println!("{}", s.report());
    }
    let path = bench::write_report("linalg", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
