//! Decode-path benchmark: prefill tokens/sec (one full forward over the
//! context window) vs autoregressive decode tokens/sec through the KV cache
//! (`Engine::decode_step` on the native engine) vs the re-prefill fallback
//! every KV-less backend gets from the trait default — the O(S) / O(S²)
//! per-token contrast that motivates ROADMAP direction 5. The generation
//! loop is the real `eval::generate_into` on warm caller-owned buffers, so
//! the numbers include sampling. Falls back to a synthetic `beta`-shaped
//! model on a bare checkout. Emits `BENCH_decode.json`.

use anyhow::Result;

use mergemoe::bench::{self, Bencher};
use mergemoe::calib;
use mergemoe::eval::{generate_into, Sampler};
use mergemoe::model::workspace::{KvScratch, Workspace};
use mergemoe::model::ModelWeights;
use mergemoe::runtime::{Engine, NativeEngine};
use mergemoe::tensor::Tensor;
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

/// The trait-default decode path (full re-prefill per token), made concrete
/// so the bench can time it against the native KV override on identical
/// forward kernels — the same shape a backend without an incremental path
/// (PJRT) gets for free.
struct ReprefillEngine;

impl Engine for ReprefillEngine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        NativeEngine.logits(model, tokens, b, s)
    }

    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        NativeEngine.logits_ws(model, tokens, b, s, ws, out)
    }

    fn name(&self) -> &'static str {
        "reprefill"
    }
}

fn main() -> Result<()> {
    let bm = bench::load_or_synth("beta");
    let model = bm.model;
    let s = bm.seq_len;
    let threads = par::max_threads();
    println!(
        "bench_decode: model=beta ({}), {threads} threads, context {s}",
        if bm.from_artifacts { "trained artifacts" } else { "synthetic weights" }
    );

    let b = Bencher::from_env();
    let mut out = Vec::new();

    // ---- prefill: one batched forward over the full window ----
    let tokens = calib::sample_sequences(None, 1, s, 7);
    let mut ws = Workspace::new();
    let mut logits = Tensor::default();
    out.push(b.run_items(&format!("decode/prefill/s{s}"), s as f64, || {
        NativeEngine.logits_ws(&model, &tokens, 1, s, &mut ws, &mut logits).unwrap()
    }));

    // ---- autoregressive decode: prompt -> window, greedy sampling ----
    // (greedy keeps every iteration on the identical token sequence)
    let prompt = &tokens[..8.min(s)];
    let max_new = if bench::quick_mode() { 16.min(s - prompt.len()) } else { s - prompt.len() };
    let mut sampler = Sampler::greedy();
    let mut kv = KvScratch::new();
    let mut toks = Vec::new();
    let mut run = |engine: &mut dyn Engine, ws: &mut Workspace, logits: &mut Tensor,
                   kv: &mut KvScratch, toks: &mut Vec<i32>| {
        let mut rng = Rng::new(11);
        let stats = generate_into(
            engine, &model, prompt, max_new, &mut sampler, &mut rng, kv, ws, logits, toks,
        )
        .unwrap();
        assert_eq!(stats.produced, max_new);
    };
    out.push(b.run_items(&format!("decode/kv/t{threads}/new{max_new}"), max_new as f64, || {
        run(&mut NativeEngine, &mut ws, &mut logits, &mut kv, &mut toks)
    }));
    out.push(b.run_items(&format!("decode/reprefill/new{max_new}"), max_new as f64, || {
        run(&mut ReprefillEngine, &mut ws, &mut logits, &mut kv, &mut toks)
    }));

    println!("\n=== bench_decode (items = tokens) ===");
    for summary in &out {
        println!("{}", summary.report());
    }
    let kv_s = out.iter().find(|x| x.name.starts_with("decode/kv/"));
    let rp = out.iter().find(|x| x.name.starts_with("decode/reprefill/"));
    if let (Some(k), Some(r)) = (kv_s, rp) {
        println!(
            "kv cache: {:.2}x over re-prefill decode at {max_new} new tokens",
            r.mean.as_secs_f64() / k.mean.as_secs_f64()
        );
    }
    let path = bench::write_report("decode", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
