//! Forward-path benchmark: native engine vs PJRT per-layer vs PJRT monolith
//! (the §Perf dispatch-overhead ablation), across the batch buckets.

use mergemoe::bench::Bencher;
use mergemoe::calib;
use mergemoe::config::Manifest;
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::runtime::{Engine, NativeEngine, PjrtEngine};

fn main() -> anyhow::Result<()> {
    let artifacts = mergemoe::config::artifacts_dir();
    let ctx = Ctx::new(artifacts.clone(), EngineSel::Native)?;
    let model = ctx.load_model("beta")?;
    let s = ctx.manifest.seq_len;
    let mut pjrt = PjrtEngine::new(Manifest::load(&artifacts)?)?;

    let b = Bencher::default();
    let mut out = Vec::new();
    for &bb in &[1usize, 8, 32] {
        let tokens = calib::sample_sequences(None, bb, s, 7);
        let toks = bb as f64 * s as f64;
        out.push(b.run_items(&format!("forward/native/b{bb}"), toks, || {
            NativeEngine.logits(&model, &tokens, bb, s).unwrap()
        }));
        out.push(b.run_items(&format!("forward/pjrt_layered/b{bb}"), toks, || {
            pjrt.logits(&model, &tokens, bb, s).unwrap()
        }));
        out.push(b.run_items(&format!("forward/pjrt_monolith/b{bb}"), toks, || {
            pjrt.logits_bucketed(&model, &tokens, bb, s, true).unwrap()
        }));
    }
    println!("\n=== bench_forward (engine comparison; items = tokens) ===");
    for s in &out {
        println!("{}", s.report());
    }
    println!(
        "pjrt: {} executables compiled in {:.2}s, {} executions",
        pjrt.n_compiled, pjrt.compile_seconds, pjrt.n_executions
    );
    Ok(())
}
