//! Forward-path benchmark: native engine at 1 thread vs all threads (the
//! §Perf speedup quoted per PR), the workspace-backed serving path vs the
//! allocating path, plus PJRT per-layer vs PJRT monolith (the
//! dispatch-overhead ablation) when compiled artifacts exist on disk,
//! across the batch buckets. Falls back to a synthetic `beta`-shaped model
//! on a bare checkout. Emits `BENCH_forward.json`.
//!
//! This binary also carries the **allocation probes** for the zero-alloc
//! acceptance check: a counting global allocator measures heap allocations
//! (a) per request in the steady-state serving loop (tokens → logits →
//! per-token log-probs through one warm `Workspace`), (b) per scored
//! chunk in the evaluation-sweep scorer path (prepared items streamed
//! through one warm `EvalScratch`), and (c) per generated token in the
//! autoregressive decode loop (`eval::generate_into` through one warm
//! `KvScratch` + workspace, sampling included). After warmup every count
//! must be 0; `MERGEMOE_STRICT_ALLOC=1` (set by ci.sh) turns a non-zero
//! count into a hard failure.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use mergemoe::bench::{self, Bencher};
use mergemoe::calib;
use mergemoe::config::Manifest;
use mergemoe::eval::scorer::{score_prepared_ws, PreparedItems};
use mergemoe::eval::tasks::{gen_items, Task};
use mergemoe::eval::{generate_into, Sampler};
use mergemoe::model::native::target_logprobs_into;
use mergemoe::model::workspace::{EvalScratch, KvScratch, Workspace};
use mergemoe::runtime::{Engine, NativeEngine, PjrtEngine};
use mergemoe::tensor::Tensor;
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

/// Counts every allocator entry point; `System` does the real work.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() -> anyhow::Result<()> {
    let bm = bench::load_or_synth("beta");
    let model = bm.model;
    let s = bm.seq_len;
    let threads = par::max_threads();
    println!(
        "bench_forward: model=beta ({}), {threads} threads",
        if bm.from_artifacts { "trained artifacts" } else { "synthetic weights" }
    );

    let b = Bencher::from_env();
    let mut out = Vec::new();
    let mut ws = Workspace::new();
    let mut ws_logits = Tensor::default();
    for &bb in &[1usize, 8, 32] {
        let tokens = calib::sample_sequences(None, bb, s, 7);
        let toks = bb as f64 * s as f64;
        par::set_max_threads(1);
        out.push(b.run_items(&format!("forward/native/serial/b{bb}"), toks, || {
            NativeEngine.logits(&model, &tokens, bb, s).unwrap()
        }));
        par::set_max_threads(threads);
        out.push(b.run_items(&format!("forward/native/t{threads}/b{bb}"), toks, || {
            NativeEngine.logits(&model, &tokens, bb, s).unwrap()
        }));
        out.push(b.run_items(&format!("forward/native/ws/t{threads}/b{bb}"), toks, || {
            NativeEngine
                .logits_ws(&model, &tokens, bb, s, &mut ws, &mut ws_logits)
                .unwrap()
        }));
    }

    // ---- allocation probe: steady-state serving loop ----
    println!("\n=== allocation probe (serving loop through one workspace) ===");
    let mut zero_alloc = true;
    for &bb in &[1usize, 32] {
        let tokens = calib::sample_sequences(None, bb, s, 9);
        // warmup: grow every arena buffer to its high-water size, spawn the
        // worker pool, warm the job queue
        for _ in 0..3 {
            NativeEngine.logits_ws(&model, &tokens, bb, s, &mut ws, &mut ws_logits)?;
            target_logprobs_into(&ws_logits, &tokens, bb, s, &mut ws.lps);
        }
        let iters = 20u64;
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        for _ in 0..iters {
            NativeEngine.logits_ws(&model, &tokens, bb, s, &mut ws, &mut ws_logits)?;
            target_logprobs_into(&ws_logits, &tokens, bb, s, &mut ws.lps);
            std::hint::black_box(&ws.lps);
        }
        let after = ALLOC_CALLS.load(Ordering::Relaxed);
        let per_req = (after - before) as f64 / iters as f64;
        println!("steady-state allocs/request b{bb}: {per_req:.2} (target 0)");
        if per_req > 0.0 {
            zero_alloc = false;
        }
    }
    // ---- allocation probe: evaluation-sweep scorer path ----
    println!("\n=== allocation probe (scorer path through one EvalScratch) ===");
    let eval_items = gen_items(Task::Parity, 32, 11);
    let mut prep = PreparedItems::new();
    prep.prepare(&eval_items, s)?;
    let mut es = EvalScratch::new();
    // warmup: grow the lane's arena + score buffers to high-water size
    for _ in 0..3 {
        score_prepared_ws(&mut NativeEngine, &model, &prep, 16, &mut es)?;
    }
    let iters = 10u64;
    let chunks_per_pass = (prep.n_seqs() as u64 + 15) / 16;
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..iters {
        let acc = score_prepared_ws(&mut NativeEngine, &model, &prep, 16, &mut es)?;
        std::hint::black_box(acc.correct);
    }
    let after = ALLOC_CALLS.load(Ordering::Relaxed);
    let per_chunk = (after - before) as f64 / (iters * chunks_per_pass) as f64;
    println!("steady-state allocs/chunk (scorer): {per_chunk:.2} (target 0)");
    if per_chunk > 0.0 {
        zero_alloc = false;
    }

    // ---- allocation probe: autoregressive decode loop ----
    println!("\n=== allocation probe (decode loop through one KvScratch) ===");
    let dec_tokens = calib::sample_sequences(None, 1, s, 13);
    let dec_prompt = &dec_tokens[..8.min(s)];
    let max_new = s - dec_prompt.len();
    // temperature + truncation so the probe covers the sampler's scratch,
    // not just the greedy argmax shortcut
    let mut sampler = Sampler::new(0.8, 8, 0.9);
    let mut kv = KvScratch::new();
    let mut gen_tokens = Vec::new();
    // warmup: size the KV slabs, the sampler scratch, and the token buffer
    // to their high-water marks (a fresh stack Rng per run keeps the token
    // stream identical without touching the heap)
    for _ in 0..3 {
        let mut rng = Rng::new(17);
        generate_into(
            &mut NativeEngine, &model, dec_prompt, max_new, &mut sampler, &mut rng,
            &mut kv, &mut ws, &mut ws_logits, &mut gen_tokens,
        )?;
    }
    let iters = 10u64;
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..iters {
        let mut rng = Rng::new(17);
        generate_into(
            &mut NativeEngine, &model, dec_prompt, max_new, &mut sampler, &mut rng,
            &mut kv, &mut ws, &mut ws_logits, &mut gen_tokens,
        )?;
        std::hint::black_box(&gen_tokens);
    }
    let after = ALLOC_CALLS.load(Ordering::Relaxed);
    let per_tok = (after - before) as f64 / (iters * max_new as u64) as f64;
    println!("steady-state allocs/token (decode): {per_tok:.2} (target 0)");
    if per_tok > 0.0 {
        zero_alloc = false;
    }

    println!(
        "zero-alloc steady state: {}",
        if zero_alloc { "PASS" } else { "FAIL (see counts above)" }
    );
    // Hard gate (ci.sh exports MERGEMOE_STRICT_ALLOC=1): any steady-state
    // allocation on the serving or scorer path fails the bench run instead
    // of scrolling by in the log.
    if !zero_alloc && std::env::var("MERGEMOE_STRICT_ALLOC").map(|v| v == "1").unwrap_or(false) {
        anyhow::bail!("steady-state hot path allocated (MERGEMOE_STRICT_ALLOC=1)");
    }
    out.push(b.run_items(
        "eval/scorer_ws/b16",
        prep.n_seqs() as f64 * s as f64,
        || score_prepared_ws(&mut NativeEngine, &model, &prep, 16, &mut es).unwrap(),
    ));

    if bm.from_artifacts {
        if let Ok(manifest) = Manifest::load(&mergemoe::config::artifacts_dir()) {
            let mut pjrt = PjrtEngine::new(manifest)?;
            for &bb in &[1usize, 8, 32] {
                let tokens = calib::sample_sequences(None, bb, s, 7);
                let toks = bb as f64 * s as f64;
                out.push(b.run_items(&format!("forward/pjrt_layered/b{bb}"), toks, || {
                    pjrt.logits(&model, &tokens, bb, s).unwrap()
                }));
                out.push(b.run_items(&format!("forward/pjrt_monolith/b{bb}"), toks, || {
                    pjrt.logits_bucketed(&model, &tokens, bb, s, true).unwrap()
                }));
            }
            println!(
                "pjrt: {} executables compiled in {:.2}s, {} executions",
                pjrt.n_compiled, pjrt.compile_seconds, pjrt.n_executions
            );
        }
    }

    println!("\n=== bench_forward (items = tokens) ===");
    for summary in &out {
        println!("{}", summary.report());
    }
    for &bb in &[1usize, 8, 32] {
        let ser = out.iter().find(|x| x.name == format!("forward/native/serial/b{bb}"));
        let par_ = out.iter().find(|x| x.name == format!("forward/native/t{threads}/b{bb}"));
        let wsr = out.iter().find(|x| x.name == format!("forward/native/ws/t{threads}/b{bb}"));
        if let (Some(a), Some(p)) = (ser, par_) {
            println!(
                "speedup b{bb}: {:.2}x over serial",
                a.mean.as_secs_f64() / p.mean.as_secs_f64()
            );
        }
        if let (Some(p), Some(w)) = (par_, wsr) {
            println!(
                "workspace b{bb}: {:.2}x over allocating parallel path",
                p.mean.as_secs_f64() / w.mean.as_secs_f64()
            );
        }
    }
    let path = bench::write_report("forward", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
