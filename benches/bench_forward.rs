//! Forward-path benchmark: native engine at 1 thread vs all threads (the
//! §Perf speedup quoted per PR), plus PJRT per-layer vs PJRT monolith (the
//! dispatch-overhead ablation) when compiled artifacts exist on disk,
//! across the batch buckets. Falls back to a synthetic `beta`-shaped model
//! on a bare checkout. Emits `BENCH_forward.json`.

use mergemoe::bench::{self, Bencher};
use mergemoe::calib;
use mergemoe::config::Manifest;
use mergemoe::runtime::{Engine, NativeEngine, PjrtEngine};
use mergemoe::util::par;

fn main() -> anyhow::Result<()> {
    let bm = bench::load_or_synth("beta");
    let model = bm.model;
    let s = bm.seq_len;
    let threads = par::max_threads();
    println!(
        "bench_forward: model=beta ({}), {threads} threads",
        if bm.from_artifacts { "trained artifacts" } else { "synthetic weights" }
    );

    let b = Bencher::default();
    let mut out = Vec::new();
    for &bb in &[1usize, 8, 32] {
        let tokens = calib::sample_sequences(None, bb, s, 7);
        let toks = bb as f64 * s as f64;
        par::set_max_threads(1);
        out.push(b.run_items(&format!("forward/native/serial/b{bb}"), toks, || {
            NativeEngine.logits(&model, &tokens, bb, s).unwrap()
        }));
        par::set_max_threads(threads);
        out.push(b.run_items(&format!("forward/native/t{threads}/b{bb}"), toks, || {
            NativeEngine.logits(&model, &tokens, bb, s).unwrap()
        }));
    }

    if bm.from_artifacts {
        if let Ok(manifest) = Manifest::load(&mergemoe::config::artifacts_dir()) {
            let mut pjrt = PjrtEngine::new(manifest)?;
            for &bb in &[1usize, 8, 32] {
                let tokens = calib::sample_sequences(None, bb, s, 7);
                let toks = bb as f64 * s as f64;
                out.push(b.run_items(&format!("forward/pjrt_layered/b{bb}"), toks, || {
                    pjrt.logits(&model, &tokens, bb, s).unwrap()
                }));
                out.push(b.run_items(&format!("forward/pjrt_monolith/b{bb}"), toks, || {
                    pjrt.logits_bucketed(&model, &tokens, bb, s, true).unwrap()
                }));
            }
            println!(
                "pjrt: {} executables compiled in {:.2}s, {} executions",
                pjrt.n_compiled, pjrt.compile_seconds, pjrt.n_executions
            );
        }
    }

    println!("\n=== bench_forward (items = tokens) ===");
    for summary in &out {
        println!("{}", summary.report());
    }
    for &bb in &[1usize, 8, 32] {
        let ser = out.iter().find(|x| x.name == format!("forward/native/serial/b{bb}"));
        let par_ = out.iter().find(|x| x.name == format!("forward/native/t{threads}/b{bb}"));
        if let (Some(a), Some(p)) = (ser, par_) {
            println!(
                "speedup b{bb}: {:.2}x over serial",
                a.mean.as_secs_f64() / p.mean.as_secs_f64()
            );
        }
    }
    let path = bench::write_report("forward", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
