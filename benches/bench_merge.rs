//! Fig. 3 benchmark: per-layer merging time, MergeMoE vs the baselines
//! (`beta`, 12 → 6, 128 calibration sequences — the paper's batch-128
//! setting), plus the isolated least-squares solve.

use mergemoe::bench::Bencher;
use mergemoe::calib;
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::merge::{self, Algorithm, NativeGram};
use mergemoe::linalg;
use mergemoe::tensor::Tensor;
use mergemoe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(mergemoe::config::artifacts_dir(), EngineSel::Native)?;
    let model = ctx.load_model("beta")?;
    let seq_len = ctx.manifest.seq_len;
    let tokens = calib::sample_sequences(None, 128, seq_len, 1);
    let data = calib::capture(&model, &tokens, 128, seq_len)?;
    let li = model.cfg.n_layers - 1;
    let moe = &model.layers[li].moe;
    let lc = &data.layers[li];
    let plan = merge::clustering::build_plan(moe, &lc.stats, 6)?;

    let b = Bencher::default();
    let mut out = Vec::new();
    for alg in [Algorithm::Average, Algorithm::ZipIt, Algorithm::MSmoe,
                Algorithm::MergeMoe] {
        out.push(b.run(&format!("merge_layer/{}", alg.name()), || {
            merge::merge_layer(alg, moe, &plan, Some(&lc.x), &mut NativeGram, 1e-6)
                .unwrap()
        }));
    }
    // isolated pieces of the MergeMoE solve
    out.push(b.run("clustering/build_plan", || {
        merge::clustering::build_plan(moe, &lc.stats, 6).unwrap()
    }));
    let mut rng = Rng::new(5);
    let p = Tensor::randn(&[64, 8192], 1.0, &mut rng);
    let y = Tensor::randn(&[64, 8192], 1.0, &mut rng);
    out.push(b.run_items("lstsq/gram_8192cols", 8192.0, || {
        use mergemoe::merge::GramBackend;
        NativeGram.gram(&p, &y).unwrap()
    }));
    let (pp, yp) = {
        use mergemoe::merge::GramBackend;
        NativeGram.gram(&p, &y).unwrap()
    };
    out.push(b.run("lstsq/solve_64x64", || {
        linalg::lstsq_from_gram(&pp, &yp, 1e-6).unwrap()
    }));

    println!("\n=== bench_merge (fig. 3) ===");
    for s in &out {
        println!("{}", s.report());
    }
    Ok(())
}
