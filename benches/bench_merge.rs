//! Fig. 3 benchmark: per-layer merging time, MergeMoE vs the baselines
//! (`beta`, 12 → 6, 128 calibration sequences — the paper's batch-128
//! setting), the isolated least-squares solve, and the serial-vs-parallel
//! MergeMoE comparison. Falls back to a synthetic `beta`-shaped model on a
//! bare checkout. Emits `BENCH_merge.json`.

use mergemoe::bench::{self, Bencher};
use mergemoe::calib;
use mergemoe::linalg;
use mergemoe::merge::{self, Algorithm, NativeGram};
use mergemoe::model::workspace::Workspace;
use mergemoe::tensor::Tensor;
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let bm = bench::load_or_synth("beta");
    let model = bm.model;
    let seq_len = bm.seq_len;
    let threads = par::max_threads();
    println!(
        "bench_merge: model=beta ({}), {threads} threads",
        if bm.from_artifacts { "trained artifacts" } else { "synthetic weights" }
    );
    let tokens = calib::sample_sequences(None, 128, seq_len, 1);
    let data = calib::capture(&model, &tokens, 128, seq_len)?;
    let li = model.cfg.n_layers - 1;
    let moe = &model.layers[li].moe;
    let lc = &data.layers[li];
    let plan = merge::clustering::build_plan(moe, &lc.stats, 6)?;

    let b = Bencher::from_env();
    let mut out = Vec::new();
    let mut ws = Workspace::new();
    for alg in [Algorithm::Average, Algorithm::ZipIt, Algorithm::MSmoe, Algorithm::MergeMoe] {
        out.push(b.run(&format!("merge_layer/{}", alg.name()), || {
            merge::merge_layer(alg, moe, &plan, Some(&lc.x), &mut NativeGram, 1e-6, &mut ws)
                .unwrap()
        }));
    }
    // serial baseline for the paper-method path (the §Perf speedup)
    par::set_max_threads(1);
    out.push(b.run("merge_layer/MergeMoE/serial", || {
        merge::merge_layer(
            Algorithm::MergeMoe, moe, &plan, Some(&lc.x), &mut NativeGram, 1e-6, &mut ws,
        )
        .unwrap()
    }));
    par::set_max_threads(threads);

    // isolated pieces of the MergeMoE solve
    out.push(b.run("clustering/build_plan", || {
        merge::clustering::build_plan(moe, &lc.stats, 6).unwrap()
    }));
    let mut rng = Rng::new(5);
    let p = Tensor::randn(&[64, 8192], 1.0, &mut rng);
    let y = Tensor::randn(&[64, 8192], 1.0, &mut rng);
    out.push(b.run_items("lstsq/gram_8192cols", 8192.0, || {
        use mergemoe::merge::GramBackend;
        NativeGram.gram(&p, &y).unwrap()
    }));
    let (pp, yp) = {
        use mergemoe::merge::GramBackend;
        NativeGram.gram(&p, &y).unwrap()
    };
    out.push(b.run("lstsq/solve_64x64", || linalg::lstsq_from_gram(&pp, &yp, 1e-6).unwrap()));

    println!("\n=== bench_merge (fig. 3) ===");
    for s in &out {
        println!("{}", s.report());
    }
    let ser = out.iter().find(|x| x.name == "merge_layer/MergeMoE/serial");
    let par_ = out.iter().find(|x| x.name == "merge_layer/MergeMoE");
    if let (Some(a), Some(p2)) = (ser, par_) {
        println!(
            "speedup merge_layer/MergeMoE: {:.2}x over serial",
            a.mean.as_secs_f64() / p2.mean.as_secs_f64()
        );
    }
    let path = bench::write_report("merge", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
