//! End-to-end compression-pipeline benchmark: calibration capture + merge
//! across calibration sizes and algorithms (the cost model behind Fig. 3 and
//! the paper's "completes within a minute" claim).

use mergemoe::bench::Bencher;
use mergemoe::coordinator::{compress, CompressSpec};
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::merge::{Algorithm, NativeGram};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(mergemoe::config::artifacts_dir(), EngineSel::Native)?;
    let model = ctx.load_model("beta")?;
    let b = Bencher::quick();
    let mut out = Vec::new();
    for &seqs in &[16usize, 64, 128] {
        for alg in [Algorithm::MSmoe, Algorithm::MergeMoe] {
            let mut spec = CompressSpec::new(vec![2, 3], 6, alg);
            spec.n_calib_seqs = seqs;
            out.push(b.run(
                &format!("pipeline/{}/calib{seqs}", alg.name()),
                || compress(&model, &spec, &mut NativeGram).unwrap(),
            ));
        }
    }
    println!("\n=== bench_pipeline ===");
    for s in &out {
        println!("{}", s.report());
    }
    Ok(())
}
