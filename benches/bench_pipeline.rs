//! End-to-end compression-pipeline benchmark: calibration capture + merge
//! across calibration sizes and algorithms (the cost model behind Fig. 3 and
//! the paper's "completes within a minute" claim). Falls back to a synthetic
//! `beta`-shaped model on a bare checkout. Emits `BENCH_pipeline.json`.

use mergemoe::bench::{self, Bencher};
use mergemoe::coordinator::{compress, CompressSpec};
use mergemoe::merge::{Algorithm, NativeGram};
use mergemoe::util::par;

fn main() -> anyhow::Result<()> {
    let bm = bench::load_or_synth("beta");
    let model = bm.model;
    let threads = par::max_threads();
    println!(
        "bench_pipeline: model=beta ({}), {threads} threads",
        if bm.from_artifacts { "trained artifacts" } else { "synthetic weights" }
    );
    let b = Bencher::quick();
    let mut out = Vec::new();
    for &seqs in &[16usize, 64, 128] {
        for alg in [Algorithm::MSmoe, Algorithm::MergeMoe] {
            let mut spec = CompressSpec::new(vec![2, 3], 6, alg);
            spec.n_calib_seqs = seqs;
            out.push(b.run(&format!("pipeline/{}/calib{seqs}", alg.name()), || {
                compress(&model, &spec, &mut NativeGram).unwrap()
            }));
        }
    }
    // serial baseline of the full paper pipeline
    let mut spec = CompressSpec::new(vec![2, 3], 6, Algorithm::MergeMoe);
    spec.n_calib_seqs = 128;
    par::set_max_threads(1);
    out.push(b.run("pipeline/MergeMoE/calib128/serial", || {
        compress(&model, &spec, &mut NativeGram).unwrap()
    }));
    par::set_max_threads(threads);

    println!("\n=== bench_pipeline ===");
    for s in &out {
        println!("{}", s.report());
    }
    let path = bench::write_report("pipeline", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
