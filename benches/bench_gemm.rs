//! GEMM microkernel benchmark: the kernel layer's headline numbers. Sweeps
//! square shapes {64..1024}² across {scalar, SIMD} kernels × {1, N}
//! threads for both hot GEMM forms (`a @ b` and `a @ bᵀ`), reports GFLOP/s
//! (items = flops), and prints the single-thread SIMD-over-scalar speedup
//! at 512³ — the PR acceptance number. Emits `BENCH_gemm.json`.
//!
//! Kernel forcing uses `kernel::set_kernel`, the bench/test override of the
//! per-process dispatch (exactly like `par::set_max_threads` for threads);
//! the process is restored to its detected kernel before the report is
//! written.

use mergemoe::bench::{self, Bencher};
use mergemoe::kernel::{self, Kind};
use mergemoe::tensor::{ops, Tensor};
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let threads = par::max_threads();
    let detected = kernel::active();
    let quick = bench::quick_mode();
    let sizes: Vec<usize> =
        if quick { vec![64, 256, 512] } else { vec![64, 128, 256, 512, 1024] };
    let kinds: Vec<Kind> = if detected == Kind::Scalar {
        // active() == Scalar either because the hardware has no SIMD
        // family or because MERGEMOE_KERNEL forced a non-auto choice —
        // say which (empty/"auto" is not a force, mirroring resolve()).
        let forced = std::env::var("MERGEMOE_KERNEL")
            .map(|v| !matches!(v.trim().to_ascii_lowercase().as_str(), "" | "auto"))
            .unwrap_or(false);
        if forced {
            println!("bench_gemm: MERGEMOE_KERNEL forces scalar — skipping the SIMD half");
        } else {
            println!("bench_gemm: no SIMD kernel on this host — scalar only");
        }
        vec![Kind::Scalar]
    } else {
        vec![Kind::Scalar, detected]
    };
    println!(
        "bench_gemm: detected kernel {}, {threads} threads, sizes {sizes:?}",
        detected.name()
    );

    let b = Bencher::from_env();
    let mut out = Vec::new();
    let mut rng = Rng::new(0x6E44);
    let max = *sizes.iter().max().unwrap();
    // one operand set at the largest size; smaller shapes slice its prefix
    let a = Tensor::randn(&[max, max], 1.0, &mut rng);
    let bt = Tensor::randn(&[max, max], 1.0, &mut rng);
    for &s in &sizes {
        // square (s, s) operands sliced out of the shared buffers
        let mut asq = Tensor::zeros(&[s, s]);
        let mut bsq = Tensor::zeros(&[s, s]);
        for i in 0..s {
            asq.row_mut(i).copy_from_slice(&a.row(i)[..s]);
            bsq.row_mut(i).copy_from_slice(&bt.row(i)[..s]);
        }
        let flops = 2.0 * (s as f64).powi(3);
        let mut c = Tensor::zeros(&[s, s]);
        let tset: Vec<usize> = if threads > 1 { vec![1, threads] } else { vec![1] };
        for &kind in &kinds {
            kernel::set_kernel(kind);
            for &t in &tset {
                par::set_max_threads(t);
                let tag = |op: &str| format!("gemm/{op}/{s}/{}/t{t}", kind.name());
                out.push(b.run_items(&tag("nn"), flops, || {
                    ops::matmul_into(&asq, &bsq, &mut c).unwrap()
                }));
                out.push(b.run_items(&tag("nt"), flops, || {
                    ops::matmul_bt_into(&asq, &bsq, &mut c).unwrap()
                }));
            }
        }
        par::set_max_threads(threads);
    }
    kernel::set_kernel(detected);

    println!("\n=== bench_gemm (items = flops; items/s = FLOP/s) ===");
    for s in &out {
        println!("{}", s.report());
    }
    if kinds.len() > 1 {
        for op in ["nn", "nt"] {
            let scalar = out.iter().find(|x| x.name == format!("gemm/{op}/512/scalar/t1"));
            let simd = out
                .iter()
                .find(|x| x.name == format!("gemm/{op}/512/{}/t1", detected.name()));
            if let (Some(sc), Some(si)) = (scalar, simd) {
                println!(
                    "speedup 512³ {op}: {} {:.2}x over scalar (single thread)",
                    detected.name(),
                    sc.mean.as_secs_f64() / si.mean.as_secs_f64()
                );
            }
        }
    }
    let path = bench::write_report("gemm", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
