//! Serving benchmark: dynamic-batcher throughput/latency across batching
//! policies (max_batch × max_wait), native backend so the numbers isolate
//! coordinator overhead from backend compute.

use std::time::Duration;

use mergemoe::coordinator::{ScoringServer, ServerConfig};
use mergemoe::eval::tasks::{gen_items, ALL_TASKS};
use mergemoe::exp::{Ctx, EngineSel};
use mergemoe::runtime::NativeEngine;
use mergemoe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new(mergemoe::config::artifacts_dir(), EngineSel::Native)?;
    let model = ctx.load_model("beta")?;
    println!("\n=== bench_batcher (policy sweep, native backend) ===");
    for (max_batch, wait_ms) in [(1usize, 0u64), (8, 1), (8, 3), (32, 1), (32, 3), (32, 10)] {
        let cfg = ServerConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            seq_len: ctx.manifest.seq_len,
        };
        let server = ScoringServer::start(model.clone(), cfg, || Ok(NativeEngine));
        let handle = server.handle();
        let n_clients = 8;
        let per = 25;
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(300 + c as u64);
                for i in 0..per {
                    let t = ALL_TASKS[(c + i) % ALL_TASKS.len()];
                    let item = gen_items(t, 1, rng.next_u64()).pop().unwrap();
                    h.score(&item.prompt, &item.options[0]).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        drop(handle);
        let m = server.shutdown();
        println!(
            "max_batch={max_batch:<3} wait={wait_ms:>2}ms  {:>6.1} req/s  mean_batch={:<5.2} \
             p50={:?} p99={:?}",
            m.throughput_rps(),
            m.mean_batch_size(),
            m.total_latency.quantile(0.5),
            m.total_latency.quantile(0.99),
        );
    }
    Ok(())
}
