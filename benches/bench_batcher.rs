//! Serving benchmark: dynamic-batcher throughput/latency across batching
//! policies (max_batch × max_wait), native backend so the numbers isolate
//! coordinator overhead from backend compute.

use std::time::Duration;

use mergemoe::bench;
use mergemoe::coordinator::{ScoringServer, ServerConfig};
use mergemoe::eval::tasks::{gen_items, ALL_TASKS};
use mergemoe::runtime::NativeEngine;
use mergemoe::util::json::Json;
use mergemoe::util::par;
use mergemoe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let bm = bench::load_or_synth("beta");
    let model = bm.model;
    println!(
        "\n=== bench_batcher (policy sweep, native backend; model={}, {} threads) ===",
        if bm.from_artifacts { "trained" } else { "synthetic" },
        par::max_threads()
    );
    let mut records: Vec<Json> = Vec::new();
    for (max_batch, wait_ms) in [(1usize, 0u64), (8, 1), (8, 3), (32, 1), (32, 3), (32, 10)] {
        let cfg = ServerConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            seq_len: bm.seq_len,
            ..ServerConfig::default()
        };
        let server = ScoringServer::start(model.clone(), cfg, || Ok(NativeEngine))?;
        let handle = server.handle();
        let n_clients = 8;
        let per = 25;
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(300 + c as u64);
                for i in 0..per {
                    let t = ALL_TASKS[(c + i) % ALL_TASKS.len()];
                    let item = gen_items(t, 1, rng.next_u64()).pop().unwrap();
                    h.score(&item.prompt, &item.options[0]).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        drop(handle);
        let m = server.shutdown();
        println!(
            "max_batch={max_batch:<3} wait={wait_ms:>2}ms  {:>6.1} req/s  mean_batch={:<5.2} \
             p50={:?} p99={:?}",
            m.throughput_rps(),
            m.mean_batch_size(),
            m.total_latency.quantile(0.5),
            m.total_latency.quantile(0.99),
        );
        records.push(Json::obj(vec![
            ("max_batch", Json::num(max_batch as f64)),
            ("max_wait_ms", Json::num(wait_ms as f64)),
            ("req_per_s", Json::num(m.throughput_rps())),
            ("mean_batch", Json::num(m.mean_batch_size())),
            ("p50_s", Json::num(m.total_latency.quantile(0.5).as_secs_f64())),
            ("p99_s", Json::num(m.total_latency.quantile(0.99).as_secs_f64())),
        ]));
    }
    // same BENCH_<name>.json trajectory record as the other benches, but
    // with the batcher's own policy-sweep schema
    let report = Json::obj(vec![
        ("bench", Json::str("batcher")),
        ("threads", Json::num(par::max_threads() as f64)),
        ("results", Json::arr(records)),
    ]);
    let dir = std::env::var("MERGEMOE_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join("BENCH_batcher.json");
    std::fs::write(&path, report.to_string())?;
    println!("wrote {}", path.display());
    Ok(())
}
