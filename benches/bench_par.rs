//! Parallel-region dispatch overhead: the persistent pool vs PR 1's
//! spawn-per-region scoped threads vs plain serial, across small/medium
//! shapes (the regime the batcher's 2 ms deadline lives in). The spawn
//! baseline is reimplemented here verbatim so every future PR can re-measure
//! the gap on the same machine. Emits `BENCH_par.json`.

use mergemoe::bench::{self, Bencher};
use mergemoe::util::par;

/// PR 1's threading primitive: spawn + join scoped threads per region.
/// Kept as the reference implementation the pool is benchmarked against.
fn spawn_parallel_for<F>(data: &mut [f32], chunk_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let threads = threads.min(n_chunks).max(1);
    if threads <= 1 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    let per = (n_chunks + threads - 1) / threads;
    let mut parts: Vec<(usize, &mut [f32])> = Vec::with_capacity(threads);
    let mut rest = data;
    let mut chunk0 = 0;
    while !rest.is_empty() {
        let take = (per * chunk_len).min(rest.len());
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        rest = tail;
        parts.push((chunk0, head));
        chunk0 += per;
    }
    let f = &f;
    std::thread::scope(|s| {
        for (c0, slab) in parts {
            s.spawn(move || {
                for (ci, chunk) in slab.chunks_mut(chunk_len).enumerate() {
                    f(c0 + ci, chunk);
                }
            });
        }
    });
}

fn main() -> anyhow::Result<()> {
    let threads = par::max_threads();
    println!("bench_par: {threads} threads, pool size before warmup {}", par::pool_size());
    let b = Bencher::from_env();
    let mut out = Vec::new();

    // warm the pool so the pool numbers measure dispatch, not spawn
    let mut warm = vec![0.0f32; 1 << 16];
    par::par_chunks_mut_if(true, &mut warm, 1024, |_ci, c| {
        for v in c.iter_mut() {
            *v += 1.0;
        }
    });
    println!("pool size after warmup: {}", par::pool_size());

    for &(elems, label) in &[(4_096usize, "4k"), (65_536usize, "64k"), (1_048_576usize, "1m")] {
        let chunk = 256usize;
        let mut data = vec![0.0f32; elems];
        out.push(b.run_items(&format!("par/pool/{label}"), elems as f64, || {
            par::par_chunks_mut_if(true, &mut data, chunk, |_ci, c| {
                for v in c.iter_mut() {
                    *v = v.mul_add(1.000001, 1.0);
                }
            });
        }));
        out.push(b.run_items(&format!("par/spawn/{label}"), elems as f64, || {
            spawn_parallel_for(&mut data, chunk, threads, |_ci, c| {
                for v in c.iter_mut() {
                    *v = v.mul_add(1.000001, 1.0);
                }
            });
        }));
        out.push(b.run_items(&format!("par/serial/{label}"), elems as f64, || {
            for c in data.chunks_mut(chunk) {
                for v in c.iter_mut() {
                    *v = v.mul_add(1.000001, 1.0);
                }
            }
        }));
    }

    println!("\n=== bench_par (items = elements) ===");
    for s in &out {
        println!("{}", s.report());
    }
    for &label in &["4k", "64k", "1m"] {
        let pool = out.iter().find(|x| x.name == format!("par/pool/{label}"));
        let spawn = out.iter().find(|x| x.name == format!("par/spawn/{label}"));
        let serial = out.iter().find(|x| x.name == format!("par/serial/{label}"));
        if let (Some(p), Some(sp)) = (pool, spawn) {
            println!(
                "speedup {label}: pool {:.2}x over spawn-per-region",
                sp.mean.as_secs_f64() / p.mean.as_secs_f64()
            );
        }
        if let (Some(p), Some(se)) = (pool, serial) {
            println!(
                "        {label}: pool {:.2}x vs serial",
                se.mean.as_secs_f64() / p.mean.as_secs_f64()
            );
        }
    }
    let path = bench::write_report("par", &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
