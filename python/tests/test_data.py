"""Corpus/task format contract tests — the python half of the cross-language
format lock (rust mirrors these in rust/src/eval/tasks.rs)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data
from compile.configs import CHARSET, SEQ_LEN


def test_charset_has_no_duplicates():
    assert len(set(CHARSET)) == len(CHARSET) == 47


def test_fingerprint_value_is_stable():
    # Pin the value: rust/src/eval/tasks.rs computes the same number with the
    # same formula; a change on either side must update both.
    fp = data.charset_fingerprint()
    assert fp == data.charset_fingerprint()
    h = 0
    for i, c in enumerate(CHARSET):
        h = (h * 131 + ord(c) * (i + 7)) % 1_000_000_007
    assert fp == h


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       task=st.sampled_from(data.TASKS))
def test_lines_stay_inside_alphabet_and_format(seed, task):
    rng = np.random.RandomState(seed)
    line = data.gen_line(task, rng)
    assert all(c in data.C2I for c in line)
    tag = {"copy": "c:", "rev": "r:", "sort": "s:", "arith": "a:",
           "parity": "p:", "maj": "m:", "markov": "t:"}[task]
    assert line.startswith(tag)
    if task != "markov":
        assert line.endswith(".")
    assert len(line) < SEQ_LEN


def test_task_correctness_of_generated_lines():
    rng = np.random.RandomState(7)
    for _ in range(50):
        c = data.gen_line("copy", rng)
        a, b = c[2:-1].split("|")
        assert a == b
        r = data.gen_line("rev", rng)
        a, b = r[2:-1].split("|")
        assert a[::-1] == b
        s = data.gen_line("sort", rng)
        a, b = s[2:-1].split("|")
        assert "".join(sorted(a)) == b
        ar = data.gen_line("arith", rng)
        lhs, rhs = ar[2:-1].split("=")
        x, y = lhs.split("+")
        assert int(x) + int(y) == int(rhs)
        p = data.gen_line("parity", rng)
        bits, ans = p[2:-1].split("#")
        assert ans == ("e" if bits.count("1") % 2 == 0 else "o")
        m = data.gen_line("maj", rng)
        s2, ans = m[2:-1].split("!")
        assert ans == ("a" if s2.count("a") > len(s2) // 2 else "b")


def test_markov_greedy_follows_chain():
    text = data.markov_greedy(5, 10)
    for a, b in zip(text, text[1:]):
        ca, cb = ord(a) - 97, ord(b) - 97
        assert cb == data.mk_succ(ca, 0)


def test_corpus_batches_shapes_and_determinism():
    a = list(data.corpus_batches(3, 4, 2))
    b = list(data.corpus_batches(3, 4, 2))
    assert len(a) == 2
    for (x1, y1), (x2, y2) in zip(a, b):
        assert x1.shape == (4, SEQ_LEN)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
        # targets are inputs shifted by one
        np.testing.assert_array_equal(x1[:, 1:], y1[:, :-1])
