"""AOT layer tests: manifest completeness and HLO-text lowering sanity.
(Heavy lowering is exercised by `make artifacts`; here we verify manifest
structure and spot-lower one artifact per kind.)"""

import json
import os

import pytest

from compile import aot
from compile.configs import MODELS, BATCH_BUCKETS, VOCAB


@pytest.fixture(scope="module")
def manifest():
    return aot.build_manifest()


def test_every_model_has_all_required_artifacts(manifest):
    names = set(manifest)
    for cfg in MODELS.values():
        n = cfg.n_experts
        sh = "sh" if cfg.shared_expert else "ns"
        for b in BATCH_BUCKETS:
            assert f"embed_v{VOCAB}_d{cfg.d_model}_b{b}" in names
            assert f"attn_d{cfg.d_model}_h{cfg.n_heads}_b{b}" in names
            assert f"lmhead_v{VOCAB}_d{cfg.d_model}_b{b}" in names
            for m in {n, *cfg.merge_targets}:
                key = (f"moe_d{cfg.d_model}_f{cfg.d_ff}_n{n}_m{m}_"
                       f"k{cfg.top_k}_{sh}_b{b}")
                assert key in names, key
            if cfg.merge_targets:
                assert f"monolith_{cfg.name}_b{b}" in names


def test_param_order_is_stable(manifest):
    # rust feeds parameters positionally; the moe signature must be exactly
    # h, ln2_g, ln2_b, router, amap, wg, wu, wd [, swg, swu, swd]
    art = manifest["moe_d64_f64_n12_m6_k2_sh_b8"]
    names = [p["name"] for p in art["params"]]
    assert names == ["h", "ln2_g", "ln2_b", "router", "amap", "wg", "wu", "wd",
                     "swg", "swu", "swd"]
    shapes = {p["name"]: tuple(p["shape"]) for p in art["params"]}
    assert shapes["router"] == (12, 64)
    assert shapes["amap"] == (6, 12)
    assert shapes["wg"] == (6, 64, 64)


def test_outputs_match_moe_contract(manifest):
    art = manifest["moe_d64_f64_n16_m8_k2_ns_b1"]
    outs = [tuple(o["shape"]) for o in art["outputs"]]
    assert outs == [(1, 64, 64), (8,), (1, 64, 2), (1, 64, 2)]


def test_spot_lowering_produces_parseable_hlo(manifest, tmp_path):
    # lower the smallest moe artifact and check basic HLO-text structure,
    # including the absence of the `topk` instruction that xla_extension
    # 0.5.1 cannot parse (regression guard for the argsort-based routing).
    name = "moe_d64_f64_n12_m6_k2_sh_b1"
    assert aot.lower_artifact(name, manifest[name], str(tmp_path))
    text = (tmp_path / f"{name}.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert " topk(" not in text, "lax.top_k leaked into HLO (unparseable by 0.5.1)"
    assert " sort(" in text  # argsort-based routing


def test_manifest_on_disk_if_built():
    # When artifacts/ exists (after `make artifacts`), the manifest must load
    # and cover every enumerated artifact with an existing file.
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        m = json.load(f)
    art_dir = os.path.dirname(path)
    for name, art in m["artifacts"].items():
        assert os.path.exists(os.path.join(art_dir, art["file"])), name
