"""L1 correctness: pallas kernels vs the pure-jnp oracle, swept with
hypothesis over shapes, seeds and value scales. This is the CORE correctness
signal of the kernel layer (DESIGN.md §7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram as gram_k
from compile.kernels import ref
from compile.kernels import swiglu as swiglu_k


def rand(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    e=st.integers(1, 6),
    tiles=st.integers(1, 4),
    f=st.sampled_from([8, 16, 64]),
    d=st.sampled_from([8, 16, 64]),
    # weight scales up to ~1.0 (trained weights sit near 0.1; beyond ~1 the
    # SwiGLU products reach 1e4 and f32 accumulation-order differences
    # between einsum and the blocked kernel dominate any fixed tolerance)
    scale=st.sampled_from([0.1, 0.5, 1.0]),
)
def test_routed_swiglu_matches_ref(seed, e, tiles, f, d, scale):
    tile_t = 16
    t = tiles * tile_t
    rng = np.random.default_rng(seed)
    x = rand(rng, t, d)
    wg = rand(rng, e, f, d, scale=scale)
    wu = rand(rng, e, f, d, scale=scale)
    wd = rand(rng, e, d, f, scale=scale)
    # sparse-ish routing matrix with some exact zeros
    r = rand(rng, t, e)
    r[np.abs(r) < 0.7] = 0.0
    got = swiglu_k.routed_swiglu(
        jnp.array(x), jnp.array(wg), jnp.array(wu), jnp.array(wd), jnp.array(r),
        tile_t=tile_t,
    )
    want = ref.routed_swiglu(x, wg, wu, wd, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    f=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([8, 32, 64]),
    chunks=st.integers(1, 4),
)
def test_gram_matches_ref(seed, f, d, chunks):
    chunk = 64
    s = chunks * chunk
    rng = np.random.default_rng(seed)
    p = rand(rng, f, s)
    y = rand(rng, d, s)
    pp, yp = gram_k.gram(jnp.array(p), jnp.array(y), chunk=chunk)
    pp_ref, yp_ref = ref.gram(p, y)
    np.testing.assert_allclose(np.asarray(pp), np.asarray(pp_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yp_ref), rtol=1e-4, atol=1e-4)


def test_routed_swiglu_zero_routing_is_zero():
    rng = np.random.default_rng(0)
    x = rand(rng, 32, 8)
    wg = rand(rng, 2, 8, 8)
    wu = rand(rng, 2, 8, 8)
    wd = rand(rng, 2, 8, 8)
    r = np.zeros((32, 2), np.float32)
    out = swiglu_k.routed_swiglu(
        jnp.array(x), jnp.array(wg), jnp.array(wu), jnp.array(wd), jnp.array(r),
        tile_t=16)
    assert float(jnp.abs(out).max()) == 0.0


def test_routed_swiglu_rejects_unaligned_tokens():
    rng = np.random.default_rng(1)
    x = rand(rng, 30, 8)  # not a multiple of tile_t
    w = rand(rng, 1, 8, 8)
    with pytest.raises(AssertionError):
        swiglu_k.routed_swiglu(jnp.array(x), jnp.array(w), jnp.array(w),
                               jnp.array(w), jnp.array(rand(rng, 30, 1)),
                               tile_t=16)


def test_gram_additivity_over_chunks():
    # PP^T and YP^T must be additive across column chunks — the invariant the
    # streaming merge path relies on.
    rng = np.random.default_rng(2)
    p = rand(rng, 16, 128)
    y = rand(rng, 8, 128)
    pp, yp = gram_k.gram(jnp.array(p), jnp.array(y), chunk=64)
    pp1, yp1 = ref.gram(p[:, :64], y[:, :64])
    pp2, yp2 = ref.gram(p[:, 64:], y[:, 64:])
    np.testing.assert_allclose(np.asarray(pp), pp1 + pp2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(yp), yp1 + yp2, rtol=1e-4, atol=1e-4)


def test_swiglu_expert_formula():
    # single expert through the kernel == W_D (silu(W_G x) * (W_U x))
    rng = np.random.default_rng(3)
    x = rand(rng, 16, 8)
    wg = rand(rng, 1, 4, 8)
    wu = rand(rng, 1, 4, 8)
    wd = rand(rng, 1, 8, 4)
    r = np.ones((16, 1), np.float32)
    got = swiglu_k.routed_swiglu(
        jnp.array(x), jnp.array(wg), jnp.array(wu), jnp.array(wd), jnp.array(r),
        tile_t=16)
    manual = (jax.nn.silu(x @ wg[0].T) * (x @ wu[0].T)) @ wd[0].T
    np.testing.assert_allclose(np.asarray(got), np.asarray(manual), rtol=1e-5, atol=1e-5)
