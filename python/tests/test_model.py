"""L2 correctness: model blocks, routing semantics, pallas-vs-ref parity of
the full MoE block, and the mapped (Appendix-B) block semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import MODELS, ModelConfig, SEQ_LEN


def tiny_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=16, n_heads=2, d_ff=8,
                n_experts=4, top_k=2, shared_expert=False, seed=7,
                train_steps=1, batch_size=1, merge_targets=(2,))
    base.update(kw)
    return ModelConfig(**base)


def test_param_count_formula_matches_init():
    for cfg in [tiny_cfg(), tiny_cfg(shared_expert=True), MODELS["beta"]]:
        p = M.init_params(cfg)
        total = sum(int(np.prod(v.shape)) for v in p.values())
        assert total == cfg.n_params(), cfg.name


def test_forward_shapes_and_pallas_parity():
    cfg = tiny_cfg(shared_expert=True)
    p = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    tokens = jnp.asarray(np.arange(2 * SEQ_LEN).reshape(2, SEQ_LEN) % cfg.vocab,
                         dtype=jnp.int32)
    ref_logits, _ = M.forward(p, tokens, cfg, use_pallas=False)
    pal_logits, _ = M.forward(p, tokens, cfg, use_pallas=True)
    assert ref_logits.shape == (2, SEQ_LEN, cfg.vocab)
    np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(pal_logits),
                               rtol=2e-4, atol=2e-4)


def test_route_topk_semantics():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((10, 16)).astype(np.float32))
    router = jnp.asarray(rng.standard_normal((6, 16)).astype(np.float32))
    r, probs, idx, w = M.route(x, router, 2)
    probs = np.asarray(probs)
    r = np.asarray(r)
    for t in range(10):
        nz = np.nonzero(r[t])[0]
        assert len(nz) == 2
        # selected weights are the top-2 softmax entries, unrenormalized
        top2 = np.sort(probs[t])[-2:]
        np.testing.assert_allclose(np.sort(r[t][nz]), top2, rtol=1e-6)
        # every unselected prob is <= min selected
        assert probs[t][~np.isin(np.arange(6), nz)].max() <= r[t][nz].min() + 1e-6


def test_moe_block_mapped_identity_equals_plain_block():
    cfg = tiny_cfg()
    p = M.init_params(cfg)
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.standard_normal((1, SEQ_LEN, cfg.d_model)).astype(np.float32))
    args = (h, p["L0.ln2_g"], p["L0.ln2_b"], p["L0.router"],
            p["L0.wg"], p["L0.wu"], p["L0.wd"], None, cfg.top_k, False)
    out_plain, counts_p, idx_p, w_p = M.moe_block(*args)
    out_mapped, counts_m, idx_m, w_m = M.moe_block_mapped(
        h, p["L0.ln2_g"], p["L0.ln2_b"], p["L0.router"],
        jnp.eye(cfg.n_experts), p["L0.wg"], p["L0.wu"], p["L0.wd"],
        None, cfg.top_k, False)
    np.testing.assert_allclose(np.asarray(out_plain), np.asarray(out_mapped),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(counts_p), np.asarray(counts_m))
    np.testing.assert_allclose(np.asarray(idx_p), np.asarray(idx_m))


def test_moe_block_mapped_sums_cluster_mass():
    # A-matrix with two clusters: routed mass must be preserved exactly
    cfg = tiny_cfg()
    p = M.init_params(cfg)
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.standard_normal((1, SEQ_LEN, cfg.d_model)).astype(np.float32))
    amap = jnp.asarray(np.array([[1, 1, 0, 0], [0, 0, 1, 1]], np.float32))
    # merged experts: first two stacked rows of the originals (values don't
    # matter for the mass check — we inspect counts)
    wg = p["L0.wg"][:2]
    wu = p["L0.wu"][:2]
    wd = p["L0.wd"][:2]
    _, counts, idx, w = M.moe_block_mapped(
        h, p["L0.ln2_g"], p["L0.ln2_b"], p["L0.router"], amap,
        wg, wu, wd, None, cfg.top_k, False)
    assert counts.shape == (2,)
    # every token selects top-2 of 4 originals; each maps into one of the 2
    # clusters, so total dispatch count is between T and 2T
    total = float(np.asarray(counts).sum())
    assert SEQ_LEN <= total <= 2 * SEQ_LEN


def test_loss_decreases_on_tiny_batch():
    import jax
    cfg = tiny_cfg()
    p = {k: jnp.asarray(v) for k, v in M.init_params(cfg).items()}
    rng = np.random.default_rng(3)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (4, SEQ_LEN)), dtype=jnp.int32)
    tgt = jnp.asarray(rng.integers(0, cfg.vocab, (4, SEQ_LEN)), dtype=jnp.int32)
    loss_fn = lambda p_: M.loss_fn(p_, tok, tgt, cfg)[0]
    l0 = float(loss_fn(p))
    g = jax.grad(loss_fn)(p)
    p2 = {k: v - 0.05 * g[k] for k, v in p.items()}
    l1 = float(loss_fn(p2))
    assert l1 < l0, (l0, l1)


def test_layernorm_matches_numpy():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((5, 16)).astype(np.float32)
    g = rng.standard_normal(16).astype(np.float32)
    b = rng.standard_normal(16).astype(np.float32)
    got = np.asarray(M.layernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b)))
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    want = (x - mu) / np.sqrt(var + 1e-5) * g + b
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
