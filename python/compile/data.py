"""Synthetic multi-domain corpus + task formats.

The paper evaluates on seven NLP benchmarks; we substitute seven synthetic
multiple-choice tasks over a byte-level alphabet (DESIGN.md §2). The *formats*
defined here are mirrored exactly by `rust/src/eval/tasks.rs` — the training
corpus (python, build time) and the evaluation items (rust, run time) must
agree on every delimiter. Each task line looks like

  copy      c:WORD|WORD.          copy the word
  rev       r:WORD|DROW.          reverse the word
  sort      s:WORD|ADORSTW.       sort the letters
  arith     a:12+34=46.           2-digit addition (operands 10..49)
  parity    p:010110#e.           e/o = even/odd number of '1's
  maj       m:abbab!b.            majority letter of an odd-length a/b string
  markov    t:qwertyu...          order-1 markov chain text (see chain below)

A corpus document is task lines joined by '\n', tokenized with CHARSET.
"""

import numpy as np

from .configs import CHARSET, SEQ_LEN

C2I = {c: i for i, c in enumerate(CHARSET)}
LETTERS = CHARSET[:26]
TASKS = ("copy", "rev", "sort", "arith", "parity", "maj", "markov")

# --- order-1 markov chain over the 26 letters -------------------------------
# Successors of letter c are s0=(7c+3)%26, s1=(11c+5)%26, s2=(13c+1)%26 with
# probabilities 0.6/0.3/0.1. The "correct" continuation of a prompt is the
# greedy (always-s0) path; rust mirrors these constants.
MK_COEF = ((7, 3), (11, 5), (13, 1))
MK_PROB = (0.6, 0.3, 0.1)


def mk_succ(c: int, k: int) -> int:
    a, b = MK_COEF[k]
    return (a * c + b) % 26


def markov_sample(rng: np.random.RandomState, start: int, length: int) -> str:
    out, c = [], start
    for _ in range(length):
        out.append(LETTERS[c])
        r = rng.random_sample()
        k = 0 if r < MK_PROB[0] else (1 if r < MK_PROB[0] + MK_PROB[1] else 2)
        c = mk_succ(c, k)
    return "".join(out)


def markov_greedy(start: int, length: int) -> str:
    out, c = [], start
    for _ in range(length):
        out.append(LETTERS[c])
        c = mk_succ(c, 0)
    return "".join(out)


# --- task line generators (training corpus uses the *correct* completion) ---

def _word(rng, lo=4, hi=8):
    n = rng.randint(lo, hi + 1)
    return "".join(LETTERS[rng.randint(0, 26)] for _ in range(n))


def gen_line(task: str, rng: np.random.RandomState) -> str:
    if task == "copy":
        w = _word(rng)
        return f"c:{w}|{w}."
    if task == "rev":
        w = _word(rng)
        return f"r:{w}|{w[::-1]}."
    if task == "sort":
        w = _word(rng)
        return f"s:{w}|{''.join(sorted(w))}."
    if task == "arith":
        a, b = rng.randint(10, 50), rng.randint(10, 50)
        return f"a:{a}+{b}={a + b}."
    if task == "parity":
        n = rng.randint(6, 13)
        bits = "".join("01"[rng.randint(0, 2)] for _ in range(n))
        return f"p:{bits}#{'e' if bits.count('1') % 2 == 0 else 'o'}."
    if task == "maj":
        n = rng.choice([5, 7, 9, 11])
        s = "".join("ab"[rng.randint(0, 2)] for _ in range(n))
        return f"m:{s}!{'a' if s.count('a') > n // 2 else 'b'}."
    if task == "markov":
        return "t:" + markov_sample(rng, rng.randint(0, 26), rng.randint(18, 30))
    raise ValueError(task)


def encode(s: str) -> np.ndarray:
    return np.array([C2I[c] for c in s], dtype=np.int32)


def corpus_batches(seed: int, batch_size: int, n_steps: int):
    """Yield (batch, targets) int32 arrays of shape (batch_size, SEQ_LEN).

    Documents are task lines (uniform mixture over the seven domains) joined
    by newlines and packed into fixed-length windows; the targets are the
    inputs shifted by one (standard next-token LM objective).
    """
    rng = np.random.RandomState(seed)
    # Hard tasks (parity, arith, copy, rev, sort) get extra corpus weight so
    # the small models reach clearly-above-chance accuracy within the
    # build-time training budget; the mixture is a training choice only and
    # not part of the format contract with the rust side.
    weighted = ("copy", "copy", "rev", "rev", "sort", "sort",
                "arith", "arith", "arith", "parity", "parity", "parity",
                "maj", "markov")
    buf = []
    for _ in range(n_steps):
        batch = np.zeros((batch_size, SEQ_LEN + 1), dtype=np.int32)
        for i in range(batch_size):
            while len(buf) < SEQ_LEN + 1:
                buf.extend(encode(gen_line(weighted[rng.randint(0, len(weighted))], rng)))
                buf.append(C2I["\n"])
            batch[i] = buf[: SEQ_LEN + 1]
            del buf[: SEQ_LEN + 1]
        yield batch[:, :-1], batch[:, 1:]


def charset_fingerprint() -> int:
    """Order-sensitive checksum mirrored by rust to guarantee identical vocab."""
    h = 0
    for i, c in enumerate(CHARSET):
        h = (h * 131 + ord(c) * (i + 7)) % 1_000_000_007
    return h
