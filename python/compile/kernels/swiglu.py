"""Pallas kernel for the routed-SwiGLU expert mixture — the model's hot-spot.

GPU -> TPU adaptation (DESIGN.md §3): the CUDA implementation the paper's
models run on launches one threadblock per (expert, token-tile) and stages
expert weights through shared memory. Here the same schedule is expressed as a
Pallas grid over (expert, token-tile) with BlockSpecs staging the expert's
three projection matrices and one token tile through VMEM; the MXU consumes
(tile_t × d)·(d × f) blocks and the output tile is accumulated across the
expert grid dimension in place (the revisiting-output accumulation pattern,
the TPU analogue of a split-K atomic add).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO. Real-TPU VMEM/MXU
estimates for this BlockSpec live in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, r_ref, o_ref):
    """One (expert e, token-tile t) grid step.

    x_ref (tile_t, d)    token tile                      (VMEM)
    wg_ref/wu_ref (1, f, d), wd_ref (1, d, f)            expert e's weights
    r_ref (tile_t, 1)    routing weights of the tile for expert e
    o_ref (tile_t, d)    output tile, accumulated over the e grid dim
    """
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    g = jnp.dot(x, wg_ref[0].T, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[0].T, preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u
    y = jnp.dot(h, wd_ref[0].T, preferred_element_type=jnp.float32)
    o_ref[...] += y * r_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_t",))
def routed_swiglu(x, wg, wu, wd, r, *, tile_t: int = 64):
    """Mixture of SwiGLU experts: see kernels.ref.routed_swiglu for semantics.

    x (t,d), wg/wu (e,f,d), wd (e,d,f), r (t,e) -> (t,d).
    `t` must be a multiple of tile_t (callers pad; the batcher's shape buckets
    guarantee it on the request path).
    """
    t, d = x.shape
    e, f, _ = wg.shape
    assert t % tile_t == 0, (t, tile_t)
    grid = (e, t // tile_t)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda ei, ti: (ti, 0)),
            pl.BlockSpec((1, f, d), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, f, d), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, d, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((tile_t, 1), lambda ei, ti: (ti, ei)),
        ],
        out_specs=pl.BlockSpec((tile_t, d), lambda ei, ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, wg, wu, wd, r)
