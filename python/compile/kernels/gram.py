"""Pallas kernel for the MergeMoE least-squares Gram accumulation.

The merge-time hot-spot of the paper's algorithm is building the normal
equations for `T1 = Q P^+` over calibration samples: `PP^T` (f×f) and
`YP^T` (d×f), streamed over sample columns. On GPU this is a split-K GEMM;
on TPU we express it as a Pallas grid over sample chunks with the two Gram
blocks accumulated in the (revisited) output tiles — both stay resident in
VMEM for the whole sweep, which is the optimal schedule whenever
f·f + d·f floats fit (always true here: f=d=64 .. 256).

interpret=True (see swiglu.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, y_ref, pp_ref, yp_ref):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        yp_ref[...] = jnp.zeros_like(yp_ref)

    p = p_ref[...]
    pp_ref[...] += jnp.dot(p, p.T, preferred_element_type=jnp.float32)
    yp_ref[...] += jnp.dot(y_ref[...], p.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk",))
def gram(p, y, *, chunk: int = 256):
    """P (f,s), Y (d,s) -> (PP^T (f,f), YP^T (d,f)). s % chunk == 0."""
    f, s = p.shape
    d, _ = y.shape
    assert s % chunk == 0, (s, chunk)
    return pl.pallas_call(
        _kernel,
        grid=(s // chunk,),
        in_specs=[
            pl.BlockSpec((f, chunk), lambda si: (0, si)),
            pl.BlockSpec((d, chunk), lambda si: (0, si)),
        ],
        out_specs=[
            pl.BlockSpec((f, f), lambda si: (0, 0)),
            pl.BlockSpec((d, f), lambda si: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((f, f), jnp.float32),
            jax.ShapeDtypeStruct((d, f), jnp.float32),
        ],
        interpret=True,
    )(p, y)
