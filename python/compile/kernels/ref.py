"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Everything here is straight-line jnp with no pallas, no custom control flow:
slow but obviously right. `test_kernels.py` sweeps the pallas implementations
against these with hypothesis; the rust native engine is in turn validated
against HLO artifacts built from the pallas path, closing the loop
pallas == ref == rust.
"""

import jax.numpy as jnp
import jax.nn


def swiglu(x, wg, wu, wd):
    """Single SwiGLU expert: x (t,d), wg/wu (f,d), wd (d,f) -> (t,d)."""
    g = x @ wg.T
    u = x @ wu.T
    return (jax.nn.silu(g) * u) @ wd.T


def routed_swiglu(x, wg, wu, wd, r):
    """Routed mixture of SwiGLU experts.

    x  (t, d)      tokens
    wg (e, f, d)   gate projections
    wu (e, f, d)   up projections
    wd (e, d, f)   down projections
    r  (t, e)      dense routing weights (0 for unrouted token/expert pairs)
    -> (t, d)      sum_e r[:, e] * swiglu_e(x)
    """
    g = jnp.einsum("td,efd->tef", x, wg)
    u = jnp.einsum("td,efd->tef", x, wu)
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,edf->ted", h, wd)
    return jnp.einsum("ted,te->td", y, r)


def gram(p, y):
    """Streaming least-squares accumulators: P (f,s), Y (d,s).

    Returns (P P^T, Y P^T) — the two Gram blocks consumed by the ridge solve
    W_D' = (Y P^T)(P P^T + λI)^{-1} that is the heart of MergeMoE.
    """
    return p @ p.T, y @ p.T
