"""L2: the MoE transformer in JAX.

One model definition serves three consumers:

* `train.py` differentiates `loss_fn` (uses the jnp reference expert mixture,
  which is autodiff-friendly);
* `aot.py` lowers the *pallas* path (`use_pallas=True`) per layer type to the
  HLO artifacts the rust runtime executes — every weight is a runtime
  parameter so one executable serves both original and merged weights;
* `tests/` cross-checks the two paths against each other.

Weight naming convention (flat npz keys consumed by rust/src/model/):
  tok_emb (V,d)  pos_emb (S,d)
  L{i}.ln1_g/ln1_b (d,)  L{i}.wq/wk/wv/wo (d,d)
  L{i}.ln2_g/ln2_b (d,)  L{i}.router (E,d)
  L{i}.wg/wu (E,f,d)  L{i}.wd (E,d,f)
  L{i}.swg/swu (f,d)  L{i}.swd (d,f)        [only if shared_expert]
  lnf_g/lnf_b (d,)  head (V,d)
All linear layers use the y = x @ W^T convention.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, SEQ_LEN
from .kernels import ref
from .kernels.swiglu import routed_swiglu as pallas_routed_swiglu

LN_EPS = 1e-5


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig) -> dict:
    rng = np.random.RandomState(cfg.seed)
    d, f, v, e = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_experts

    def w(*shape, scale=None):
        s = scale if scale is not None else 1.0 / np.sqrt(shape[-1])
        return (rng.randn(*shape) * s).astype(np.float32)

    p = {
        "tok_emb": w(v, d, scale=0.02),
        "pos_emb": w(SEQ_LEN, d, scale=0.02),
        "lnf_g": np.ones(d, np.float32),
        "lnf_b": np.zeros(d, np.float32),
        "head": w(v, d),
    }
    for i in range(cfg.n_layers):
        p[f"L{i}.ln1_g"] = np.ones(d, np.float32)
        p[f"L{i}.ln1_b"] = np.zeros(d, np.float32)
        p[f"L{i}.ln2_g"] = np.ones(d, np.float32)
        p[f"L{i}.ln2_b"] = np.zeros(d, np.float32)
        for nm in ("wq", "wk", "wv", "wo"):
            p[f"L{i}.{nm}"] = w(d, d)
        p[f"L{i}.router"] = w(e, d)
        p[f"L{i}.wg"] = w(e, f, d)
        p[f"L{i}.wu"] = w(e, f, d)
        p[f"L{i}.wd"] = w(e, d, f)
        if cfg.shared_expert:
            p[f"L{i}.swg"] = w(f, d)
            p[f"L{i}.swu"] = w(f, d)
            p[f"L{i}.swd"] = w(d, f)
    return p


# --------------------------------------------------------------------------
# blocks (batch-of-sequences shapes: h is (B, S, d))
# --------------------------------------------------------------------------

def layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS) * g + b


def embed(p, tokens):
    """tokens (B,S) int32 -> h (B,S,d)."""
    return p["tok_emb"][tokens] + p["pos_emb"][None, : tokens.shape[1]]


def attn_block(h, ln_g, ln_b, wq, wk, wv, wo, n_heads: int):
    """Pre-LN causal multi-head attention with residual."""
    b, s, d = h.shape
    hd = d // n_heads
    x = layernorm(h, ln_g, ln_b)
    q = (x @ wq.T).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ wk.T).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ wv.T).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqe,bhke->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhke->bhqe", att, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    return h + o @ wo.T


def route(x2d, router, top_k: int, via_sort: bool = False):
    """Paper §3.1 routing: probs = softmax(W_r X); keep top-K entries.

    Returns (dense routing matrix r (t,e), probs (t,e), idx (t,K), w (t,K)).
    The top-K softmax entries are used as-is (no renormalization), exactly as
    in Eq. 1's mask_top_K formulation.

    `via_sort` selects an argsort-based top-k for the AOT path: lax.top_k
    lowers to the `topk` HLO instruction, which xla_extension 0.5.1's text
    parser cannot read; stable argsort of -probs reproduces lax.top_k's
    tie-break (lower index first) and lowers to the classic `sort` op.
    The training path keeps lax.top_k (this image's jax/jaxlib pairing
    mis-handles the batched gather that argsort+take_along_axis emits under
    autodiff).
    """
    logits = x2d @ router.T
    probs = jax.nn.softmax(logits, axis=-1)
    if via_sort:
        idx = jnp.argsort(-probs, axis=-1)[:, :top_k]
        w = jnp.take_along_axis(probs, idx, axis=-1)
    else:
        w, idx = jax.lax.top_k(probs, top_k)
    r = jnp.zeros_like(probs).at[jnp.arange(x2d.shape[0])[:, None], idx].set(w)
    return r, probs, idx, w


def moe_block(h, ln_g, ln_b, router, wg, wu, wd, shared, top_k: int,
              use_pallas: bool):
    """Pre-LN MoE MLP with residual.

    Returns (h', counts (E,), idx (B,S,K), w (B,S,K)); counts feed the
    usage-frequency statistics that Theorem 1's weights are built from.
    """
    b, s, d = h.shape
    x = layernorm(h, ln_g, ln_b).reshape(b * s, d)
    r, probs, idx, w = route(x, router, top_k, via_sort=use_pallas)
    fn = pallas_routed_swiglu if use_pallas else ref.routed_swiglu
    y = fn(x, wg, wu, wd, r)
    if shared is not None:
        swg, swu, swd = shared
        y = y + ref.swiglu(x, swg, swu, swd)
    counts = (r > 0).astype(jnp.float32).sum(0)
    return (h + y.reshape(b, s, d), counts,
            idx.reshape(b, s, top_k), w.reshape(b, s, top_k))


def moe_block_mapped(h, ln_g, ln_b, router, amap, wg, wu, wd, shared, top_k,
                     use_pallas: bool):
    """MoE block with an explicit routing map (the paper's Appendix-B layout).

    The router stays N-way (N = original expert count, rows of `router`);
    after top-K masking, the routing vector r (N,) is transformed by
    `amap` (M, N) and dispatched to the M *real* experts:

      amap = I    : uncompressed layer (M = N)
      amap = A    : merged layer (summation matrix of Eq. 2; the N->M
                    "expert references" of Appendix B)
      amap = B·A  : Table-5 oracle — original experts kept, outputs merged
                    exactly ("w/o merging errors")

    Returns (h', counts over the M real experts, N-way top-K idx/weights).
    """
    b, s, d = h.shape
    x = layernorm(h, ln_g, ln_b).reshape(b * s, d)
    r, _, idx, w = route(x, router, top_k, via_sort=True)
    r = r @ amap.T
    fn = pallas_routed_swiglu if use_pallas else ref.routed_swiglu
    y = fn(x, wg, wu, wd, r)
    if shared is not None:
        y = y + ref.swiglu(x, *shared)
    counts = (r > 0).astype(jnp.float32).sum(0)
    return (h + y.reshape(b, s, d), counts,
            idx.reshape(b, s, top_k), w.reshape(b, s, top_k))


def lm_head(p, h):
    x = layernorm(h, p["lnf_g"], p["lnf_b"])
    return x @ p["head"].T


def forward(p, tokens, cfg: ModelConfig, use_pallas: bool = False):
    """Full LM forward: tokens (B,S) -> logits (B,S,V). Also returns the
    per-layer (counts, mean router prob) stats for the load-balance loss."""
    h = embed(p, tokens)
    aux = []
    for i in range(cfg.n_layers):
        h = attn_block(h, p[f"L{i}.ln1_g"], p[f"L{i}.ln1_b"], p[f"L{i}.wq"],
                       p[f"L{i}.wk"], p[f"L{i}.wv"], p[f"L{i}.wo"], cfg.n_heads)
        shared = ((p[f"L{i}.swg"], p[f"L{i}.swu"], p[f"L{i}.swd"])
                  if cfg.shared_expert else None)
        x_ln = layernorm(h, p[f"L{i}.ln2_g"], p[f"L{i}.ln2_b"])
        probs = jax.nn.softmax(
            x_ln.reshape(-1, cfg.d_model) @ p[f"L{i}.router"].T, -1)
        h, counts, _, _ = moe_block(
            h, p[f"L{i}.ln2_g"], p[f"L{i}.ln2_b"], p[f"L{i}.router"],
            p[f"L{i}.wg"], p[f"L{i}.wu"], p[f"L{i}.wd"], shared,
            cfg.top_k, use_pallas)
        aux.append((counts, probs.mean(0)))
    return lm_head(p, h), aux


def loss_fn(p, tokens, targets, cfg: ModelConfig, aux_weight: float = 1e-2):
    """Next-token cross entropy + Switch-style load-balance auxiliary loss."""
    logits, aux = forward(p, tokens, cfg, use_pallas=False)
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, targets[..., None], -1).mean()
    bal = 0.0
    n_tok = tokens.shape[0] * tokens.shape[1]
    for counts, mean_prob in aux:
        frac = counts / (n_tok * cfg.top_k)
        bal = bal + cfg.n_experts * jnp.sum(frac * mean_prob)
    return nll + aux_weight * bal / cfg.n_layers, nll
