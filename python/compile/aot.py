"""AOT lowering: JAX (L2, calling the L1 pallas kernels) -> HLO text artifacts.

Emits one executable per *layer type × shape signature* so the rust
coordinator can compose models whose layers have heterogeneous expert counts
(compressed layers use the `moe_*_e{M}_*` artifact, untouched layers the
`e{N}` one). Every weight is a runtime parameter: one executable serves
original and merged weights of the same shape.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids (see
/opt/xla-example/README.md). Lowered with return_tuple=True; rust unwraps
with `decompose_tuple`.

artifacts/manifest.json records, for every artifact, the ordered parameter
list (name, shape, dtype) and output list, plus the model configurations and
the charset fingerprint — the rust side is entirely manifest-driven.

Usage: python -m compile.aot [--out ../artifacts] [--skip-train-check]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import MODELS, SEQ_LEN, BATCH_BUCKETS, GRAM_COLS, VOCAB
from .data import charset_fingerprint
from . import model as M
from .kernels.gram import gram as pallas_gram

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# --------------------------------------------------------------------------
# layer-type entry points (weights as positional args, fixed order)
# --------------------------------------------------------------------------

def embed_fn(tokens, tok_emb, pos_emb):
    return (tok_emb[tokens] + pos_emb[None, : tokens.shape[1]],)


def attn_fn(n_heads, h, ln_g, ln_b, wq, wk, wv, wo):
    return (M.attn_block(h, ln_g, ln_b, wq, wk, wv, wo, n_heads),)


def moe_fn(top_k, h, ln_g, ln_b, router, amap, wg, wu, wd, *shared):
    """Unified MoE block artifact (Appendix-B layout): the router stays
    N-way and `amap` (M,N) redirects routing mass to the M real experts —
    identity for uncompressed layers, A for merged layers, B·A for the
    Table-5 oracle. See model.moe_block_mapped."""
    sh = tuple(shared) if shared else None
    return M.moe_block_mapped(h, ln_g, ln_b, router, amap, wg, wu, wd, sh,
                              top_k, use_pallas=True)


def lmhead_fn(h, lnf_g, lnf_b, head):
    x = M.layernorm(h, lnf_g, lnf_b)
    logits = x @ head.T
    return (logits, jax.nn.log_softmax(logits, axis=-1))


def monolith_fn(cfg, tokens, *weights):
    keys = monolith_keys(cfg)
    p = dict(zip(keys, weights))
    logits, _ = M.forward(p, tokens, cfg, use_pallas=True)
    return (logits,)


def monolith_keys(cfg):
    keys = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        keys += [f"L{i}.{n}" for n in
                 ("ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
                  "ln2_g", "ln2_b", "router", "wg", "wu", "wd")]
        if cfg.shared_expert:
            keys += [f"L{i}.swg", f"L{i}.swu", f"L{i}.swd"]
    keys += ["lnf_g", "lnf_b", "head"]
    return keys


def gram_fn(p, y):
    pp, yp = pallas_gram(p, y)
    return (pp, yp)


# --------------------------------------------------------------------------
# artifact enumeration
# --------------------------------------------------------------------------

def _params(*items):
    return [{"name": n, "shape": list(s), "dtype": d} for n, s, d in items]


def build_manifest():
    """Enumerate every artifact (deduplicated by shape signature)."""
    arts = {}

    def add(name, fn, params, outputs, meta=None):
        if name in arts:
            return
        arts[name] = {"fn": fn, "params": params, "outputs": outputs,
                      "meta": meta or {}}

    d_set = sorted({(c.d_model, c.n_heads) for c in MODELS.values()})
    for b in BATCH_BUCKETS:
        add(f"embed_v{VOCAB}_d64_b{b}",
            embed_fn,
            _params(("tokens", (b, SEQ_LEN), I32),
                    ("tok_emb", (VOCAB, 64), F32),
                    ("pos_emb", (SEQ_LEN, 64), F32)),
            [{"shape": [b, SEQ_LEN, 64], "dtype": F32}])
        for d, h in d_set:
            add(f"attn_d{d}_h{h}_b{b}",
                functools.partial(attn_fn, h),
                _params(("h", (b, SEQ_LEN, d), F32),
                        ("ln1_g", (d,), F32), ("ln1_b", (d,), F32),
                        ("wq", (d, d), F32), ("wk", (d, d), F32),
                        ("wv", (d, d), F32), ("wo", (d, d), F32)),
                [{"shape": [b, SEQ_LEN, d], "dtype": F32}])
            add(f"lmhead_v{VOCAB}_d{d}_b{b}",
                lmhead_fn,
                _params(("h", (b, SEQ_LEN, d), F32),
                        ("lnf_g", (d,), F32), ("lnf_b", (d,), F32),
                        ("head", (VOCAB, d), F32)),
                [{"shape": [b, SEQ_LEN, VOCAB], "dtype": F32},
                 {"shape": [b, SEQ_LEN, VOCAB], "dtype": F32}])

    # moe blocks: every (d, f, N router rows, M real experts, K, shared)
    # signature any experiment needs. (N,N) doubles as the oracle artifact
    # (amap = B·A) and the uncompressed layer (amap = I).
    for cfg in MODELS.values():
        d, f, k = cfg.d_model, cfg.d_ff, cfg.top_k
        n = cfg.n_experts
        m_set = {n, *cfg.merge_targets}
        sh = cfg.shared_expert
        for m in sorted(m_set):
            for b in BATCH_BUCKETS:
                sig = f"moe_d{d}_f{f}_n{n}_m{m}_k{k}_{'sh' if sh else 'ns'}_b{b}"
                shared_params = (_params((f"swg", (f, d), F32),
                                         (f"swu", (f, d), F32),
                                         (f"swd", (d, f), F32)) if sh else [])
                add(sig, functools.partial(moe_fn, k),
                    _params(("h", (b, SEQ_LEN, d), F32),
                            ("ln2_g", (d,), F32), ("ln2_b", (d,), F32),
                            ("router", (n, d), F32),
                            ("amap", (m, n), F32),
                            ("wg", (m, f, d), F32), ("wu", (m, f, d), F32),
                            ("wd", (m, d, f), F32)) + shared_params,
                    [{"shape": [b, SEQ_LEN, d], "dtype": F32},
                     {"shape": [m], "dtype": F32},
                     {"shape": [b, SEQ_LEN, k], "dtype": I32},
                     {"shape": [b, SEQ_LEN, k], "dtype": F32}])

    # monolithic full-model forwards (per-layer-dispatch overhead ablation)
    for cfg in MODELS.values():
        if not cfg.merge_targets:
            continue
        for b in BATCH_BUCKETS:
            keys = monolith_keys(cfg)
            init = M.init_params(cfg)
            params = _params(("tokens", (b, SEQ_LEN), I32)) + _params(
                *((k_, init[k_].shape, F32) for k_ in keys))
            add(f"monolith_{cfg.name}_b{b}",
                functools.partial(monolith_fn, cfg), params,
                [{"shape": [b, SEQ_LEN, VOCAB], "dtype": F32}],
                meta={"model": cfg.name, "keys": keys})

    # gram accumulators for the lstsq solve (merge-time hot path)
    for cfg in MODELS.values():
        if not cfg.merge_targets:
            continue
        d, f = cfg.d_model, cfg.d_ff
        for s in GRAM_COLS:
            add(f"gram_f{f}_d{d}_s{s}", gram_fn,
                _params(("p", (f, s), F32), ("y", (d, s), F32)),
                [{"shape": [f, f], "dtype": F32},
                 {"shape": [d, f], "dtype": F32}])
    return arts


def lower_artifact(name, art, out_dir):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    if os.path.exists(path):
        return False
    args = [spec(tuple(p["shape"]), jnp.int32 if p["dtype"] == I32 else jnp.float32)
            for p in art["params"]]
    lowered = jax.jit(art["fn"]).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as fp:
        fp.write(text)
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-list of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    arts = build_manifest()
    only = set(args.only.split(",")) if args.only else None
    n_new = 0
    for name, art in arts.items():
        if only and name not in only:
            continue
        if lower_artifact(name, art, args.out):
            n_new += 1
            print(f"lowered {name}")
    manifest = {
        "charset_fingerprint": charset_fingerprint(),
        "seq_len": SEQ_LEN,
        "vocab": VOCAB,
        "batch_buckets": list(BATCH_BUCKETS),
        "gram_cols": list(GRAM_COLS),
        "models": {n: c.to_json() for n, c in MODELS.items()},
        "artifacts": {
            n: {"file": f"{n}.hlo.txt", "params": a["params"],
                "outputs": a["outputs"], "meta": a["meta"]}
            for n, a in arts.items()
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as fp:
        json.dump(manifest, fp, indent=1)
    print(f"{n_new} artifacts lowered, manifest: {len(arts)} entries")


if __name__ == "__main__":
    main()
