"""Model configurations for the MergeMoE reproduction.

Three MoE models mirror the paper's three evaluation targets (see DESIGN.md §2):

  alpha  ~ Qwen3-30B-A3B      : no shared expert, many experts, top-2
  beta   ~ Qwen1.5-MoE-A2.7B  : shared expert, top-2
  gamma  ~ DeepSeekMoE-16B    : shared expert, higher K, odd merge target

Three dense models provide the paper's dense-baseline rows; a dense model is
simply an MoE model with a single always-selected expert (E=1, K=1), which lets
every code path (python training, HLO artifacts, rust engines) be shared.

This file is the single source of truth for model shapes; `aot.py` derives the
artifact manifest from it and the rust side reads the JSON it emits.
"""

from dataclasses import dataclass, asdict, field


# Byte-level alphabet shared with the rust evaluation harness
# (rust/src/eval/tasks.rs mirrors this string; tests on both sides assert on a
# SHA-ish fingerprint so the two can never drift silently).
CHARSET = "abcdefghijklmnopqrstuvwxyz0123456789:|.+=#!>? \n"
VOCAB = len(CHARSET)  # 47

SEQ_LEN = 64
BATCH_BUCKETS = (1, 8, 32)  # request-batch buckets served by the rust batcher
GRAM_COLS = (256, 1024)  # sample-column buckets for the gram artifact


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int  # per-expert hidden width f
    n_experts: int  # N (routed experts)
    top_k: int  # K
    shared_expert: bool  # DeepSeek/Qwen1.5-style shared expert (d_ff width)
    seed: int
    train_steps: int
    batch_size: int  # sequences per training step
    lr: float = 3e-3
    # expert counts for which merged-layer HLO artifacts must exist
    merge_targets: tuple = field(default=())

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab(self) -> int:
        return VOCAB

    def n_params(self) -> int:
        d, f, v = self.d_model, self.d_ff, VOCAB
        emb = v * d + SEQ_LEN * d
        attn = 4 * d * d + 2 * d
        router = self.n_experts * d
        experts = self.n_experts * 3 * f * d
        shared = 3 * f * d if self.shared_expert else 0
        per_layer = attn + router + experts + shared + 2 * d
        return emb + self.n_layers * per_layer + v * d + 2 * d

    def to_json(self) -> dict:
        out = asdict(self)
        out["vocab"] = VOCAB
        out["seq_len"] = SEQ_LEN
        out["n_params"] = self.n_params()
        out["merge_targets"] = list(self.merge_targets)
        return out


MODELS = {
    # ~Qwen3-30B-A3B analogue: no shared expert; table 1 merges 16 -> 8.
    "alpha": ModelConfig(
        name="alpha", n_layers=4, d_model=64, n_heads=4, d_ff=64,
        n_experts=16, top_k=2, shared_expert=False, seed=101,
        train_steps=1400, batch_size=16, merge_targets=(8,),
    ),
    # ~Qwen1.5-MoE-A2.7B analogue: shared expert; table 2 / figs 2-4 merge
    # 12 -> 6 and sweep the reduced-expert count (fig 2a) from 2 to 12.
    "beta": ModelConfig(
        name="beta", n_layers=4, d_model=64, n_heads=4, d_ff=64,
        n_experts=12, top_k=2, shared_expert=True, seed=202,
        train_steps=1400, batch_size=16, merge_targets=(2, 3, 4, 6, 8, 10),
    ),
    # ~DeepSeekMoE-16B analogue: shared expert, higher K, odd target (16->7).
    "gamma": ModelConfig(
        name="gamma", n_layers=5, d_model=64, n_heads=4, d_ff=64,
        n_experts=16, top_k=4, shared_expert=True, seed=303,
        train_steps=1400, batch_size=16, merge_targets=(7,),
    ),
    # Dense baselines (single always-on expert). Sizes chosen so that
    # dense_a / dense_b4 roughly match the *active* parameter count of the
    # compressed alpha / beta models, and dense_b1 is the clearly-smaller
    # baseline (paper's Qwen1.5-1.8B row).
    "dense_a": ModelConfig(
        name="dense_a", n_layers=4, d_model=64, n_heads=4, d_ff=128,
        n_experts=1, top_k=1, shared_expert=False, seed=404,
        train_steps=600, batch_size=16, merge_targets=(),
    ),
    "dense_b4": ModelConfig(
        name="dense_b4", n_layers=4, d_model=64, n_heads=4, d_ff=96,
        n_experts=1, top_k=1, shared_expert=False, seed=505,
        train_steps=600, batch_size=16, merge_targets=(),
    ),
    "dense_b1": ModelConfig(
        name="dense_b1", n_layers=2, d_model=64, n_heads=4, d_ff=48,
        n_experts=1, top_k=1, shared_expert=False, seed=606,
        train_steps=600, batch_size=16, merge_targets=(),
    ),
}
