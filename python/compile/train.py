"""Build-time trainer for the six models (3 MoE targets + 3 dense baselines).

Runs ONCE (cached by weight-file existence; `make artifacts` skips it when
`artifacts/weights_<name>.npz` already exists). Never on the request path.

Outputs per model:
  artifacts/weights_<name>.npz    flat weight dict (model.py naming)
  artifacts/train_log_<name>.json loss curve (recorded in EXPERIMENTS.md)

Usage: python -m compile.train [--models alpha,beta,...] [--out DIR]
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .configs import MODELS, ModelConfig
from .data import corpus_batches
from .model import init_params, loss_fn


def adam_init(p):
    z = {k: np.zeros_like(v) for k, v in p.items()}
    return z, {k: np.zeros_like(v) for k, v in p.items()}


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(p, m, v, step, tokens, targets, cfg: ModelConfig):
    """One Adam step (b1=.9, b2=.98, eps=1e-9) with cosine LR decay."""
    (loss, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        p, tokens, targets, cfg)
    warm, total = 40.0, float(cfg.train_steps)
    lr = cfg.lr * jnp.minimum(step / warm, 1.0) * (
        0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(step / total, 1.0))) * 0.9 + 0.1)
    b1, b2, eps = 0.9, 0.98, 1e-9
    new_p, new_m, new_v = {}, {}, {}
    for k in p:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        mhat = new_m[k] / (1 - b1 ** step)
        vhat = new_v[k] / (1 - b2 ** step)
        new_p[k] = p[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, new_m, new_v, loss, nll


def train_model(cfg: ModelConfig, out_dir: str) -> dict:
    t0 = time.time()
    p = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    m, v = adam_init(p)
    m = {k: jnp.asarray(x) for k, x in m.items()}
    v = {k: jnp.asarray(x) for k, x in v.items()}
    log = {"model": cfg.name, "steps": [], "loss": [], "nll": []}
    batches = corpus_batches(cfg.seed + 7, cfg.batch_size, cfg.train_steps)
    for step, (tok, tgt) in enumerate(batches, start=1):
        p, m, v, loss, nll = train_step(
            p, m, v, jnp.float32(step), jnp.asarray(tok), jnp.asarray(tgt), cfg)
        if step % 20 == 0 or step == 1:
            log["steps"].append(step)
            log["loss"].append(float(loss))
            log["nll"].append(float(nll))
            print(f"[{cfg.name}] step {step:4d}  loss {float(loss):.4f}  "
                  f"nll {float(nll):.4f}  ({time.time()-t0:.0f}s)", flush=True)
    log["wall_seconds"] = time.time() - t0
    np.savez(os.path.join(out_dir, f"weights_{cfg.name}.npz"),
             **{k: np.asarray(x) for k, x in p.items()})
    with open(os.path.join(out_dir, f"train_log_{cfg.name}.json"), "w") as f:
        json.dump(log, f)
    print(f"[{cfg.name}] done in {log['wall_seconds']:.0f}s", flush=True)
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(MODELS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.models.split(","):
        cfg = MODELS[name]
        path = os.path.join(args.out, f"weights_{name}.npz")
        if os.path.exists(path) and not args.force:
            print(f"[{name}] cached at {path}, skipping")
            continue
        train_model(cfg, args.out)


if __name__ == "__main__":
    main()
