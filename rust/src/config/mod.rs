//! Configuration system: model configs and the AOT artifact manifest.
//!
//! Everything the runtime knows about shapes comes from
//! `artifacts/manifest.json`, written by `python/compile/aot.py`. The rust
//! side never hard-codes a tensor shape: artifacts are looked up by semantic
//! key (layer type + shape signature) built from the [`ModelConfig`]s that
//! the same manifest carries.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Dtype of an artifact parameter/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }
}

/// One parameter of an HLO artifact (ordered).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// One output of an HLO artifact (ordered; artifacts return tuples).
#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// Manifest entry for one AOT-lowered executable.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<OutputSpec>,
    /// monolith artifacts carry the ordered weight-key list here
    pub monolith_keys: Option<Vec<String>>,
}

/// Mirror of `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub shared_expert: bool,
    pub n_params: usize,
    pub merge_targets: Vec<usize>,
}

impl ModelConfig {
    /// Parameter count of one routed expert (the unit of memory saving).
    pub fn expert_params(&self) -> usize {
        3 * self.d_ff * self.d_model
    }

    /// Serialize to the JSON shape [`ModelConfig::from_json`] (and the
    /// artifact manifest parser) accepts. Registry manifests embed this as
    /// their `arch` field so a variant is loadable from a bare registry,
    /// without the artifacts manifest.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("n_layers", Json::num(self.n_layers as f64)),
            ("d_model", Json::num(self.d_model as f64)),
            ("n_heads", Json::num(self.n_heads as f64)),
            ("d_ff", Json::num(self.d_ff as f64)),
            ("n_experts", Json::num(self.n_experts as f64)),
            ("top_k", Json::num(self.top_k as f64)),
            ("shared_expert", Json::Bool(self.shared_expert)),
            ("n_params", Json::num(self.n_params as f64)),
            (
                "merge_targets",
                Json::arr(self.merge_targets.iter().map(|&m| Json::num(m as f64))),
            ),
        ])
    }

    /// Parse a config serialized by [`ModelConfig::to_json`] (same field
    /// set the artifact manifest uses for its `models` entries).
    pub fn from_json(name: &str, j: &Json) -> Result<ModelConfig> {
        parse_model(name, j)
    }

    /// Total parameter count if `merged_layers` layers are reduced to `m`
    /// experts each — the "Model Size" column of Tables 1–3.
    pub fn params_after_merge(&self, merged_layers: usize, m: usize) -> usize {
        self.n_params - merged_layers * (self.n_experts - m) * self.expert_params()
    }
}

/// Parsed artifact manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seq_len: usize,
    pub vocab: usize,
    pub batch_buckets: Vec<usize>,
    pub gram_cols: Vec<usize>,
    pub charset_fingerprint: u64,
    pub models: BTreeMap<String, ModelConfig>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and validate the charset fingerprint
    /// against the rust task generators (drift here would silently corrupt
    /// every evaluation, so it is a hard error).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let fp = j.get("charset_fingerprint")?.as_f64()? as u64;
        let ours = crate::eval::tasks::charset_fingerprint();
        if fp != ours {
            bail!(
                "charset fingerprint mismatch: python {fp} vs rust {ours} — \
                 python/compile/data.py and rust/src/eval/tasks.rs have diverged"
            );
        }
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(name, mj)?);
        }
        let mut artifacts = BTreeMap::new();
        for (name, aj) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(name.clone(), parse_artifact(dir, name, aj)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            seq_len: j.get("seq_len")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            batch_buckets: j.get("batch_buckets")?.as_usize_vec()?,
            gram_cols: j.get("gram_cols")?.as_usize_vec()?,
            charset_fingerprint: fp,
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelConfig> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model {name:?} (have: {:?})",
                                     self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    // ------- semantic artifact keys (must match aot.py naming) -------

    pub fn embed_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("embed_v{}_d{}_b{}", self.vocab, cfg.d_model, b)
    }

    pub fn attn_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("attn_d{}_h{}_b{}", cfg.d_model, cfg.n_heads, b)
    }

    pub fn moe_key(&self, cfg: &ModelConfig, n_experts: usize, b: usize) -> String {
        format!(
            "moe_d{}_f{}_e{}_k{}_{}_b{}",
            cfg.d_model, cfg.d_ff, n_experts, cfg.top_k,
            if cfg.shared_expert { "sh" } else { "ns" }, b
        )
    }

    pub fn moe_oracle_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!(
            "moeoracle_d{}_f{}_e{}_k{}_{}_b{}",
            cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k,
            if cfg.shared_expert { "sh" } else { "ns" }, b
        )
    }

    pub fn lmhead_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("lmhead_v{}_d{}_b{}", self.vocab, cfg.d_model, b)
    }

    pub fn monolith_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("monolith_{}_b{}", cfg.name, b)
    }

    pub fn gram_key(&self, cfg: &ModelConfig, s: usize) -> String {
        format!("gram_f{}_d{}_s{}", cfg.d_ff, cfg.d_model, s)
    }

    /// Pick the smallest batch bucket that fits `n` sequences.
    pub fn bucket_for(&self, n: usize) -> usize {
        for &b in &self.batch_buckets {
            if n <= b {
                return b;
            }
        }
        *self.batch_buckets.last().expect("no batch buckets")
    }
}

fn parse_model(name: &str, j: &Json) -> Result<ModelConfig> {
    Ok(ModelConfig {
        name: name.to_string(),
        n_layers: j.get("n_layers")?.as_usize()?,
        d_model: j.get("d_model")?.as_usize()?,
        n_heads: j.get("n_heads")?.as_usize()?,
        d_ff: j.get("d_ff")?.as_usize()?,
        n_experts: j.get("n_experts")?.as_usize()?,
        top_k: j.get("top_k")?.as_usize()?,
        shared_expert: j.get("shared_expert")?.as_bool()?,
        n_params: j.get("n_params")?.as_usize()?,
        merge_targets: j.get("merge_targets")?.as_usize_vec()?,
    })
}

fn parse_artifact(dir: &Path, name: &str, j: &Json) -> Result<ArtifactSpec> {
    let mut params = Vec::new();
    for p in j.get("params")?.as_arr()? {
        params.push(ParamSpec {
            name: p.get("name")?.as_str()?.to_string(),
            shape: p.get("shape")?.as_usize_vec()?,
            dtype: Dtype::parse(p.get("dtype")?.as_str()?)?,
        });
    }
    let mut outputs = Vec::new();
    for o in j.get("outputs")?.as_arr()? {
        outputs.push(OutputSpec {
            shape: o.get("shape")?.as_usize_vec()?,
            dtype: Dtype::parse(o.get("dtype")?.as_str()?)?,
        });
    }
    let monolith_keys = match j.get("meta")?.opt("keys") {
        Some(keys) => Some(
            keys.as_arr()?
                .iter()
                .map(|k| Ok(k.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
        ),
        None => None,
    };
    Ok(ArtifactSpec {
        name: name.to_string(),
        file: dir.join(j.get("file")?.as_str()?),
        params,
        outputs,
        monolith_keys,
    })
}

/// Hot-reloadable scoring-server knobs, as read from a `--config-file`
/// JSON document. Every field is optional — absent fields keep the
/// incumbent value when applied — but present fields are validated here
/// (types, ranges) and unknown keys are a hard parse error: a typo'd knob
/// in a reload must be rejected, not silently ignored while the operator
/// believes it took effect. The server-side two-phase apply
/// (`coordinator::server::AdminHandle::apply_tuning`) adds the checks that
/// need runtime context (e.g. the structural queue capacity).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServerTuning {
    /// Soft admission cap (must stay within the structural channel
    /// capacity the server booted with).
    pub queue_cap: Option<usize>,
    /// Per-request deadline in milliseconds; `0` disables deadlines.
    pub deadline_ms: Option<u64>,
    /// Transient-failure retries per (sub-)batch.
    pub max_retries: Option<u32>,
    /// Base of the capped exponential retry backoff, in microseconds.
    pub retry_backoff_us: Option<u64>,
    /// Fault-injection plan (`MERGEMOE_FAULT` grammar); `""` turns
    /// injection off.
    pub fault: Option<String>,
}

impl ServerTuning {
    /// Parse and validate a tuning document.
    pub fn parse(j: &Json) -> Result<ServerTuning> {
        let obj = j.as_obj().context("server tuning must be a JSON object")?;
        const KNOWN: [&str; 5] =
            ["queue_cap", "deadline_ms", "max_retries", "retry_backoff_us", "fault"];
        for k in obj.keys() {
            if !KNOWN.contains(&k.as_str()) {
                bail!("unknown server-tuning key {k:?} (known: {KNOWN:?})");
            }
        }
        let mut t = ServerTuning::default();
        if let Some(v) = j.opt("queue_cap") {
            let n = v.as_usize().context("queue_cap")?;
            if n == 0 {
                bail!("queue_cap must be >= 1");
            }
            t.queue_cap = Some(n);
        }
        if let Some(v) = j.opt("deadline_ms") {
            t.deadline_ms = Some(v.as_usize().context("deadline_ms")? as u64);
        }
        if let Some(v) = j.opt("max_retries") {
            let n = v.as_usize().context("max_retries")?;
            if n > 16 {
                bail!("max_retries {n} > 16 (runaway retry budget)");
            }
            t.max_retries = Some(n as u32);
        }
        if let Some(v) = j.opt("retry_backoff_us") {
            t.retry_backoff_us = Some(v.as_usize().context("retry_backoff_us")? as u64);
        }
        if let Some(v) = j.opt("fault") {
            let spec = v.as_str().context("fault")?;
            if !spec.trim().is_empty() {
                // validate the grammar at parse time — a reload must not
                // commit a plan the server cannot construct
                crate::util::fault::FaultPlan::parse(spec)
                    .with_context(|| format!("fault plan {spec:?}"))?;
            }
            t.fault = Some(spec.to_string());
        }
        Ok(t)
    }

    /// Read and validate `path` ([`ServerTuning::parse`] of its contents).
    pub fn load(path: &Path) -> Result<ServerTuning> {
        Self::parse(&Json::parse_file(path)?)
            .with_context(|| format!("validating server tuning {}", path.display()))
    }
}

/// Default artifacts directory: `$MERGEMOE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MERGEMOE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> String {
        let fp = crate::eval::tasks::charset_fingerprint();
        format!(
            r#"{{
  "charset_fingerprint": {fp},
  "seq_len": 64, "vocab": 47,
  "batch_buckets": [1, 8, 32], "gram_cols": [256],
  "models": {{
    "tiny": {{"name":"tiny","n_layers":2,"d_model":8,"n_heads":2,"d_ff":8,
              "n_experts":4,"top_k":2,"shared_expert":false,"seed":1,
              "train_steps":1,"batch_size":1,"lr":0.001,
              "merge_targets":[2],"vocab":47,"seq_len":64,"n_params":1000}}
  }},
  "artifacts": {{
    "attn_d8_h2_b1": {{"file":"attn_d8_h2_b1.hlo.txt",
      "params":[{{"name":"h","shape":[1,64,8],"dtype":"f32"}}],
      "outputs":[{{"shape":[1,64,8],"dtype":"f32"}}],
      "meta":{{}}}}
  }}
}}"#
        )
    }

    #[test]
    fn parses_and_keys() {
        let dir = std::env::temp_dir().join("mergemoe_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), mini_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.model("tiny").unwrap();
        assert_eq!(cfg.n_experts, 4);
        assert_eq!(m.attn_key(cfg, 1), "attn_d8_h2_b1");
        assert_eq!(m.moe_key(cfg, 2, 8), "moe_d8_f8_e2_k2_ns_b8");
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(5), 8);
        assert_eq!(m.bucket_for(999), 32);
        let a = m.artifact("attn_d8_h2_b1").unwrap();
        assert_eq!(a.params[0].shape, vec![1, 64, 8]);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn fingerprint_mismatch_is_fatal() {
        let dir = std::env::temp_dir().join("mergemoe_manifest_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = mini_manifest_json().replacen(
            &crate::eval::tasks::charset_fingerprint().to_string(),
            "12345",
            1,
        );
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn model_config_json_roundtrip() {
        let cfg = ModelConfig {
            name: "beta".into(), n_layers: 4, d_model: 64, n_heads: 4, d_ff: 64,
            n_experts: 12, top_k: 2, shared_expert: true,
            n_params: 123_456, merge_targets: vec![2, 3, 4, 6, 8, 10],
        };
        let j = cfg.to_json();
        let back = ModelConfig::from_json("beta", &Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.n_experts, 12);
        assert_eq!(back.merge_targets, cfg.merge_targets);
        assert!(back.shared_expert);
        assert_eq!(back.n_params, 123_456);
    }

    #[test]
    fn server_tuning_validates() {
        let t = ServerTuning::parse(
            &Json::parse(r#"{"queue_cap": 8, "deadline_ms": 250, "fault": "seed:1"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(t.queue_cap, Some(8));
        assert_eq!(t.deadline_ms, Some(250));
        assert_eq!(t.fault.as_deref(), Some("seed:1"));
        assert_eq!(t.max_retries, None);
        // empty document = keep everything
        assert_eq!(ServerTuning::parse(&Json::parse("{}").unwrap()).unwrap(),
                   ServerTuning::default());
        // rejections: unknown key, zero queue, bad fault grammar, bad types
        assert!(ServerTuning::parse(&Json::parse(r#"{"queue_capp": 8}"#).unwrap()).is_err());
        assert!(ServerTuning::parse(&Json::parse(r#"{"queue_cap": 0}"#).unwrap()).is_err());
        assert!(ServerTuning::parse(&Json::parse(r#"{"fault": "wat:1"}"#).unwrap()).is_err());
        assert!(ServerTuning::parse(&Json::parse(r#"{"max_retries": 99}"#).unwrap()).is_err());
        assert!(ServerTuning::parse(&Json::parse(r#"{"deadline_ms": -5}"#).unwrap()).is_err());
        assert!(ServerTuning::parse(&Json::parse("[1]").unwrap()).is_err());
        // "" fault = explicit off, valid
        let off = ServerTuning::parse(&Json::parse(r#"{"fault": ""}"#).unwrap()).unwrap();
        assert_eq!(off.fault.as_deref(), Some(""));
    }

    #[test]
    fn params_after_merge_accounting() {
        let cfg = ModelConfig {
            name: "x".into(), n_layers: 4, d_model: 64, n_heads: 4, d_ff: 64,
            n_experts: 16, top_k: 2, shared_expert: false,
            n_params: 1_000_000, merge_targets: vec![8],
        };
        let saved = 2 * (16 - 8) * 3 * 64 * 64;
        assert_eq!(cfg.params_after_merge(2, 8), 1_000_000 - saved);
    }
}
