//! Configuration system: model configs and the AOT artifact manifest.
//!
//! Everything the runtime knows about shapes comes from
//! `artifacts/manifest.json`, written by `python/compile/aot.py`. The rust
//! side never hard-codes a tensor shape: artifacts are looked up by semantic
//! key (layer type + shape signature) built from the [`ModelConfig`]s that
//! the same manifest carries.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Dtype of an artifact parameter/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }
}

/// One parameter of an HLO artifact (ordered).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// One output of an HLO artifact (ordered; artifacts return tuples).
#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// Manifest entry for one AOT-lowered executable.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<ParamSpec>,
    pub outputs: Vec<OutputSpec>,
    /// monolith artifacts carry the ordered weight-key list here
    pub monolith_keys: Option<Vec<String>>,
}

/// Mirror of `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub shared_expert: bool,
    pub n_params: usize,
    pub merge_targets: Vec<usize>,
}

impl ModelConfig {
    /// Parameter count of one routed expert (the unit of memory saving).
    pub fn expert_params(&self) -> usize {
        3 * self.d_ff * self.d_model
    }

    /// Total parameter count if `merged_layers` layers are reduced to `m`
    /// experts each — the "Model Size" column of Tables 1–3.
    pub fn params_after_merge(&self, merged_layers: usize, m: usize) -> usize {
        self.n_params - merged_layers * (self.n_experts - m) * self.expert_params()
    }
}

/// Parsed artifact manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seq_len: usize,
    pub vocab: usize,
    pub batch_buckets: Vec<usize>,
    pub gram_cols: Vec<usize>,
    pub charset_fingerprint: u64,
    pub models: BTreeMap<String, ModelConfig>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and validate the charset fingerprint
    /// against the rust task generators (drift here would silently corrupt
    /// every evaluation, so it is a hard error).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let fp = j.get("charset_fingerprint")?.as_f64()? as u64;
        let ours = crate::eval::tasks::charset_fingerprint();
        if fp != ours {
            bail!(
                "charset fingerprint mismatch: python {fp} vs rust {ours} — \
                 python/compile/data.py and rust/src/eval/tasks.rs have diverged"
            );
        }
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(name, mj)?);
        }
        let mut artifacts = BTreeMap::new();
        for (name, aj) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(name.clone(), parse_artifact(dir, name, aj)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            seq_len: j.get("seq_len")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            batch_buckets: j.get("batch_buckets")?.as_usize_vec()?,
            gram_cols: j.get("gram_cols")?.as_usize_vec()?,
            charset_fingerprint: fp,
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelConfig> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model {name:?} (have: {:?})",
                                     self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    // ------- semantic artifact keys (must match aot.py naming) -------

    pub fn embed_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("embed_v{}_d{}_b{}", self.vocab, cfg.d_model, b)
    }

    pub fn attn_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("attn_d{}_h{}_b{}", cfg.d_model, cfg.n_heads, b)
    }

    pub fn moe_key(&self, cfg: &ModelConfig, n_experts: usize, b: usize) -> String {
        format!(
            "moe_d{}_f{}_e{}_k{}_{}_b{}",
            cfg.d_model, cfg.d_ff, n_experts, cfg.top_k,
            if cfg.shared_expert { "sh" } else { "ns" }, b
        )
    }

    pub fn moe_oracle_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!(
            "moeoracle_d{}_f{}_e{}_k{}_{}_b{}",
            cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k,
            if cfg.shared_expert { "sh" } else { "ns" }, b
        )
    }

    pub fn lmhead_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("lmhead_v{}_d{}_b{}", self.vocab, cfg.d_model, b)
    }

    pub fn monolith_key(&self, cfg: &ModelConfig, b: usize) -> String {
        format!("monolith_{}_b{}", cfg.name, b)
    }

    pub fn gram_key(&self, cfg: &ModelConfig, s: usize) -> String {
        format!("gram_f{}_d{}_s{}", cfg.d_ff, cfg.d_model, s)
    }

    /// Pick the smallest batch bucket that fits `n` sequences.
    pub fn bucket_for(&self, n: usize) -> usize {
        for &b in &self.batch_buckets {
            if n <= b {
                return b;
            }
        }
        *self.batch_buckets.last().expect("no batch buckets")
    }
}

fn parse_model(name: &str, j: &Json) -> Result<ModelConfig> {
    Ok(ModelConfig {
        name: name.to_string(),
        n_layers: j.get("n_layers")?.as_usize()?,
        d_model: j.get("d_model")?.as_usize()?,
        n_heads: j.get("n_heads")?.as_usize()?,
        d_ff: j.get("d_ff")?.as_usize()?,
        n_experts: j.get("n_experts")?.as_usize()?,
        top_k: j.get("top_k")?.as_usize()?,
        shared_expert: j.get("shared_expert")?.as_bool()?,
        n_params: j.get("n_params")?.as_usize()?,
        merge_targets: j.get("merge_targets")?.as_usize_vec()?,
    })
}

fn parse_artifact(dir: &Path, name: &str, j: &Json) -> Result<ArtifactSpec> {
    let mut params = Vec::new();
    for p in j.get("params")?.as_arr()? {
        params.push(ParamSpec {
            name: p.get("name")?.as_str()?.to_string(),
            shape: p.get("shape")?.as_usize_vec()?,
            dtype: Dtype::parse(p.get("dtype")?.as_str()?)?,
        });
    }
    let mut outputs = Vec::new();
    for o in j.get("outputs")?.as_arr()? {
        outputs.push(OutputSpec {
            shape: o.get("shape")?.as_usize_vec()?,
            dtype: Dtype::parse(o.get("dtype")?.as_str()?)?,
        });
    }
    let monolith_keys = match j.get("meta")?.opt("keys") {
        Some(keys) => Some(
            keys.as_arr()?
                .iter()
                .map(|k| Ok(k.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
        ),
        None => None,
    };
    Ok(ArtifactSpec {
        name: name.to_string(),
        file: dir.join(j.get("file")?.as_str()?),
        params,
        outputs,
        monolith_keys,
    })
}

/// Default artifacts directory: `$MERGEMOE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MERGEMOE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> String {
        let fp = crate::eval::tasks::charset_fingerprint();
        format!(
            r#"{{
  "charset_fingerprint": {fp},
  "seq_len": 64, "vocab": 47,
  "batch_buckets": [1, 8, 32], "gram_cols": [256],
  "models": {{
    "tiny": {{"name":"tiny","n_layers":2,"d_model":8,"n_heads":2,"d_ff":8,
              "n_experts":4,"top_k":2,"shared_expert":false,"seed":1,
              "train_steps":1,"batch_size":1,"lr":0.001,
              "merge_targets":[2],"vocab":47,"seq_len":64,"n_params":1000}}
  }},
  "artifacts": {{
    "attn_d8_h2_b1": {{"file":"attn_d8_h2_b1.hlo.txt",
      "params":[{{"name":"h","shape":[1,64,8],"dtype":"f32"}}],
      "outputs":[{{"shape":[1,64,8],"dtype":"f32"}}],
      "meta":{{}}}}
  }}
}}"#
        )
    }

    #[test]
    fn parses_and_keys() {
        let dir = std::env::temp_dir().join("mergemoe_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), mini_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.model("tiny").unwrap();
        assert_eq!(cfg.n_experts, 4);
        assert_eq!(m.attn_key(cfg, 1), "attn_d8_h2_b1");
        assert_eq!(m.moe_key(cfg, 2, 8), "moe_d8_f8_e2_k2_ns_b8");
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(5), 8);
        assert_eq!(m.bucket_for(999), 32);
        let a = m.artifact("attn_d8_h2_b1").unwrap();
        assert_eq!(a.params[0].shape, vec![1, 64, 8]);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn fingerprint_mismatch_is_fatal() {
        let dir = std::env::temp_dir().join("mergemoe_manifest_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = mini_manifest_json().replacen(
            &crate::eval::tasks::charset_fingerprint().to_string(),
            "12345",
            1,
        );
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn params_after_merge_accounting() {
        let cfg = ModelConfig {
            name: "x".into(), n_layers: 4, d_model: 64, n_heads: 4, d_ff: 64,
            n_experts: 16, top_k: 2, shared_expert: false,
            n_params: 1_000_000, merge_targets: vec![8],
        };
        let saved = 2 * (16 - 8) * 3 * 64 * 64;
        assert_eq!(cfg.params_after_merge(2, 8), 1_000_000 - saved);
    }
}
