//! The scoring server: a worker thread owning the engine + model, fed by the
//! dynamic batcher, answering option-scoring requests (the serving workload
//! of the e2e example — a compressed model deployed behind a batched
//! endpoint).
//!
//! Engine objects wrap PJRT client state and are not `Send`, so the worker
//! *constructs* its engine inside the thread from a factory closure; clients
//! hold a cheap cloneable handle.
//!
//! The worker owns one [`Workspace`] (plus a logits tensor, a batch token
//! buffer and a log-prob buffer) and reuses them across every batch, so the
//! steady-state loop — gather tokens, forward, score, reply — runs without
//! touching the allocator once the arena is warm. Workspaces are per-worker
//! by contract: never shared across threads.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::{next_batch, BatchDecision};
use super::metrics::ServerMetrics;
use crate::eval::tasks;
use crate::model::native::target_logprobs_into;
use crate::model::workspace::Workspace;
use crate::model::ModelWeights;
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub seq_len: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            seq_len: 64,
        }
    }
}

/// A scoring request: mean log-probability of `completion` given `prompt`.
struct Request {
    tokens: Vec<i32>,
    prompt_len: usize,
    completion_len: usize,
    submitted: Instant,
    reply: Sender<Result<f64>>,
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    seq_len: usize,
    /// Padding token, resolved once at server construction instead of
    /// re-tokenizing "\n" on every request.
    pad: i32,
}

impl ServerHandle {
    /// Score a (prompt, completion) pair; blocks until the batched backend
    /// answers. Thread-safe; call from many threads to exercise batching.
    pub fn score(&self, prompt: &str, completion: &str) -> Result<f64> {
        let ptoks = tasks::encode(prompt);
        let ctoks = tasks::encode(completion);
        let prompt_len = ptoks.len();
        let completion_len = ctoks.len();
        if prompt_len == 0 || completion_len == 0 {
            return Err(anyhow!("prompt and completion must be non-empty"));
        }
        if prompt_len + completion_len > self.seq_len {
            return Err(anyhow!("request longer than seq_len"));
        }
        let mut toks = ptoks;
        toks.extend(ctoks);
        toks.resize(self.seq_len, self.pad);
        let (rtx, rrx) = channel();
        self.tx
            .send(Request {
                tokens: toks,
                prompt_len,
                completion_len,
                submitted: Instant::now(),
                reply: rtx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().context("server dropped request")?
    }
}

/// Record the per-batch counters shared by the success and failure paths
/// (one `batch_latency` sample per batch, always) and hand the still-locked
/// guard back for any per-request bookkeeping.
fn record_batch(
    metrics: &Mutex<ServerMetrics>,
    batch_size: usize,
    wall_seconds: f64,
    compute: Duration,
) -> std::sync::MutexGuard<'_, ServerMetrics> {
    let mut m = metrics.lock().unwrap();
    m.batches += 1;
    m.batched_sequences += batch_size as u64;
    m.batch_latency.record(compute);
    m.wall_seconds = wall_seconds;
    m
}

/// The scoring server. Owns the worker thread; dropping it (or calling
/// [`ScoringServer::shutdown`]) stops the worker.
pub struct ScoringServer {
    handle: ServerHandle,
    metrics: Arc<Mutex<ServerMetrics>>,
    join: Option<std::thread::JoinHandle<()>>,
    _keep_tx: Option<Sender<Request>>,
}

impl ScoringServer {
    /// Start the server. `make_engine` runs on the worker thread and builds
    /// the backend (e.g. `|| PjrtEngine::new(manifest)`).
    pub fn start<E, F>(model: ModelWeights, cfg: ServerConfig, make_engine: F) -> ScoringServer
    where
        E: Engine,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let metrics2 = metrics.clone();
        let cfg2 = cfg.clone();
        let pad = tasks::encode("\n")[0];
        let join = std::thread::spawn(move || {
            let mut engine = match make_engine() {
                Ok(e) => e,
                Err(e) => {
                    crate::warnlog!("engine construction failed: {e:#}");
                    // drain and fail all requests
                    while let Ok(req) = rx.recv() {
                        let _ = req.reply.send(Err(anyhow!("engine unavailable")));
                    }
                    return;
                }
            };
            // Steady-state serving buffers: one workspace per worker, one
            // logits tensor, one token gather, one log-prob buffer — reused
            // across every batch.
            let mut ws = Workspace::new();
            let mut logits = Tensor::default();
            let mut tokens: Vec<i32> = Vec::new();
            let start = Instant::now();
            loop {
                match next_batch(&rx, cfg2.max_batch, cfg2.max_wait) {
                    BatchDecision::Shutdown => break,
                    BatchDecision::Flush(items) => {
                        let b = items.len();
                        let s = cfg2.seq_len;
                        let t_batch = Instant::now();
                        tokens.clear();
                        for it in &items {
                            tokens.extend_from_slice(&it.payload.tokens);
                        }
                        let result =
                            engine.logits_ws(&model, &tokens, b, s, &mut ws, &mut logits);
                        match result {
                            Ok(()) => {
                                target_logprobs_into(&logits, &tokens, b, s, &mut ws.lps);
                                let mut m = record_batch(
                                    &metrics2,
                                    b,
                                    start.elapsed().as_secs_f64(),
                                    t_batch.elapsed(),
                                );
                                for (bi, it) in items.iter().enumerate() {
                                    let r = &it.payload;
                                    let mut sum = 0.0f64;
                                    for si in (r.prompt_len - 1)
                                        ..(r.prompt_len + r.completion_len - 1)
                                    {
                                        sum += ws.lps[bi * s + si] as f64;
                                    }
                                    m.requests += 1;
                                    m.queue_latency
                                        .record(it.enqueued.duration_since(r.submitted));
                                    m.total_latency.record(r.submitted.elapsed());
                                    let _ = r
                                        .reply
                                        .send(Ok(sum / r.completion_len as f64));
                                }
                            }
                            Err(e) => {
                                drop(record_batch(
                                    &metrics2,
                                    b,
                                    start.elapsed().as_secs_f64(),
                                    t_batch.elapsed(),
                                ));
                                let msg = format!("{e:#}");
                                for it in items {
                                    let _ =
                                        it.payload.reply.send(Err(anyhow!(msg.clone())));
                                }
                            }
                        }
                    }
                }
            }
        });
        ScoringServer {
            handle: ServerHandle { tx: tx.clone(), seq_len: cfg.seq_len, pad },
            metrics,
            join: Some(join),
            _keep_tx: Some(tx),
        }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    pub fn metrics(&self) -> ServerMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop accepting requests and join the worker.
    pub fn shutdown(mut self) -> ServerMetrics {
        self._keep_tx = None; // close our copy
        let ServerHandle { tx, .. } = self.handle.clone();
        drop(tx);
        // handle clones held by clients keep the channel open; callers drop
        // them before shutdown in practice. Replace our handle sender too:
        self.handle = ServerHandle {
            tx: {
                let (dead_tx, _) = channel();
                dead_tx
            },
            seq_len: self.handle.seq_len,
            pad: self.handle.pad,
        };
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for ScoringServer {
    fn drop(&mut self) {
        self._keep_tx = None;
        // Replace our handle's sender with a dead channel so the worker
        // observes disconnect (client-held handle clones must already be
        // dropped by now, as documented on `handle()`).
        let (dead_tx, _) = channel();
        self.handle = ServerHandle {
            tx: dead_tx,
            seq_len: self.handle.seq_len,
            pad: self.handle.pad,
        };
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    #[test]
    fn serves_scores_and_batches() {
        let model = tiny_model(4, 2, false, 100);
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            seq_len: 64,
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine));
        let h = server.handle();
        // concurrent clients to force batching
        let mut joins = Vec::new();
        for i in 0..12 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let score = h.score("c:abcd|", if i % 2 == 0 { "abcd." } else { "zzzz." });
                score.unwrap()
            }));
        }
        let scores: Vec<f64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert!(scores.iter().all(|s| s.is_finite() && *s < 0.0));
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.requests, 12);
        assert!(m.batches <= 12);
        assert!(m.mean_batch_size() >= 1.0);
        // the worker records one batch-compute sample per batch
        assert_eq!(m.batch_latency.count(), m.batches);
        assert!(m.batch_latency_p50() <= m.batch_latency_p99());
    }

    #[test]
    fn rejects_oversized_requests() {
        let model = tiny_model(4, 2, false, 101);
        let server =
            ScoringServer::start(model, ServerConfig::default(), || Ok(NativeEngine));
        let h = server.handle();
        let long = "a".repeat(100);
        assert!(h.score(&long, "b").is_err());
        assert!(h.score("", "b").is_err());
        drop(h);
    }

    #[test]
    fn identical_requests_get_identical_scores_regardless_of_batching() {
        let model = tiny_model(4, 2, true, 102);
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            seq_len: 64,
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine));
        let h = server.handle();
        let a = h.score("r:abc|", "cba.").unwrap();
        let b = h.score("r:abc|", "cba.").unwrap();
        assert!((a - b).abs() < 1e-6);
        drop(h);
    }
}
