//! The scoring server: a supervised worker thread owning the engine + model,
//! fed by the dynamic batcher through a **bounded** admission queue,
//! answering option-scoring requests (the serving workload of the e2e
//! example — a compressed model deployed behind a batched endpoint).
//!
//! Overload hardening, end to end:
//!
//! * **Bounded admission** — the queue holds at most
//!   [`ServerConfig::queue_cap`] requests (`--queue-cap` /
//!   `MERGEMOE_QUEUE_CAP`); a full queue sheds the request immediately with
//!   the typed [`ServeError::Overloaded`] instead of buffering unbounded
//!   latency. Queue depth is observable ([`ServerStatus::queue_depth`]).
//! * **Deadlines** — [`ServerConfig::deadline`] stamps every request with an
//!   expiry; the batcher flushes deadline-aware and partitions out expired
//!   items, which are failed with [`ServeError::DeadlineExceeded`] *before*
//!   any forward-pass compute is spent on them.
//! * **Fault classification + retry** — engine errors are classified
//!   [`FaultClass::Transient`] or [`FaultClass::Fatal`]
//!   ([`crate::util::fault::classify`]). Transient batch failures retry
//!   under capped exponential backoff; a batch that keeps failing is split
//!   in half recursively, so one poison request fails alone instead of
//!   failing its batchmates. Fatal errors fail the batch fast.
//! * **Worker supervision** — a panic mid-batch is caught, the in-flight
//!   requests are failed with [`ServeError::WorkerPanicked`], and the worker
//!   respawns with a fresh engine + workspace (panics can leave both
//!   mid-update) up to [`ServerConfig::restart_budget`]; past the budget the
//!   server degrades to fast-rejecting ([`ServeError::Degraded`], visible on
//!   `/healthz`).
//! * **Graceful drain** — [`ScoringServer::shutdown`] / [`drain`](ScoringServer::drain)
//!   stop admission (state flip observed by every handle clone), enqueue an
//!   explicit close sentinel behind the admitted work, finish that work
//!   under a drain timeout, and join. Shutdown never depends on clients
//!   dropping their [`ServerHandle`] clones.
//!
//! Every path above is driven deterministically by
//! [`crate::util::fault::FaultPlan`] (`MERGEMOE_FAULT`), so the robustness
//! behaviors are reproducible tier-1 tests (`tests/fault_injection.rs`),
//! not claims. With no plan configured the steady-state loop is the exact
//! unhardened execution: gather tokens, forward, score, reply — reusing one
//! [`Workspace`], one logits tensor, one token buffer and one score buffer,
//! so it runs without touching the allocator once the arena is warm.
//! Workspaces are per-worker by contract: never shared across threads.
//!
//! Engine objects wrap PJRT client state and are not `Send`, so the worker
//! *constructs* its engine inside the thread from a factory closure (called
//! again on every respawn); clients hold a cheap cloneable handle.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicU8, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{mpsc::sync_channel, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{next_batch, BatchDecision, Ctl, WorkItem};
use super::metrics::ServerMetrics;
use crate::eval::tasks;
use crate::model::native::target_logprobs_into;
use crate::model::workspace::Workspace;
use crate::model::ModelWeights;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::util::fault::{classify, FaultAction, FaultClass, FaultPlan, InjectedFault};

/// Typed request-path errors: every way the hardened server can refuse or
/// fail a request, distinguishable by clients (and mapped to HTTP statuses
/// by [`crate::coordinator::http`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded admission queue is full — load was shed. Back off and
    /// retry.
    Overloaded,
    /// The request's deadline passed before its forward pass started.
    DeadlineExceeded,
    /// The worker panicked while this request was in flight.
    WorkerPanicked,
    /// The worker exhausted its restart budget (or never built an engine);
    /// the server is fast-rejecting until restarted.
    Degraded,
    /// The server is draining or stopped; no new work is admitted.
    ShuttingDown,
    /// The request itself is invalid (empty or longer than `seq_len`).
    Rejected(String),
    /// The engine failed this request fatally or exhausted its retries.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "overloaded: admission queue full"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded while queued"),
            ServeError::WorkerPanicked => write!(f, "worker panicked mid-batch"),
            ServeError::Degraded => write!(f, "server degraded: restart budget exhausted"),
            ServeError::ShuttingDown => write!(f, "server shutting down"),
            ServeError::Rejected(why) => write!(f, "request rejected: {why}"),
            ServeError::Engine(why) => write!(f, "engine failure: {why}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// How the server sources its fault-injection plan.
#[derive(Debug, Clone, Default)]
pub enum FaultSetting {
    /// Consult `MERGEMOE_FAULT` (unset ⇒ no injection). The default.
    #[default]
    FromEnv,
    /// Never inject, regardless of the environment.
    Off,
    /// Use this plan (tests script exact failure schedules this way).
    Plan(Arc<FaultPlan>),
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Largest batch handed to the engine.
    pub max_batch: usize,
    /// Longest a flush waits on the oldest queued request.
    pub max_wait: Duration,
    /// Padded sequence length every request is resized to.
    pub seq_len: usize,
    /// Bounded admission-queue capacity; a full queue sheds with
    /// [`ServeError::Overloaded`]. Default: `MERGEMOE_QUEUE_CAP` or 256.
    pub queue_cap: usize,
    /// Per-request deadline (admission → forward-pass start). `None`
    /// disables expiry.
    pub deadline: Option<Duration>,
    /// Transient-failure retries per (sub-)batch before splitting/failing.
    pub max_retries: u32,
    /// Base of the capped exponential retry backoff.
    pub retry_backoff: Duration,
    /// Worker respawns allowed before the server degrades to
    /// fast-rejecting.
    pub restart_budget: u32,
    /// Drain window for [`ScoringServer::shutdown`]: queued work older than
    /// this is failed with [`ServeError::ShuttingDown`] instead of computed.
    pub drain_timeout: Duration,
    /// Fault-injection source (see [`FaultSetting`]).
    pub fault: FaultSetting,
}

fn env_queue_cap() -> usize {
    match std::env::var("MERGEMOE_QUEUE_CAP") {
        Ok(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                crate::warnlog!("ignoring invalid MERGEMOE_QUEUE_CAP={v:?} (want integer >= 1)");
                256
            }
        },
        Err(_) => 256,
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            seq_len: 64,
            queue_cap: env_queue_cap(),
            deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_micros(500),
            restart_budget: 3,
            drain_timeout: Duration::from_secs(5),
            fault: FaultSetting::FromEnv,
        }
    }
}

/// A scoring request: mean log-probability of `completion` given `prompt`.
struct Request {
    tokens: Vec<i32>,
    prompt_len: usize,
    completion_len: usize,
    submitted: Instant,
    deadline: Option<Instant>,
    reply: Sender<Result<f64, ServeError>>,
}

const STATE_RUNNING: u8 = 0;
const STATE_DRAINING: u8 = 1;

/// State shared between handles, the worker, and status observers.
struct Shared {
    state: AtomicU8,
    degraded: AtomicBool,
    /// Queue-depth gauge. Signed: a client increments strictly *after* a
    /// successful `try_send`, so `depth >= n` proves n items truly sit in
    /// the channel (tests rely on that to fill the queue race-free); the
    /// worker's decrement can then transiently win the race and drive the
    /// value to -1, which the getters clamp to 0.
    depth: AtomicIsize,
    drain_deadline: Mutex<Option<Instant>>,
    metrics: Mutex<ServerMetrics>,
}

impl Shared {
    fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed).max(0) as usize
    }
}

impl Default for Shared {
    fn default() -> Self {
        Shared {
            state: AtomicU8::new(STATE_RUNNING),
            degraded: AtomicBool::new(false),
            depth: AtomicIsize::new(0),
            drain_deadline: Mutex::new(None),
            metrics: Mutex::new(ServerMetrics::default()),
        }
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Ctl<Request>>,
    shared: Arc<Shared>,
    seq_len: usize,
    /// Padding token, resolved once (fallibly) at server construction
    /// instead of re-tokenizing "\n" on every request.
    pad: i32,
    deadline: Option<Duration>,
}

impl ServerHandle {
    /// Score a (prompt, completion) pair; blocks until the batched backend
    /// answers or refuses. Thread-safe; call from many threads to exercise
    /// batching. Uses the server's configured deadline.
    pub fn score(&self, prompt: &str, completion: &str) -> Result<f64, ServeError> {
        self.score_with_deadline(prompt, completion, self.deadline)
    }

    /// [`score`](Self::score) with an explicit per-request deadline
    /// (`None` = no expiry), overriding the server default.
    pub fn score_with_deadline(
        &self,
        prompt: &str,
        completion: &str,
        deadline: Option<Duration>,
    ) -> Result<f64, ServeError> {
        let ptoks = tasks::encode(prompt);
        let ctoks = tasks::encode(completion);
        let prompt_len = ptoks.len();
        let completion_len = ctoks.len();
        if prompt_len == 0 || completion_len == 0 {
            return Err(ServeError::Rejected(
                "prompt and completion must be non-empty".into(),
            ));
        }
        if prompt_len + completion_len > self.seq_len {
            return Err(ServeError::Rejected("request longer than seq_len".into()));
        }
        if self.shared.state.load(Ordering::Acquire) != STATE_RUNNING {
            return Err(ServeError::ShuttingDown);
        }
        if self.shared.degraded.load(Ordering::Acquire) {
            return Err(ServeError::Degraded);
        }
        let mut toks = ptoks;
        toks.extend(ctoks);
        toks.resize(self.seq_len, self.pad);
        let submitted = Instant::now();
        let (rtx, rrx) = channel();
        let req = Request {
            tokens: toks,
            prompt_len,
            completion_len,
            submitted,
            deadline: deadline.map(|d| submitted + d),
            reply: rtx,
        };
        match self.tx.try_send(Ctl::Item(req)) {
            Ok(()) => {
                self.shared.depth.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(_)) => {
                self.shared.metrics.lock().unwrap().shed += 1;
                return Err(ServeError::Overloaded);
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err(ServeError::ShuttingDown);
            }
        }
        // the supervised worker replies to every admitted request; a
        // dropped reply channel means it died outside its own supervision
        rrx.recv().map_err(|_| ServeError::WorkerPanicked)?
    }

    /// Requests currently queued (admission gauge).
    pub fn queue_depth(&self) -> usize {
        self.shared.depth()
    }
}

/// Read-only observer of server health + metrics (what `/healthz` and
/// `/metrics` render; cloneable into the HTTP front end).
#[derive(Clone)]
pub struct ServerStatus {
    shared: Arc<Shared>,
}

impl ServerStatus {
    /// Snapshot of the rolled-up serving metrics.
    pub fn metrics(&self) -> ServerMetrics {
        self.shared.metrics.lock().unwrap().clone()
    }

    /// True once the worker's restart budget is exhausted (the server
    /// fast-rejects until restarted).
    pub fn degraded(&self) -> bool {
        self.shared.degraded.load(Ordering::Acquire)
    }

    /// True once a drain/shutdown has begun (admission stopped).
    pub fn draining(&self) -> bool {
        self.shared.state.load(Ordering::Acquire) != STATE_RUNNING
    }

    /// Requests currently queued.
    pub fn queue_depth(&self) -> usize {
        self.shared.depth()
    }
}

/// Outcome of one batch-execution attempt.
enum BatchError {
    /// The attempt panicked; message extracted from the payload.
    Panicked(String),
    /// The attempt failed with a classified engine error.
    Failed(FaultClass, String),
}

/// The worker-side half: owns the engine, model, and every steady-state
/// buffer; lives entirely on the worker thread.
struct Worker<E, F> {
    model: ModelWeights,
    cfg: ServerConfig,
    shared: Arc<Shared>,
    make_engine: F,
    engine: Option<E>,
    restarts_left: u32,
    fault: Option<Arc<FaultPlan>>,
    started: Instant,
    ws: Workspace,
    logits: Tensor,
    tokens: Vec<i32>,
    scores: Vec<f64>,
}

impl<E: Engine, F: FnMut() -> Result<E>> Worker<E, F> {
    fn run(mut self, rx: Receiver<Ctl<Request>>) {
        match (self.make_engine)() {
            Ok(e) => self.engine = Some(e),
            Err(e) => {
                crate::warnlog!("engine construction failed: {e:#}");
                self.degrade("engine construction failed");
            }
        }
        loop {
            match next_batch(&rx, self.cfg.max_batch, self.cfg.max_wait, |r: &Request| {
                r.deadline
            }) {
                BatchDecision::Shutdown => break,
                BatchDecision::Flush(batch) => {
                    let n = (batch.ready.len() + batch.expired.len()) as isize;
                    self.shared.depth.fetch_sub(n, Ordering::Relaxed);
                    for it in batch.expired {
                        self.fail_expired(it);
                    }
                    if !batch.ready.is_empty() {
                        self.dispatch(batch.ready);
                    }
                    if batch.close {
                        break;
                    }
                }
            }
        }
    }

    fn dispatch(&mut self, items: Vec<WorkItem<Request>>) {
        if self.engine.is_none() {
            self.fail_all(items, ServeError::Degraded);
            return;
        }
        self.execute(items);
    }

    /// Run one (sub-)batch to completion: retry transient failures under
    /// capped exponential backoff, split persistent failures in half (a
    /// poison request ends up alone and fails alone), fail fatal errors
    /// fast, and hand panics to the supervisor.
    fn execute(&mut self, mut items: Vec<WorkItem<Request>>) {
        // re-check deadlines: retries/splits ahead of this sub-batch may
        // have consumed a request's remaining budget while it waited
        let now = Instant::now();
        if items.iter().any(|it| it.payload.deadline.is_some_and(|d| d <= now)) {
            let (expired, live): (Vec<_>, Vec<_>) = items
                .into_iter()
                .partition(|it| it.payload.deadline.is_some_and(|d| d <= now));
            for it in expired {
                self.fail_expired(it);
            }
            items = live;
        }
        if items.is_empty() {
            return;
        }
        // past the drain window, queued work is shed instead of computed
        if self.past_drain_deadline() {
            self.fail_all(items, ServeError::ShuttingDown);
            return;
        }
        let mut attempt = 0u32;
        loop {
            match self.try_batch(&items) {
                Ok(()) => {
                    self.reply_ok(items);
                    return;
                }
                Err(BatchError::Panicked(msg)) => {
                    self.after_panic(items, msg);
                    return;
                }
                Err(BatchError::Failed(FaultClass::Fatal, msg)) => {
                    crate::warnlog!("fatal engine error, failing batch of {}: {msg}", items.len());
                    self.fail_all(items, ServeError::Engine(msg));
                    return;
                }
                Err(BatchError::Failed(FaultClass::Transient, msg)) => {
                    attempt += 1;
                    if attempt > self.cfg.max_retries {
                        if items.len() > 1 {
                            // persistent transient failure: split so one
                            // poison request cannot fail its batchmates
                            self.shared.metrics.lock().unwrap().splits += 1;
                            crate::debuglog!(
                                "splitting batch of {} after {attempt} failed attempts",
                                items.len()
                            );
                            let right = items.split_off(items.len() / 2);
                            self.execute(items);
                            self.execute(right);
                        } else {
                            self.fail_all(items, ServeError::Engine(msg));
                        }
                        return;
                    }
                    self.shared.metrics.lock().unwrap().retried += 1;
                    let backoff = backoff_delay(self.cfg.retry_backoff, attempt);
                    if !backoff.is_zero() {
                        std::thread::sleep(backoff);
                    }
                }
            }
        }
    }

    /// One engine attempt over `items`: fault-plan consultation, forward
    /// pass, scoring into `self.scores`. Panics are contained here.
    fn try_batch(&mut self, items: &[WorkItem<Request>]) -> Result<(), BatchError> {
        let b = items.len();
        let s = self.cfg.seq_len;
        self.tokens.clear();
        for it in items {
            self.tokens.extend_from_slice(&it.payload.tokens);
        }
        let t_batch = Instant::now();
        let Worker { engine, ws, logits, tokens, scores, model, fault, .. } = self;
        let engine = engine.as_mut().expect("dispatch() guarantees an engine");
        let result = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
            if let Some(plan) = fault.as_deref() {
                match plan.next() {
                    FaultAction::None => {}
                    FaultAction::Slow(d) => std::thread::sleep(d),
                    FaultAction::Transient => {
                        return Err(InjectedFault { class: FaultClass::Transient }.into())
                    }
                    FaultAction::Fatal => {
                        return Err(InjectedFault { class: FaultClass::Fatal }.into())
                    }
                    FaultAction::Panic => panic!("injected worker panic"),
                }
                if plan.is_poisoned(tokens) {
                    return Err(InjectedFault { class: FaultClass::Transient }.into());
                }
            }
            engine.logits_ws(model, tokens, b, s, ws, logits)?;
            target_logprobs_into(logits, tokens, b, s, &mut ws.lps);
            scores.clear();
            for (bi, it) in items.iter().enumerate() {
                let r = &it.payload;
                let mut sum = 0.0f64;
                for si in (r.prompt_len - 1)..(r.prompt_len + r.completion_len - 1) {
                    sum += ws.lps[bi * s + si] as f64;
                }
                scores.push(sum / r.completion_len as f64);
            }
            Ok(())
        }));
        // one batch-counter + compute-latency sample per executed attempt,
        // success or failure, so p99 reflects bad batches too
        {
            let mut m = self.shared.metrics.lock().unwrap();
            m.batches += 1;
            m.batched_sequences += b as u64;
            m.batch_latency.record(t_batch.elapsed());
            m.wall_seconds = self.started.elapsed().as_secs_f64();
        }
        match result {
            Ok(Ok(())) => Ok(()),
            Ok(Err(e)) => Err(BatchError::Failed(classify(&e), format!("{e:#}"))),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                Err(BatchError::Panicked(msg))
            }
        }
    }

    /// Supervisor: fail the in-flight requests, then respawn the worker
    /// state (fresh engine + workspace) or degrade once the budget is gone.
    fn after_panic(&mut self, items: Vec<WorkItem<Request>>, msg: String) {
        crate::warnlog!(
            "worker panicked mid-batch ({msg}); failing {} in-flight request(s)",
            items.len()
        );
        self.fail_all(items, ServeError::WorkerPanicked);
        // the panic may have interrupted an arena or engine mid-update:
        // discard both and rebuild from scratch
        self.engine = None;
        self.ws = Workspace::new();
        self.logits = Tensor::default();
        if self.restarts_left == 0 {
            self.degrade("worker restart budget exhausted");
            return;
        }
        self.restarts_left -= 1;
        match (self.make_engine)() {
            Ok(e) => {
                self.engine = Some(e);
                self.shared.metrics.lock().unwrap().restarted += 1;
                crate::info!(
                    "worker respawned with a fresh engine ({} restart(s) left)",
                    self.restarts_left
                );
            }
            Err(e) => {
                crate::warnlog!("engine respawn failed: {e:#}");
                self.degrade("engine respawn failed");
            }
        }
    }

    fn degrade(&self, why: &str) {
        crate::warnlog!("server degraded ({why}): fast-rejecting until restarted");
        self.shared.degraded.store(true, Ordering::Release);
    }

    fn past_drain_deadline(&self) -> bool {
        if self.shared.state.load(Ordering::Acquire) == STATE_RUNNING {
            return false;
        }
        match *self.shared.drain_deadline.lock().unwrap() {
            Some(d) => Instant::now() > d,
            None => false,
        }
    }

    fn reply_ok(&mut self, items: Vec<WorkItem<Request>>) {
        let mut m = self.shared.metrics.lock().unwrap();
        for (bi, it) in items.iter().enumerate() {
            let r = &it.payload;
            m.requests += 1;
            m.queue_latency.record(it.enqueued.duration_since(r.submitted));
            m.total_latency.record(r.submitted.elapsed());
            let _ = r.reply.send(Ok(self.scores[bi]));
        }
    }

    /// Reply `err` to every item, recording request/error counters and
    /// latency (failures are visible in p99, not invisible).
    fn fail_all(&self, items: Vec<WorkItem<Request>>, err: ServeError) {
        let mut m = self.shared.metrics.lock().unwrap();
        for it in items {
            let r = &it.payload;
            m.requests += 1;
            m.errors += 1;
            m.queue_latency.record(it.enqueued.duration_since(r.submitted));
            m.total_latency.record(r.submitted.elapsed());
            let _ = r.reply.send(Err(err.clone()));
        }
    }

    fn fail_expired(&self, it: WorkItem<Request>) {
        let r = &it.payload;
        let mut m = self.shared.metrics.lock().unwrap();
        m.requests += 1;
        m.errors += 1;
        m.expired += 1;
        m.queue_latency.record(it.enqueued.duration_since(r.submitted));
        m.total_latency.record(r.submitted.elapsed());
        let _ = r.reply.send(Err(ServeError::DeadlineExceeded));
    }
}

/// Capped exponential backoff: `base * 2^(attempt-1)`, capped at 100ms.
fn backoff_delay(base: Duration, attempt: u32) -> Duration {
    const CAP: Duration = Duration::from_millis(100);
    let shift = attempt.saturating_sub(1).min(16);
    base.saturating_mul(1u32 << shift).min(CAP)
}

/// The scoring server. Owns the supervised worker thread; dropping it (or
/// calling [`ScoringServer::shutdown`]) drains and joins the worker.
pub struct ScoringServer {
    handle: ServerHandle,
    shared: Arc<Shared>,
    tx: SyncSender<Ctl<Request>>,
    join: Option<std::thread::JoinHandle<()>>,
    drain_timeout: Duration,
}

impl ScoringServer {
    /// Start the server. `make_engine` runs on the worker thread and builds
    /// the backend (e.g. `|| PjrtEngine::new(manifest)`); it is called again
    /// on every supervised respawn. Fails fast on construction errors (e.g.
    /// an unresolvable padding token) instead of panicking on the first
    /// request.
    pub fn start<E, F>(model: ModelWeights, cfg: ServerConfig, make_engine: F) -> Result<ScoringServer>
    where
        E: Engine,
        F: FnMut() -> Result<E> + Send + 'static,
    {
        let pad = tasks::encode("\n").first().copied().ok_or_else(|| {
            anyhow!("cannot resolve pad token: encoding \"\\n\" produced no tokens")
        })?;
        let fault = match &cfg.fault {
            FaultSetting::FromEnv => FaultPlan::from_env()?,
            FaultSetting::Off => None,
            FaultSetting::Plan(p) => Some(p.clone()),
        };
        let (tx, rx) = sync_channel::<Ctl<Request>>(cfg.queue_cap.max(1));
        let shared = Arc::new(Shared::default());
        let handle = ServerHandle {
            tx: tx.clone(),
            shared: shared.clone(),
            seq_len: cfg.seq_len,
            pad,
            deadline: cfg.deadline,
        };
        let drain_timeout = cfg.drain_timeout;
        let restart_budget = cfg.restart_budget;
        let shared2 = shared.clone();
        let join = std::thread::spawn(move || {
            // Steady-state serving buffers: one workspace per worker, one
            // logits tensor, one token gather, one score buffer — reused
            // across every batch (and rebuilt fresh after a panic).
            let worker = Worker {
                model,
                cfg,
                shared: shared2,
                make_engine,
                engine: None,
                restarts_left: restart_budget,
                fault,
                started: Instant::now(),
                ws: Workspace::new(),
                logits: Tensor::default(),
                tokens: Vec::new(),
                scores: Vec::new(),
            };
            worker.run(rx);
        });
        Ok(ScoringServer { handle, shared, tx, join: Some(join), drain_timeout })
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// A cloneable health/metrics observer (for the HTTP front end).
    pub fn status(&self) -> ServerStatus {
        ServerStatus { shared: self.shared.clone() }
    }

    /// Snapshot of the rolled-up serving metrics.
    pub fn metrics(&self) -> ServerMetrics {
        self.shared.metrics.lock().unwrap().clone()
    }

    /// Requests currently queued.
    pub fn queue_depth(&self) -> usize {
        self.shared.depth()
    }

    /// Graceful drain with the configured [`ServerConfig::drain_timeout`]:
    /// stop admission, finish queued work, join the worker.
    pub fn shutdown(self) -> ServerMetrics {
        let t = self.drain_timeout;
        self.drain(t)
    }

    /// Graceful drain with an explicit timeout: admission stops immediately
    /// (live [`ServerHandle`] clones get [`ServeError::ShuttingDown`]),
    /// already-admitted requests are completed — until `timeout` elapses,
    /// after which the remainder is failed fast — and the worker is joined.
    /// Never hangs, regardless of how many handle clones clients still hold.
    pub fn drain(mut self, timeout: Duration) -> ServerMetrics {
        self.close(timeout);
        self.shared.metrics.lock().unwrap().clone()
    }

    fn close(&mut self, timeout: Duration) {
        let Some(join) = self.join.take() else { return };
        self.shared.state.store(STATE_DRAINING, Ordering::Release);
        *self.shared.drain_deadline.lock().unwrap() = Some(Instant::now() + timeout);
        // Explicit close protocol: the sentinel queues FIFO behind every
        // admitted request, so the worker finishes the backlog then exits.
        // A full queue just means waiting for the live worker to free a
        // slot; a vanished worker is observed via is_finished. Either way
        // this terminates — shutdown does not depend on clients dropping
        // their handle clones.
        loop {
            if join.is_finished() {
                break;
            }
            match self.tx.try_send(Ctl::Close) {
                Ok(()) => break,
                Err(TrySendError::Full(_)) => std::thread::sleep(Duration::from_millis(1)),
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        let _ = join.join();
    }
}

impl Drop for ScoringServer {
    fn drop(&mut self) {
        let t = self.drain_timeout;
        self.close(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    fn quiet_cfg() -> ServerConfig {
        ServerConfig { fault: FaultSetting::Off, ..ServerConfig::default() }
    }

    #[test]
    fn serves_scores_and_batches() {
        let model = tiny_model(4, 2, false, 100);
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            seq_len: 64,
            ..quiet_cfg()
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        // concurrent clients to force batching
        let mut joins = Vec::new();
        for i in 0..12 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let score = h.score("c:abcd|", if i % 2 == 0 { "abcd." } else { "zzzz." });
                score.unwrap()
            }));
        }
        let scores: Vec<f64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert!(scores.iter().all(|s| s.is_finite() && *s < 0.0));
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.requests, 12);
        assert_eq!(m.errors, 0);
        assert!(m.batches <= 12);
        assert!(m.mean_batch_size() >= 1.0);
        // the worker records one batch-compute sample per batch attempt
        assert_eq!(m.batch_latency.count(), m.batches);
        assert!(m.batch_latency_p50() <= m.batch_latency_p99());
    }

    #[test]
    fn rejects_oversized_requests_with_typed_error() {
        let model = tiny_model(4, 2, false, 101);
        let server = ScoringServer::start(model, quiet_cfg(), || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        let long = "a".repeat(100);
        assert!(matches!(h.score(&long, "b"), Err(ServeError::Rejected(_))));
        assert!(matches!(h.score("", "b"), Err(ServeError::Rejected(_))));
        drop(h);
    }

    #[test]
    fn identical_requests_get_identical_scores_regardless_of_batching() {
        let model = tiny_model(4, 2, true, 102);
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            seq_len: 64,
            ..quiet_cfg()
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        let a = h.score("r:abc|", "cba.").unwrap();
        let b = h.score("r:abc|", "cba.").unwrap();
        assert!((a - b).abs() < 1e-6);
        drop(h);
    }

    #[test]
    fn engine_construction_failure_degrades_not_hangs() {
        let model = tiny_model(4, 2, false, 103);
        let server = ScoringServer::start(model, quiet_cfg(), || -> Result<NativeEngine> {
            Err(anyhow!("no backend here"))
        })
        .unwrap();
        let h = server.handle();
        // the admission path fast-rejects once construction failed; a
        // request racing the construction gets failed by the worker instead
        let r = h.score("c:ab|", "ab.");
        assert!(
            matches!(r, Err(ServeError::Degraded)),
            "want Degraded, got {r:?}"
        );
        assert!(server.status().degraded());
        let m = server.shutdown();
        assert_eq!(m.requests + m.shed, m.errors + m.shed); // nothing succeeded
    }

    #[test]
    fn backoff_caps() {
        let base = Duration::from_millis(1);
        assert_eq!(backoff_delay(base, 1), Duration::from_millis(1));
        assert_eq!(backoff_delay(base, 3), Duration::from_millis(4));
        assert_eq!(backoff_delay(base, 30), Duration::from_millis(100));
    }

    #[test]
    fn queue_cap_env_fallback_is_sane() {
        // (does not set the env var — just pins the default)
        let cfg = ServerConfig::default();
        assert!(cfg.queue_cap >= 1);
    }
}
