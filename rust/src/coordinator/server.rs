//! The scoring server: **continuous batching** over N supervised compute
//! lanes, fed by the dynamic batcher through a **bounded** admission queue,
//! answering option-scoring requests (the serving workload of the e2e
//! example — a compressed model deployed behind a batched endpoint).
//!
//! Batch *formation* and batch *compute* run on different threads: a
//! dedicated collector runs [`next_batch`] non-stop, so a request admitted
//! during batch k's forward pass joins batch k+1 immediately instead of
//! waiting out compute + `max_wait` serially. Formed batches cross a
//! bounded MPMC [`WorkQueue`] (capacity = lane count, so formation runs at
//! most one batch ahead per lane) to [`ServerConfig::workers`] compute
//! lanes (`--workers` / `MERGEMOE_WORKERS`, default 1), each owning its own
//! engine, workspace, and steady-state buffers. `workers = 1` reproduces
//! the pre-split single-worker serving path: one lane executes batches in
//! formation order, and per-request scores are bit-identical (scores are
//! row-independent of batch composition; ARCHITECTURE.md ledger, pinned by
//! `tests/continuous_batching.rs`). The formation-vs-compute overlap is
//! observable: `overlapped` counts batches formed while a lane was mid
//! forward pass, `collector_idle` and per-lane `lane_batches` land on
//! `/metrics`.
//!
//! Overload hardening, end to end:
//!
//! * **Bounded admission** — the queue holds at most
//!   [`ServerConfig::queue_cap`] requests (`--queue-cap` /
//!   `MERGEMOE_QUEUE_CAP`); a full queue sheds the request immediately with
//!   the typed [`ServeError::Overloaded`] instead of buffering unbounded
//!   latency. Queue depth is observable ([`ServerStatus::queue_depth`]).
//! * **Deadlines** — [`ServerConfig::deadline`] stamps every request with an
//!   expiry; the batcher flushes deadline-aware and partitions out expired
//!   items, which are failed with [`ServeError::DeadlineExceeded`] *before*
//!   any forward-pass compute is spent on them.
//! * **Fault classification + retry** — engine errors are classified
//!   [`FaultClass::Transient`] or [`FaultClass::Fatal`]
//!   ([`crate::util::fault::classify`]). Transient batch failures retry
//!   under capped exponential backoff; a batch that keeps failing is split
//!   in half recursively, so one poison request fails alone instead of
//!   failing its batchmates. Fatal errors fail the batch fast.
//! * **Lane supervision** — a panic mid-batch is caught, the in-flight
//!   requests are failed with [`ServeError::WorkerPanicked`], and the lane
//!   respawns with a fresh engine + workspace (panics can leave both
//!   mid-update) under the **shared** [`ServerConfig::restart_budget`] all
//!   lanes draw from; past the budget the server degrades to
//!   fast-rejecting ([`ServeError::Degraded`], visible on `/healthz`).
//! * **Graceful drain** — [`ScoringServer::shutdown`] / [`drain`](ScoringServer::drain)
//!   stop admission (state flip observed by every handle clone), enqueue an
//!   explicit close sentinel behind the admitted work, let the collector
//!   flush the backlog into the lane queue and close it, then join every
//!   lane once it has drained its share — all under a drain timeout.
//!   Shutdown never depends on clients dropping their [`ServerHandle`]
//!   clones.
//!
//! * **Atomic hot-swap** ([`AdminHandle::swap_in`]) — the serving weights
//!   live in a mutex-guarded [`VariantSlot`] (an `Arc<ModelWeights>` plus a
//!   `name@vN` label) mirrored by a generation counter. A swap stages the
//!   candidate completely *outside* the slot — shape compatibility against
//!   the incumbent, then a pinned probe request scored under
//!   `catch_unwind` — and only a fully verified candidate is committed
//!   (slot write + generation bump under the lock). The worker notices the
//!   new generation between batches; the batch in flight finishes on the
//!   old `Arc`, so **zero in-flight requests are dropped or failed by a
//!   swap**, and a failed stage rolls back with the incumbent untouched
//!   (`swaps` / `swap_rollbacks` metrics, label visible on `/healthz`).
//! * **Validated config hot-reload** ([`AdminHandle::apply_tuning`]) —
//!   queue cap (soft, within the structural channel capacity), deadline,
//!   retry budget/backoff, and the fault plan re-read from a
//!   [`crate::config::ServerTuning`] document via validate-then-commit:
//!   a rejected document changes nothing and is reported on `/healthz`
//!   (`reloads` / `reload_failures` metrics).
//!
//! Every path above is driven deterministically by
//! [`crate::util::fault::FaultPlan`] (`MERGEMOE_FAULT`), so the robustness
//! behaviors are reproducible tier-1 tests (`tests/fault_injection.rs`,
//! `tests/registry.rs`), not claims. With no plan configured the
//! steady-state loop is the exact unhardened execution: gather tokens,
//! forward, score, reply — each lane reusing one [`Workspace`], one logits
//! tensor, one token buffer and one score buffer, so it runs without
//! touching the allocator once the arena is warm (an `Arc` clone on swap is
//! pointer bookkeeping, not a weight copy). Workspaces are per-lane by
//! contract: never shared across threads.
//!
//! Engine objects wrap PJRT client state and are not `Send` (which is also
//! why lanes cannot be handed [`Engine::fork`] results across threads), so
//! every lane *constructs* its own engine inside its thread from one shared
//! `Fn` factory closure (called again on every respawn) — equivalent
//! independent ownership; clients hold a cheap cloneable handle.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{
    AtomicBool, AtomicIsize, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{mpsc::sync_channel, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{next_batch, partition_by_key, BatchDecision, Ctl, WorkItem};
use super::cache::{CacheConfig, CacheError, CacheStats, VariantCache, VariantKey};
use super::metrics::ServerMetrics;
use super::registry::Registry;
use crate::config::ServerTuning;
use crate::eval::tasks;
use crate::model::native::target_logprobs_into;
use crate::model::workspace::Workspace;
use crate::model::ModelWeights;
use crate::runtime::{Engine, NativeEngine};
use crate::tensor::Tensor;
use crate::util::fault::{classify, FaultAction, FaultClass, FaultPlan, InjectedFault};
use crate::util::par::WorkQueue;

/// Typed request-path errors: every way the hardened server can refuse or
/// fail a request, distinguishable by clients (and mapped to HTTP statuses
/// by [`crate::coordinator::http`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded admission queue is full — load was shed. Back off and
    /// retry.
    Overloaded,
    /// The request's deadline passed before its forward pass started.
    DeadlineExceeded,
    /// The worker panicked while this request was in flight.
    WorkerPanicked,
    /// The worker exhausted its restart budget (or never built an engine);
    /// the server is fast-rejecting until restarted.
    Degraded,
    /// The server is draining or stopped; no new work is admitted.
    ShuttingDown,
    /// The request itself is invalid (empty or longer than `seq_len`).
    Rejected(String),
    /// The engine failed this request fatally or exhausted its retries.
    Engine(String),
    /// The requested variant is quarantined (its build failed fatally or
    /// exhausted retries) and the fallback policy is
    /// [`RouteFallback::Reject`].
    VariantUnavailable(String),
    /// The requested variant cannot fit the cache budget even after
    /// evicting every unpinned entry.
    BudgetExceeded(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "overloaded: admission queue full"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded while queued"),
            ServeError::WorkerPanicked => write!(f, "worker panicked mid-batch"),
            ServeError::Degraded => write!(f, "server degraded: restart budget exhausted"),
            ServeError::ShuttingDown => write!(f, "server shutting down"),
            ServeError::Rejected(why) => write!(f, "request rejected: {why}"),
            ServeError::Engine(why) => write!(f, "engine failure: {why}"),
            ServeError::VariantUnavailable(why) => {
                write!(f, "variant unavailable: {why}")
            }
            ServeError::BudgetExceeded(why) => {
                write!(f, "cache budget exceeded: {why}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// A successful routed score: the value plus whether the configured
/// [`RouteFallback::Base`] policy served it on the boot variant because the
/// requested variant was quarantined.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreOutcome {
    /// Mean completion log-probability (what [`ServerHandle::score`]
    /// returns unwrapped).
    pub score: f64,
    /// True iff this score was computed on the boot variant *instead of*
    /// the requested one (quarantine fallback).
    pub fallback: bool,
}

/// What to do with traffic routed at a quarantined variant
/// (`--route-fallback`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouteFallback {
    /// Fail fast with the typed [`ServeError::VariantUnavailable`]. The
    /// default: never silently answer with different weights.
    #[default]
    Reject,
    /// Serve on the boot variant, marking the response `fallback=true`.
    Base,
}

impl RouteFallback {
    /// Parse a `--route-fallback` value (`"base"` or `"reject"`).
    pub fn parse(s: &str) -> Result<RouteFallback> {
        match s.trim().to_ascii_lowercase().as_str() {
            "reject" => Ok(RouteFallback::Reject),
            "base" | "boot" => Ok(RouteFallback::Base),
            other => bail!("unknown route-fallback {other:?} (want base|reject)"),
        }
    }
}

/// How the server sources its fault-injection plan.
#[derive(Debug, Clone, Default)]
pub enum FaultSetting {
    /// Consult `MERGEMOE_FAULT` (unset ⇒ no injection). The default.
    #[default]
    FromEnv,
    /// Never inject, regardless of the environment.
    Off,
    /// Use this plan (tests script exact failure schedules this way).
    Plan(Arc<FaultPlan>),
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Largest batch handed to the engine.
    pub max_batch: usize,
    /// Longest a flush waits on the oldest queued request.
    pub max_wait: Duration,
    /// Padded sequence length every request is resized to.
    pub seq_len: usize,
    /// Bounded admission-queue capacity; a full queue sheds with
    /// [`ServeError::Overloaded`]. Default: `MERGEMOE_QUEUE_CAP` or 256.
    pub queue_cap: usize,
    /// Per-request deadline (admission → forward-pass start). `None`
    /// disables expiry.
    pub deadline: Option<Duration>,
    /// Transient-failure retries per (sub-)batch before splitting/failing.
    pub max_retries: u32,
    /// Base of the capped exponential retry backoff.
    pub retry_backoff: Duration,
    /// Worker respawns allowed before the server degrades to
    /// fast-rejecting.
    pub restart_budget: u32,
    /// Drain window for [`ScoringServer::shutdown`]: queued work older than
    /// this is failed with [`ServeError::ShuttingDown`] instead of computed.
    pub drain_timeout: Duration,
    /// Fault-injection source (see [`FaultSetting`]).
    pub fault: FaultSetting,
    /// Compute lanes pulling formed batches from the collector. `1` (the
    /// default) executes batches one at a time in formation order — the
    /// single-worker serving path. Default: `MERGEMOE_WORKERS` or 1.
    pub workers: usize,
    /// Variant-cache tuning (byte budget, build retries, calibration size).
    /// The budget default honors `MERGEMOE_CACHE_BUDGET_MB`.
    pub cache: CacheConfig,
    /// Policy for traffic routed at a quarantined variant.
    pub route_fallback: RouteFallback,
}

fn env_workers() -> usize {
    match std::env::var("MERGEMOE_WORKERS") {
        Ok(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                crate::warnlog!("ignoring invalid MERGEMOE_WORKERS={v:?} (want integer >= 1)");
                1
            }
        },
        Err(_) => 1,
    }
}

fn env_queue_cap() -> usize {
    match std::env::var("MERGEMOE_QUEUE_CAP") {
        Ok(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                crate::warnlog!("ignoring invalid MERGEMOE_QUEUE_CAP={v:?} (want integer >= 1)");
                256
            }
        },
        Err(_) => 256,
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            seq_len: 64,
            queue_cap: env_queue_cap(),
            deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_micros(500),
            restart_budget: 3,
            drain_timeout: Duration::from_secs(5),
            fault: FaultSetting::FromEnv,
            workers: env_workers(),
            cache: CacheConfig::default(),
            route_fallback: RouteFallback::Reject,
        }
    }
}

/// A scoring request: mean log-probability of `completion` given `prompt`.
struct Request {
    tokens: Vec<i32>,
    prompt_len: usize,
    completion_len: usize,
    submitted: Instant,
    deadline: Option<Instant>,
    /// Which compressed variant to score on; `None` = the boot/hot-swapped
    /// slot (exactly the pre-routing behavior). The collector never mixes
    /// variants within a batch.
    variant: Option<VariantKey>,
    reply: Sender<Result<ScoreOutcome, ServeError>>,
}

const STATE_RUNNING: u8 = 0;
const STATE_DRAINING: u8 = 1;

/// Poison-tolerant lock for observability paths: a thread that panicked
/// while holding one of these mutexes must never take down `/healthz` or
/// `/metrics` — the guarded values (counters, label strings) stay readable
/// whatever the poisoner was mid-writing.
fn lock_tolerant<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The hot-swappable serving weights: what the worker forwards with, plus
/// the `name@vN` label `/healthz` reports. Guarded by `Shared::slot`; the
/// [`Shared::model_gen`] mirror lets the worker detect a swap with one
/// atomic load per batch instead of taking the lock.
struct VariantSlot {
    model: Arc<ModelWeights>,
    label: String,
}

/// Worker-side hot-reloadable knobs (the admission-side ones — soft queue
/// cap, deadline — live directly in atomics on [`Shared`]).
struct WorkerTuning {
    max_retries: u32,
    retry_backoff: Duration,
    fault: Option<Arc<FaultPlan>>,
}

/// State shared between handles, the worker, and status observers.
struct Shared {
    state: AtomicU8,
    degraded: AtomicBool,
    /// Queue-depth gauge. Signed: a client increments strictly *after* a
    /// successful `try_send`, so `depth >= n` proves n items truly sit in
    /// the channel (tests rely on that to fill the queue race-free); the
    /// worker's decrement can then transiently win the race and drive the
    /// value to -1, which the getters clamp to 0.
    depth: AtomicIsize,
    drain_deadline: Mutex<Option<Instant>>,
    metrics: Mutex<ServerMetrics>,
    /// Current serving variant; replaced whole on hot-swap.
    slot: Mutex<VariantSlot>,
    /// Bumped (under the `slot` lock) on every committed swap.
    model_gen: AtomicU64,
    /// Soft admission cap — hot-reloadable, never above the structural
    /// channel capacity (validated in [`AdminHandle::apply_tuning`]).
    soft_cap: AtomicUsize,
    /// Per-request deadline in µs; 0 = disabled. Hot-reloadable.
    deadline_us: AtomicU64,
    /// Hot-reloadable worker knobs; mirrored by `tuning_gen`.
    wtuning: Mutex<WorkerTuning>,
    /// Bumped on every committed tuning reload.
    tuning_gen: AtomicU64,
    /// Outcome of the most recent reload attempt (`/healthz`).
    last_reload: Mutex<String>,
    /// Why the server degraded (empty while healthy).
    degraded_reason: Mutex<String>,
    /// Restart budget the server booted with (for `/healthz` accounting).
    restart_budget: u32,
    /// Respawns still available — one pool shared by every lane.
    restarts_left: AtomicU32,
    /// Compute lanes the server booted with.
    workers: usize,
    /// True while the collector has no batch in hand (blocked in batch
    /// formation / waiting for requests); false while handing a formed
    /// batch to the lanes. `/metrics` gauge.
    collector_idle: AtomicBool,
    /// Lanes currently inside [`Lane::execute`]; the collector samples this
    /// at handoff to count formation-vs-compute overlap (`overlapped`).
    computing: AtomicUsize,
    /// Memory-budgeted compressed-variant cache; lanes check routed batches
    /// out of it per batch (pin for the duration of compute).
    cache: Arc<VariantCache>,
    /// Policy for traffic routed at a quarantined variant.
    route_fallback: RouteFallback,
}

impl Shared {
    fn new(
        cfg: &ServerConfig,
        model: Arc<ModelWeights>,
        label: String,
        fault: Option<Arc<FaultPlan>>,
        cache: Arc<VariantCache>,
    ) -> Shared {
        Shared {
            state: AtomicU8::new(STATE_RUNNING),
            degraded: AtomicBool::new(false),
            depth: AtomicIsize::new(0),
            drain_deadline: Mutex::new(None),
            metrics: Mutex::new(ServerMetrics::default()),
            slot: Mutex::new(VariantSlot { model, label }),
            model_gen: AtomicU64::new(0),
            soft_cap: AtomicUsize::new(cfg.queue_cap.max(1)),
            deadline_us: AtomicU64::new(
                cfg.deadline.map_or(0, |d| d.as_micros().max(1) as u64),
            ),
            wtuning: Mutex::new(WorkerTuning {
                max_retries: cfg.max_retries,
                retry_backoff: cfg.retry_backoff,
                fault,
            }),
            tuning_gen: AtomicU64::new(0),
            last_reload: Mutex::new("never".into()),
            degraded_reason: Mutex::new(String::new()),
            restart_budget: cfg.restart_budget,
            restarts_left: AtomicU32::new(cfg.restart_budget),
            workers: cfg.workers.max(1),
            collector_idle: AtomicBool::new(true),
            computing: AtomicUsize::new(0),
            cache,
            route_fallback: cfg.route_fallback,
        }
    }

    /// Claim one respawn from the shared restart budget. `false` once the
    /// budget is exhausted — the claiming lane should degrade the server.
    fn try_claim_restart(&self) -> bool {
        self.restarts_left
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1))
            .is_ok()
    }

    fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed).max(0) as usize
    }

    /// The hot-reloadable server-default deadline (0 ⇔ disabled).
    fn hot_deadline(&self) -> Option<Duration> {
        match self.deadline_us.load(Ordering::Relaxed) {
            0 => None,
            us => Some(Duration::from_micros(us)),
        }
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Ctl<Request>>,
    shared: Arc<Shared>,
    seq_len: usize,
    /// Padding token, resolved once (fallibly) at server construction
    /// instead of re-tokenizing "\n" on every request.
    pad: i32,
}

impl ServerHandle {
    /// Score a (prompt, completion) pair; blocks until the batched backend
    /// answers or refuses. Thread-safe; call from many threads to exercise
    /// batching. Uses the server's configured (hot-reloadable) deadline.
    pub fn score(&self, prompt: &str, completion: &str) -> Result<f64, ServeError> {
        self.score_with_deadline(prompt, completion, self.shared.hot_deadline())
    }

    /// [`score`](Self::score) with an explicit per-request deadline
    /// (`None` = no expiry), overriding the server default.
    pub fn score_with_deadline(
        &self,
        prompt: &str,
        completion: &str,
        deadline: Option<Duration>,
    ) -> Result<f64, ServeError> {
        self.score_routed_with_deadline(prompt, completion, None, deadline)
            .map(|o| o.score)
    }

    /// Resolve a `{method, ratio, calib_source}` triple against the base
    /// (boot) model into a canonical [`VariantKey`], rejecting unknown
    /// methods, out-of-range ratios, and unparsable calibration sources
    /// with [`ServeError::Rejected`].
    pub fn resolve_variant(
        &self,
        method: &str,
        ratio: f64,
        calib: &str,
    ) -> Result<VariantKey, ServeError> {
        VariantKey::resolve(method, ratio, calib, self.shared.cache.base().cfg.n_experts)
            .map_err(|e| ServeError::Rejected(format!("{e:#}")))
    }

    /// Score on a specific compressed variant (`None` = boot variant —
    /// exactly [`score`](Self::score)). The variant is built/loaded on
    /// demand by the cache; the outcome says whether fallback served it.
    pub fn score_routed(
        &self,
        prompt: &str,
        completion: &str,
        variant: Option<VariantKey>,
    ) -> Result<ScoreOutcome, ServeError> {
        self.score_routed_with_deadline(prompt, completion, variant, self.shared.hot_deadline())
    }

    /// [`score_routed`](Self::score_routed) with an explicit deadline.
    pub fn score_routed_with_deadline(
        &self,
        prompt: &str,
        completion: &str,
        variant: Option<VariantKey>,
        deadline: Option<Duration>,
    ) -> Result<ScoreOutcome, ServeError> {
        let ptoks = tasks::encode(prompt);
        let ctoks = tasks::encode(completion);
        let prompt_len = ptoks.len();
        let completion_len = ctoks.len();
        if prompt_len == 0 || completion_len == 0 {
            return Err(ServeError::Rejected(
                "prompt and completion must be non-empty".into(),
            ));
        }
        if prompt_len + completion_len > self.seq_len {
            return Err(ServeError::Rejected("request longer than seq_len".into()));
        }
        if self.shared.state.load(Ordering::Acquire) != STATE_RUNNING {
            return Err(ServeError::ShuttingDown);
        }
        if self.shared.degraded.load(Ordering::Acquire) {
            return Err(ServeError::Degraded);
        }
        // soft admission cap (hot-reloadable, ≤ structural capacity): shed
        // here when a reload tightened the cap below the channel's size —
        // the structural `try_send` bound below remains the backstop
        if self.shared.depth() >= self.shared.soft_cap.load(Ordering::Relaxed) {
            lock_tolerant(&self.shared.metrics).shed += 1;
            return Err(ServeError::Overloaded);
        }
        let mut toks = ptoks;
        toks.extend(ctoks);
        toks.resize(self.seq_len, self.pad);
        let submitted = Instant::now();
        let (rtx, rrx) = channel();
        let req = Request {
            tokens: toks,
            prompt_len,
            completion_len,
            submitted,
            deadline: deadline.map(|d| submitted + d),
            variant,
            reply: rtx,
        };
        match self.tx.try_send(Ctl::Item(req)) {
            Ok(()) => {
                self.shared.depth.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(_)) => {
                lock_tolerant(&self.shared.metrics).shed += 1;
                return Err(ServeError::Overloaded);
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err(ServeError::ShuttingDown);
            }
        }
        // the supervised worker replies to every admitted request; a
        // dropped reply channel means it died outside its own supervision
        rrx.recv().map_err(|_| ServeError::WorkerPanicked)?
    }

    /// Requests currently queued (admission gauge).
    pub fn queue_depth(&self) -> usize {
        self.shared.depth()
    }
}

/// Read-only observer of server health + metrics (what `/healthz` and
/// `/metrics` render; cloneable into the HTTP front end).
#[derive(Clone)]
pub struct ServerStatus {
    shared: Arc<Shared>,
}

impl ServerStatus {
    /// Snapshot of the rolled-up serving metrics.
    pub fn metrics(&self) -> ServerMetrics {
        lock_tolerant(&self.shared.metrics).clone()
    }

    /// True once the worker's restart budget is exhausted (the server
    /// fast-rejects until restarted).
    pub fn degraded(&self) -> bool {
        self.shared.degraded.load(Ordering::Acquire)
    }

    /// True once a drain/shutdown has begun (admission stopped).
    pub fn draining(&self) -> bool {
        self.shared.state.load(Ordering::Acquire) != STATE_RUNNING
    }

    /// Requests currently queued.
    pub fn queue_depth(&self) -> usize {
        self.shared.depth()
    }

    /// `name@vN` label of the variant currently serving.
    pub fn variant(&self) -> String {
        lock_tolerant(&self.shared.slot).label.clone()
    }

    /// Outcome of the most recent config reload attempt (`"never"`, `"ok"`,
    /// or `"rejected: <why>"`).
    pub fn last_reload(&self) -> String {
        lock_tolerant(&self.shared.last_reload).clone()
    }

    /// Why the server degraded; `None` while healthy.
    pub fn degraded_reason(&self) -> Option<String> {
        if !self.degraded() {
            return None;
        }
        Some(lock_tolerant(&self.shared.degraded_reason).clone())
    }

    /// Snapshot of the variant-cache gauges/counters (`/metrics`).
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.snapshot()
    }

    /// Worker restarts consumed so far.
    pub fn restarts_used(&self) -> u64 {
        lock_tolerant(&self.shared.metrics).restarted
    }

    /// Worker restart budget the server booted with.
    pub fn restart_budget(&self) -> u32 {
        self.shared.restart_budget
    }

    /// Compute lanes the server booted with.
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// True while the collector has no batch in hand (blocked in batch
    /// formation / waiting for requests).
    pub fn collector_idle(&self) -> bool {
        self.shared.collector_idle.load(Ordering::Acquire)
    }
}

/// Administrative handle: variant hot-swap and config hot-reload. Cloneable
/// into the HTTP front end (`POST /admin/swap`, `POST /admin/reload`).
/// Both operations are **validate-then-commit**: every fallible step runs
/// against staged state, and the serving path only ever observes either the
/// unchanged incumbent or a fully verified replacement.
#[derive(Clone)]
pub struct AdminHandle {
    shared: Arc<Shared>,
    /// The sync-channel capacity the server booted with; the reloadable
    /// soft cap must stay within it (the channel cannot grow live).
    structural_cap: usize,
    seq_len: usize,
    pad: i32,
}

impl AdminHandle {
    /// Atomically swap the serving weights to `model` under live traffic.
    ///
    /// Stage: shape compatibility against the incumbent (vocabulary, max
    /// sequence length), then a pinned probe request scored with the native
    /// reference engine under `catch_unwind` — a candidate whose weights
    /// panic the forward pass or score non-finite never reaches the slot.
    /// Commit: slot write + generation bump; the worker picks the new
    /// `Arc` up between batches, so in-flight requests finish on the old
    /// weights and none are dropped. Any stage failure rolls back with the
    /// incumbent untouched (`swap_rollbacks`).
    pub fn swap_in(&self, model: ModelWeights, label: &str) -> Result<()> {
        let staged = self.stage(model);
        match staged {
            Ok(m) => {
                {
                    let mut slot = self.shared.slot.lock().unwrap();
                    slot.model = m;
                    slot.label = label.to_string();
                    // bump under the lock: a worker that sees the new
                    // generation always finds the new model in the slot
                    self.shared.model_gen.fetch_add(1, Ordering::Release);
                }
                lock_tolerant(&self.shared.metrics).swaps += 1;
                crate::info!("hot-swapped serving variant to {label}");
                Ok(())
            }
            Err(e) => {
                lock_tolerant(&self.shared.metrics).swap_rollbacks += 1;
                crate::warnlog!("hot-swap to {label} rolled back: {e:#}");
                Err(e.context("hot-swap rolled back; incumbent variant unchanged"))
            }
        }
    }

    /// The fallible half of [`AdminHandle::swap_in`]: everything that can
    /// reject the candidate, run before anything is committed.
    fn stage(&self, model: ModelWeights) -> Result<Arc<ModelWeights>> {
        let vocab_new = model.tok_emb.shape()[0];
        let max_seq = model.pos_emb.shape()[0];
        {
            let slot = self.shared.slot.lock().unwrap();
            let vocab_old = slot.model.tok_emb.shape()[0];
            if vocab_new != vocab_old {
                bail!(
                    "candidate vocabulary {vocab_new} != serving vocabulary {vocab_old}"
                );
            }
        }
        if max_seq < self.seq_len {
            bail!(
                "candidate max sequence length {max_seq} < serving seq_len {}",
                self.seq_len
            );
        }
        probe_model(&model, self.seq_len, self.pad)?;
        Ok(Arc::new(model))
    }

    /// Apply a validated [`ServerTuning`] document (validate-then-commit).
    /// Absent fields keep the incumbent value. A rejected document changes
    /// nothing, counts `reload_failures`, and is reported on `/healthz`.
    pub fn apply_tuning(&self, t: &ServerTuning) -> Result<()> {
        let staged = (|| -> Result<Option<Option<Arc<FaultPlan>>>> {
            if let Some(cap) = t.queue_cap {
                if cap > self.structural_cap {
                    bail!(
                        "queue_cap {cap} exceeds the structural channel capacity {} \
                         the server booted with",
                        self.structural_cap
                    );
                }
            }
            // outer None = leave injection alone; inner None = turn it off
            Ok(match &t.fault {
                None => None,
                Some(spec) if spec.trim().is_empty() => Some(None),
                Some(spec) => Some(Some(Arc::new(
                    FaultPlan::parse(spec).context("constructing fault plan")?,
                ))),
            })
        })();
        match staged {
            Ok(fault) => {
                if let Some(cap) = t.queue_cap {
                    self.shared.soft_cap.store(cap, Ordering::Relaxed);
                }
                if let Some(ms) = t.deadline_ms {
                    self.shared.deadline_us.store(ms.saturating_mul(1000), Ordering::Relaxed);
                }
                {
                    let mut w = self.shared.wtuning.lock().unwrap();
                    if let Some(r) = t.max_retries {
                        w.max_retries = r;
                    }
                    if let Some(us) = t.retry_backoff_us {
                        w.retry_backoff = Duration::from_micros(us);
                    }
                    if let Some(f) = fault {
                        w.fault = f;
                    }
                    self.shared.tuning_gen.fetch_add(1, Ordering::Release);
                }
                *self.shared.last_reload.lock().unwrap() = "ok".into();
                lock_tolerant(&self.shared.metrics).reloads += 1;
                crate::info!("config reload committed");
                Ok(())
            }
            Err(e) => {
                self.record_reload_failure(&e);
                Err(e)
            }
        }
    }

    /// Re-read and apply a `--config-file` tuning document
    /// ([`ServerTuning::load`] + [`AdminHandle::apply_tuning`]); parse and
    /// validation failures are recorded exactly like apply failures.
    pub fn reload_from(&self, path: &std::path::Path) -> Result<()> {
        match ServerTuning::load(path) {
            Ok(t) => self.apply_tuning(&t),
            Err(e) => {
                self.record_reload_failure(&e);
                Err(e)
            }
        }
    }

    fn record_reload_failure(&self, e: &anyhow::Error) {
        *self.shared.last_reload.lock().unwrap() = format!("rejected: {e:#}");
        lock_tolerant(&self.shared.metrics).reload_failures += 1;
        crate::warnlog!("config reload rejected (incumbent tuning kept): {e:#}");
    }
}

/// Smoke-score a pinned probe request against `model` with the native
/// reference engine, on the caller's thread, panics contained. The serving
/// engine is not consulted — the probe certifies the *weights* are
/// servable (finite scores, no panic); engine-specific state is rebuilt
/// per-worker anyway.
fn probe_model(model: &ModelWeights, seq_len: usize, pad: i32) -> Result<()> {
    const PROBE_PROMPT: &str = "c:abcd|";
    const PROBE_COMPLETION: &str = "abcd.";
    let ptoks = tasks::encode(PROBE_PROMPT);
    let ctoks = tasks::encode(PROBE_COMPLETION);
    let (pl, cl) = (ptoks.len(), ctoks.len());
    if pl + cl > seq_len {
        bail!("probe longer than seq_len {seq_len}");
    }
    let mut tokens = ptoks;
    tokens.extend(ctoks);
    tokens.resize(seq_len, pad);
    let result = catch_unwind(AssertUnwindSafe(|| -> Result<f64> {
        let mut ws = Workspace::new();
        let mut logits = Tensor::default();
        let mut engine = NativeEngine;
        engine.logits_ws(model, &tokens, 1, seq_len, &mut ws, &mut logits)?;
        target_logprobs_into(&logits, &tokens, 1, seq_len, &mut ws.lps);
        let mut sum = 0.0f64;
        for si in (pl - 1)..(pl + cl - 1) {
            sum += ws.lps[si] as f64;
        }
        Ok(sum / cl as f64)
    }));
    match result {
        Ok(Ok(score)) if score.is_finite() => Ok(()),
        Ok(Ok(score)) => bail!("probe produced a non-finite score ({score})"),
        Ok(Err(e)) => Err(e.context("probe forward pass failed")),
        Err(_) => bail!("probe forward pass panicked"),
    }
}

/// Outcome of one batch-execution attempt.
enum BatchError {
    /// The attempt panicked; message extracted from the payload.
    Panicked(String),
    /// The attempt failed with a classified engine error.
    Failed(FaultClass, String),
}

/// A formed batch in flight from the collector to a lane: one variant per
/// batch (the collector splits a flush by [`Request::variant`], so a lane
/// checks out at most one cache entry per batch and scores never mix
/// weights).
struct FormedBatch {
    /// `None` = boot/hot-swapped slot.
    variant: Option<VariantKey>,
    items: Vec<WorkItem<Request>>,
}

/// Reply [`ServeError::DeadlineExceeded`] to an item whose deadline passed
/// while queued (no forward pass was spent on it), recording its latency
/// and the expiry counters.
fn fail_expired(shared: &Shared, it: WorkItem<Request>) {
    let r = &it.payload;
    let mut m = lock_tolerant(&shared.metrics);
    m.requests += 1;
    m.errors += 1;
    m.expired += 1;
    m.queue_latency.record(it.enqueued.duration_since(r.submitted));
    m.total_latency.record(r.submitted.elapsed());
    let _ = r.reply.send(Err(ServeError::DeadlineExceeded));
}

/// Reply `err` to every item, recording request/error counters and latency
/// (failures are visible in p99, not invisible).
fn fail_all(shared: &Shared, items: Vec<WorkItem<Request>>, err: ServeError) {
    let mut m = lock_tolerant(&shared.metrics);
    for it in items {
        let r = &it.payload;
        m.requests += 1;
        m.errors += 1;
        m.queue_latency.record(it.enqueued.duration_since(r.submitted));
        m.total_latency.record(r.submitted.elapsed());
        let _ = r.reply.send(Err(err.clone()));
    }
}

/// Closes the lanes' work queue when dropped — attached to the collector
/// thread so a collector that unwinds can never strand lanes in
/// [`WorkQueue::pop`].
struct CloseQueueOnDrop(Arc<WorkQueue<FormedBatch>>);

impl Drop for CloseQueueOnDrop {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// The batch-formation half of the server: runs [`next_batch`] continuously
/// on its own thread and hands every formed batch to the lanes' queue —
/// which is what lets batch k+1 form while batch k computes. Expired items
/// are failed here (they never cost a lane anything); their queue-depth
/// decrement happens with the reply, while ready items are decremented by
/// the lane that pops them (so `depth` keeps counting work the server has
/// not yet started).
fn run_collector(
    shared: &Shared,
    rx: &Receiver<Ctl<Request>>,
    queue: &WorkQueue<FormedBatch>,
    max_batch: usize,
    max_wait: Duration,
) {
    loop {
        shared.collector_idle.store(true, Ordering::Release);
        let decision = next_batch(rx, max_batch, max_wait, |r: &Request| r.deadline);
        shared.collector_idle.store(false, Ordering::Release);
        match decision {
            BatchDecision::Shutdown => break,
            BatchDecision::Flush(batch) => {
                if !batch.expired.is_empty() {
                    shared
                        .depth
                        .fetch_sub(batch.expired.len() as isize, Ordering::Relaxed);
                    for it in batch.expired {
                        fail_expired(shared, it);
                    }
                }
                if !batch.ready.is_empty() {
                    // overlap counter: a lane is mid-forward right now, so
                    // this batch formed during compute — the continuous
                    // batching win, pinned by tests/continuous_batching.rs
                    if shared.computing.load(Ordering::Acquire) > 0 {
                        lock_tolerant(&shared.metrics).overlapped += 1;
                    }
                    // one formed batch per distinct variant: routing must
                    // never mix weights within a forward pass. Stable
                    // partition, so the workers=1 path still executes
                    // requests in formation order per variant.
                    // Only the collector itself closes the queue (on exit),
                    // so a push can never observe a closed queue.
                    for (variant, items) in
                        partition_by_key(batch.ready, |r: &Request| r.variant.clone())
                    {
                        let _ = queue.push(FormedBatch { variant, items });
                    }
                }
                if batch.close {
                    break;
                }
            }
        }
    }
}

/// One compute lane: owns an engine and every steady-state buffer; holds
/// the serving weights as an `Arc` refreshed from the shared
/// [`VariantSlot`] between batches (never mid-batch — an in-flight batch
/// always finishes on the weights it started with). Lives entirely on its
/// own thread; the engine factory is shared (`Arc<F>`, `Fn`) because every
/// lane — and every supervised respawn — constructs from it.
struct Lane<E, F> {
    id: usize,
    model: Arc<ModelWeights>,
    cfg: ServerConfig,
    shared: Arc<Shared>,
    make_engine: Arc<F>,
    engine: Option<E>,
    fault: Option<Arc<FaultPlan>>,
    /// Last observed [`Shared::model_gen`] / [`Shared::tuning_gen`].
    model_gen_seen: u64,
    tuning_gen_seen: u64,
    started: Instant,
    ws: Workspace,
    logits: Tensor,
    tokens: Vec<i32>,
    scores: Vec<f64>,
    /// True while the current batch is being served on the boot variant
    /// *instead of* its requested one ([`RouteFallback::Base`]); stamped
    /// into every [`ScoreOutcome`] the batch replies with.
    fallback: bool,
}

impl<E: Engine, F: Fn() -> Result<E>> Lane<E, F> {
    fn run(mut self, queue: &WorkQueue<FormedBatch>) {
        match (self.make_engine)() {
            Ok(e) => self.engine = Some(e),
            Err(e) => {
                crate::warnlog!("engine construction failed: {e:#}");
                self.degrade("engine construction failed");
                // keep popping: an engine-less lane fails its share of the
                // work fast instead of letting it pile up in the queue
            }
        }
        while let Some(batch) = queue.pop() {
            self.shared.depth.fetch_sub(batch.items.len() as isize, Ordering::Relaxed);
            self.refresh();
            self.dispatch(batch);
        }
    }

    /// Pick up committed hot-swaps / reloads: one atomic load each on the
    /// steady path; the locks are only taken when a generation moved.
    /// Engine-side caches key on `ModelWeights::uid`, so a swapped model
    /// invalidates them naturally on its first batch.
    fn refresh(&mut self) {
        let mg = self.shared.model_gen.load(Ordering::Acquire);
        if mg != self.model_gen_seen {
            let slot = self.shared.slot.lock().unwrap();
            self.model = slot.model.clone();
            self.model_gen_seen = mg;
            crate::debuglog!("worker picked up variant {}", slot.label);
        }
        let tg = self.shared.tuning_gen.load(Ordering::Acquire);
        if tg != self.tuning_gen_seen {
            let w = self.shared.wtuning.lock().unwrap();
            self.cfg.max_retries = w.max_retries;
            self.cfg.retry_backoff = w.retry_backoff;
            self.fault = w.fault.clone();
            self.tuning_gen_seen = tg;
        }
    }

    fn dispatch(&mut self, batch: FormedBatch) {
        if self.engine.is_none() {
            fail_all(&self.shared, batch.items, ServeError::Degraded);
            return;
        }
        // overlap accounting: the collector samples `computing` while
        // handing off (route/execute never unwind — panics are contained
        // in try_batch — so the decrement always runs)
        self.shared.computing.fetch_add(1, Ordering::AcqRel);
        self.route(batch);
        self.shared.computing.fetch_sub(1, Ordering::AcqRel);
    }

    /// Resolve the batch's variant through the cache, then execute on the
    /// checked-out weights. The cache lease pins the variant for the whole
    /// execution (including retries and splits) so LRU eviction can never
    /// free weights mid-forward-pass; the lane's boot/slot model is swapped
    /// back afterwards. Cache refusals become typed replies — or, for a
    /// quarantined variant under [`RouteFallback::Base`], a boot-variant
    /// score marked `fallback=true`.
    fn route(&mut self, batch: FormedBatch) {
        let FormedBatch { variant, items } = batch;
        self.fallback = false;
        let Some(key) = variant else {
            self.execute(items);
            return;
        };
        // the earliest per-item deadline bounds how long a parked checkout
        // may wait on another thread's in-flight build of the same variant
        let deadline = items.iter().filter_map(|it| it.payload.deadline).min();
        match self.shared.cache.checkout(&key, deadline) {
            Ok(lease) => {
                let boot = std::mem::replace(&mut self.model, lease.model().clone());
                self.execute(items);
                self.model = boot;
                drop(lease); // unpin only after the last sub-batch finished
            }
            Err(CacheError::DeadlineExceeded) => {
                // the *earliest* deadline expired while parked; fail exactly
                // the expired items and re-route the rest (their later
                // deadlines grant more parking budget). Terminates: each
                // pass removes at least the item whose deadline fired.
                let now = Instant::now();
                let (expired, live): (Vec<_>, Vec<_>) = items
                    .into_iter()
                    .partition(|it| it.payload.deadline.is_some_and(|d| d <= now));
                for it in expired {
                    fail_expired(&self.shared, it);
                }
                if !live.is_empty() {
                    self.route(FormedBatch { variant: Some(key), items: live });
                }
            }
            Err(CacheError::VariantUnavailable { variant, reason }) => {
                match self.shared.route_fallback {
                    RouteFallback::Base => {
                        crate::debuglog!(
                            "variant {variant} unavailable ({reason}); serving batch on boot variant"
                        );
                        self.fallback = true;
                        self.execute(items);
                        self.fallback = false;
                    }
                    RouteFallback::Reject => fail_all(
                        &self.shared,
                        items,
                        ServeError::VariantUnavailable(format!("{variant}: {reason}")),
                    ),
                }
            }
            Err(e @ CacheError::BudgetExceeded { .. }) => {
                fail_all(&self.shared, items, ServeError::BudgetExceeded(format!("{e}")));
            }
        }
    }

    /// Run one (sub-)batch to completion: retry transient failures under
    /// capped exponential backoff, split persistent failures in half (a
    /// poison request ends up alone and fails alone), fail fatal errors
    /// fast, and hand panics to the supervisor.
    fn execute(&mut self, mut items: Vec<WorkItem<Request>>) {
        // re-check deadlines: retries/splits ahead of this sub-batch may
        // have consumed a request's remaining budget while it waited
        let now = Instant::now();
        if items.iter().any(|it| it.payload.deadline.is_some_and(|d| d <= now)) {
            let (expired, live): (Vec<_>, Vec<_>) = items
                .into_iter()
                .partition(|it| it.payload.deadline.is_some_and(|d| d <= now));
            for it in expired {
                fail_expired(&self.shared, it);
            }
            items = live;
        }
        if items.is_empty() {
            return;
        }
        // past the drain window, queued work is shed instead of computed
        if self.past_drain_deadline() {
            fail_all(&self.shared, items, ServeError::ShuttingDown);
            return;
        }
        let mut attempt = 0u32;
        loop {
            match self.try_batch(&items) {
                Ok(()) => {
                    self.reply_ok(items);
                    return;
                }
                Err(BatchError::Panicked(msg)) => {
                    self.after_panic(items, msg);
                    return;
                }
                Err(BatchError::Failed(FaultClass::Fatal, msg)) => {
                    crate::warnlog!("fatal engine error, failing batch of {}: {msg}", items.len());
                    fail_all(&self.shared, items, ServeError::Engine(msg));
                    return;
                }
                Err(BatchError::Failed(FaultClass::Transient, msg)) => {
                    attempt += 1;
                    if attempt > self.cfg.max_retries {
                        if items.len() > 1 {
                            // persistent transient failure: split so one
                            // poison request cannot fail its batchmates
                            lock_tolerant(&self.shared.metrics).splits += 1;
                            crate::debuglog!(
                                "splitting batch of {} after {attempt} failed attempts",
                                items.len()
                            );
                            let right = items.split_off(items.len() / 2);
                            self.execute(items);
                            self.execute(right);
                        } else {
                            fail_all(&self.shared, items, ServeError::Engine(msg));
                        }
                        return;
                    }
                    lock_tolerant(&self.shared.metrics).retried += 1;
                    let backoff = backoff_delay(self.cfg.retry_backoff, attempt);
                    if !backoff.is_zero() {
                        std::thread::sleep(backoff);
                    }
                }
            }
        }
    }

    /// One engine attempt over `items`: fault-plan consultation, forward
    /// pass, scoring into `self.scores`. Panics are contained here.
    fn try_batch(&mut self, items: &[WorkItem<Request>]) -> Result<(), BatchError> {
        let b = items.len();
        let s = self.cfg.seq_len;
        self.tokens.clear();
        for it in items {
            self.tokens.extend_from_slice(&it.payload.tokens);
        }
        let t_batch = Instant::now();
        let Lane { engine, ws, logits, tokens, scores, model, fault, .. } = self;
        let engine = engine.as_mut().expect("dispatch() guarantees an engine");
        let result = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
            if let Some(plan) = fault.as_deref() {
                match plan.next() {
                    FaultAction::None => {}
                    FaultAction::Slow(d) => std::thread::sleep(d),
                    FaultAction::Transient => {
                        return Err(InjectedFault { class: FaultClass::Transient }.into())
                    }
                    FaultAction::Fatal => {
                        return Err(InjectedFault { class: FaultClass::Fatal }.into())
                    }
                    FaultAction::Panic => panic!("injected worker panic"),
                }
                if plan.is_poisoned(tokens) {
                    return Err(InjectedFault { class: FaultClass::Transient }.into());
                }
            }
            engine.logits_ws(model.as_ref(), tokens, b, s, ws, logits)?;
            target_logprobs_into(logits, tokens, b, s, &mut ws.lps);
            scores.clear();
            for (bi, it) in items.iter().enumerate() {
                let r = &it.payload;
                let mut sum = 0.0f64;
                for si in (r.prompt_len - 1)..(r.prompt_len + r.completion_len - 1) {
                    sum += ws.lps[bi * s + si] as f64;
                }
                scores.push(sum / r.completion_len as f64);
            }
            Ok(())
        }));
        // one batch-counter + compute-latency sample per executed attempt,
        // success or failure, so p99 reflects bad batches too
        {
            let mut m = lock_tolerant(&self.shared.metrics);
            m.batches += 1;
            m.batched_sequences += b as u64;
            m.batch_latency.record(t_batch.elapsed());
            m.wall_seconds = self.started.elapsed().as_secs_f64();
            if m.lane_batches.len() < self.shared.workers {
                m.lane_batches.resize(self.shared.workers, 0);
            }
            m.lane_batches[self.id] += 1;
        }
        match result {
            Ok(Ok(())) => Ok(()),
            Ok(Err(e)) => Err(BatchError::Failed(classify(&e), format!("{e:#}"))),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                Err(BatchError::Panicked(msg))
            }
        }
    }

    /// Supervisor: fail the in-flight requests, then respawn the lane
    /// state (fresh engine + workspace) or degrade once the shared restart
    /// budget is gone.
    fn after_panic(&mut self, items: Vec<WorkItem<Request>>, msg: String) {
        crate::warnlog!(
            "lane {} panicked mid-batch ({msg}); failing {} in-flight request(s)",
            self.id,
            items.len()
        );
        fail_all(&self.shared, items, ServeError::WorkerPanicked);
        // the panic may have interrupted an arena or engine mid-update:
        // discard both and rebuild from scratch
        self.engine = None;
        self.ws = Workspace::new();
        self.logits = Tensor::default();
        if !self.shared.try_claim_restart() {
            self.degrade("worker restart budget exhausted");
            return;
        }
        match (self.make_engine)() {
            Ok(e) => {
                self.engine = Some(e);
                lock_tolerant(&self.shared.metrics).restarted += 1;
                crate::info!(
                    "lane {} respawned with a fresh engine ({} restart(s) left)",
                    self.id,
                    self.shared.restarts_left.load(Ordering::Relaxed)
                );
            }
            Err(e) => {
                crate::warnlog!("engine respawn failed: {e:#}");
                self.degrade("engine respawn failed");
            }
        }
    }

    fn degrade(&self, why: &str) {
        crate::warnlog!("server degraded ({why}): fast-rejecting until restarted");
        // poison-tolerant: a lane must be able to degrade the server even
        // if another panicked lane poisoned the reason lock first
        *lock_tolerant(&self.shared.degraded_reason) = why.to_string();
        self.shared.degraded.store(true, Ordering::Release);
    }

    fn past_drain_deadline(&self) -> bool {
        if self.shared.state.load(Ordering::Acquire) == STATE_RUNNING {
            return false;
        }
        match *self.shared.drain_deadline.lock().unwrap() {
            Some(d) => Instant::now() > d,
            None => false,
        }
    }

    fn reply_ok(&mut self, items: Vec<WorkItem<Request>>) {
        let mut m = lock_tolerant(&self.shared.metrics);
        if self.fallback {
            m.fallbacks += items.len() as u64;
        }
        for (bi, it) in items.iter().enumerate() {
            let r = &it.payload;
            m.requests += 1;
            m.queue_latency.record(it.enqueued.duration_since(r.submitted));
            m.total_latency.record(r.submitted.elapsed());
            let _ = r.reply.send(Ok(ScoreOutcome { score: self.scores[bi], fallback: self.fallback }));
        }
    }

}

/// Capped exponential backoff: `base * 2^(attempt-1)`, capped at 100ms.
fn backoff_delay(base: Duration, attempt: u32) -> Duration {
    const CAP: Duration = Duration::from_millis(100);
    let shift = attempt.saturating_sub(1).min(16);
    base.saturating_mul(1u32 << shift).min(CAP)
}

/// The scoring server. Owns the collector thread and every compute lane;
/// dropping it (or calling [`ScoringServer::shutdown`]) drains and joins
/// them all.
pub struct ScoringServer {
    handle: ServerHandle,
    admin: AdminHandle,
    shared: Arc<Shared>,
    tx: SyncSender<Ctl<Request>>,
    collector: Option<std::thread::JoinHandle<()>>,
    lanes: Vec<std::thread::JoinHandle<()>>,
    drain_timeout: Duration,
}

impl ScoringServer {
    /// Start the server. `make_engine` runs on each lane thread and builds
    /// its backend (e.g. `|| PjrtEngine::new(manifest)`); it is shared by
    /// every lane and called again on every supervised respawn, hence the
    /// `Fn + Sync` bound. Fails fast on construction errors (e.g. an
    /// unresolvable padding token) instead of panicking on the first
    /// request.
    pub fn start<E, F>(model: ModelWeights, cfg: ServerConfig, make_engine: F) -> Result<ScoringServer>
    where
        E: Engine,
        F: Fn() -> Result<E> + Send + Sync + 'static,
    {
        ScoringServer::start_with_registry(model, cfg, None, make_engine)
    }

    /// [`start`](Self::start) with a registry for the variant cache to
    /// probe before compressing from scratch: a routed request whose
    /// variant has a good registry version loads it instead of re-running
    /// compression. `None` (what `start` passes) means every cold variant
    /// is compressed from the boot model.
    pub fn start_with_registry<E, F>(
        model: ModelWeights,
        cfg: ServerConfig,
        registry: Option<Arc<Registry>>,
        make_engine: F,
    ) -> Result<ScoringServer>
    where
        E: Engine,
        F: Fn() -> Result<E> + Send + Sync + 'static,
    {
        let pad = tasks::encode("\n").first().copied().ok_or_else(|| {
            anyhow!("cannot resolve pad token: encoding \"\\n\" produced no tokens")
        })?;
        let fault = match &cfg.fault {
            FaultSetting::FromEnv => FaultPlan::from_env()?,
            FaultSetting::Off => None,
            FaultSetting::Plan(p) => Some(p.clone()),
        };
        let (tx, rx) = sync_channel::<Ctl<Request>>(cfg.queue_cap.max(1));
        // the cache owns the canonical base Arc (compression source +
        // fallback target, outside the byte budget); the slot and lanes
        // boot from the same Arc
        let cache = Arc::new(VariantCache::new(model, registry, cfg.cache.clone(), fault.clone()));
        let model = cache.base().clone();
        // until a registry swap replaces it, the booted weights serve under
        // their model name (no registry version to cite)
        let label = format!("{}@local", model.cfg.name);
        let shared = Arc::new(Shared::new(&cfg, model.clone(), label, fault.clone(), cache));
        let handle = ServerHandle {
            tx: tx.clone(),
            shared: shared.clone(),
            seq_len: cfg.seq_len,
            pad,
        };
        let admin = AdminHandle {
            shared: shared.clone(),
            structural_cap: cfg.queue_cap.max(1),
            seq_len: cfg.seq_len,
            pad,
        };
        let drain_timeout = cfg.drain_timeout;
        let workers = cfg.workers.max(1);
        // formed-batch queue: capacity = lane count, so the collector runs
        // at most one batch ahead per lane before blocking (bounded memory,
        // and requests keep accruing batching opportunity in the admission
        // channel instead of being committed to stale batches early)
        let queue = Arc::new(WorkQueue::new(workers));
        let make_engine = Arc::new(make_engine);
        let mut lanes = Vec::with_capacity(workers);
        for id in 0..workers {
            // Steady-state serving buffers: one workspace per lane, one
            // logits tensor, one token gather, one score buffer — reused
            // across every batch (and rebuilt fresh after a panic).
            let lane = Lane {
                id,
                model: model.clone(),
                cfg: cfg.clone(),
                shared: shared.clone(),
                make_engine: make_engine.clone(),
                engine: None,
                fault: fault.clone(),
                model_gen_seen: 0,
                tuning_gen_seen: 0,
                started: Instant::now(),
                ws: Workspace::new(),
                logits: Tensor::default(),
                tokens: Vec::new(),
                scores: Vec::new(),
                fallback: false,
            };
            let q = queue.clone();
            lanes.push(
                std::thread::Builder::new()
                    .name(format!("mergemoe-lane-{id}"))
                    .spawn(move || lane.run(&q))
                    .context("spawning compute lane")?,
            );
        }
        let shared2 = shared.clone();
        let (max_batch, max_wait) = (cfg.max_batch, cfg.max_wait);
        let collector = std::thread::Builder::new()
            .name("mergemoe-collector".into())
            .spawn(move || {
                // end-of-stream for every lane when the collector exits —
                // normally *or* by unwinding: lanes drain what is queued,
                // then stop
                let close = CloseQueueOnDrop(queue);
                run_collector(&shared2, &rx, &close.0, max_batch, max_wait);
            })
            .context("spawning batch collector")?;
        Ok(ScoringServer { handle, admin, shared, tx, collector: Some(collector), lanes, drain_timeout })
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// A cloneable admin handle (hot-swap + config hot-reload).
    pub fn admin(&self) -> AdminHandle {
        self.admin.clone()
    }

    /// A cloneable health/metrics observer (for the HTTP front end).
    pub fn status(&self) -> ServerStatus {
        ServerStatus { shared: self.shared.clone() }
    }

    /// Snapshot of the rolled-up serving metrics.
    pub fn metrics(&self) -> ServerMetrics {
        lock_tolerant(&self.shared.metrics).clone()
    }

    /// Requests currently queued.
    pub fn queue_depth(&self) -> usize {
        self.shared.depth()
    }

    /// Graceful drain with the configured [`ServerConfig::drain_timeout`]:
    /// stop admission, finish queued work, join the worker.
    pub fn shutdown(self) -> ServerMetrics {
        let t = self.drain_timeout;
        self.drain(t)
    }

    /// Graceful drain with an explicit timeout: admission stops immediately
    /// (live [`ServerHandle`] clones get [`ServeError::ShuttingDown`]),
    /// already-admitted requests are completed — until `timeout` elapses,
    /// after which the remainder is failed fast — and the worker is joined.
    /// Never hangs, regardless of how many handle clones clients still hold.
    pub fn drain(mut self, timeout: Duration) -> ServerMetrics {
        self.close(timeout);
        lock_tolerant(&self.shared.metrics).clone()
    }

    fn close(&mut self, timeout: Duration) {
        let Some(collector) = self.collector.take() else { return };
        self.shared.state.store(STATE_DRAINING, Ordering::Release);
        *self.shared.drain_deadline.lock().unwrap() = Some(Instant::now() + timeout);
        // Explicit close protocol: the sentinel queues FIFO behind every
        // admitted request; the collector flushes the backlog into the lane
        // queue, closes it, and exits, and each lane drains its share then
        // exits. A full admission queue just means waiting for the live
        // collector to free a slot; a vanished collector is observed via
        // is_finished. Either way this terminates — shutdown does not
        // depend on clients dropping their handle clones, and past the
        // drain deadline the lanes shed their remaining work fast.
        loop {
            if collector.is_finished() {
                break;
            }
            match self.tx.try_send(Ctl::Close) {
                Ok(()) => break,
                Err(TrySendError::Full(_)) => std::thread::sleep(Duration::from_millis(1)),
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        // join order matters: the collector's exit closes the lane queue,
        // which is what lets every lane observe end-of-stream
        let _ = collector.join();
        for lane in self.lanes.drain(..) {
            let _ = lane.join();
        }
    }
}

impl Drop for ScoringServer {
    fn drop(&mut self) {
        let t = self.drain_timeout;
        self.close(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    fn quiet_cfg() -> ServerConfig {
        ServerConfig { fault: FaultSetting::Off, ..ServerConfig::default() }
    }

    #[test]
    fn serves_scores_and_batches() {
        let model = tiny_model(4, 2, false, 100);
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            seq_len: 64,
            ..quiet_cfg()
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        // concurrent clients to force batching
        let mut joins = Vec::new();
        for i in 0..12 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let score = h.score("c:abcd|", if i % 2 == 0 { "abcd." } else { "zzzz." });
                score.unwrap()
            }));
        }
        let scores: Vec<f64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert!(scores.iter().all(|s| s.is_finite() && *s < 0.0));
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.requests, 12);
        assert_eq!(m.errors, 0);
        assert!(m.batches <= 12);
        assert!(m.mean_batch_size() >= 1.0);
        // the worker records one batch-compute sample per batch attempt
        assert_eq!(m.batch_latency.count(), m.batches);
        assert!(m.batch_latency_p50() <= m.batch_latency_p99());
    }

    #[test]
    fn rejects_oversized_requests_with_typed_error() {
        let model = tiny_model(4, 2, false, 101);
        let server = ScoringServer::start(model, quiet_cfg(), || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        let long = "a".repeat(100);
        assert!(matches!(h.score(&long, "b"), Err(ServeError::Rejected(_))));
        assert!(matches!(h.score("", "b"), Err(ServeError::Rejected(_))));
        drop(h);
    }

    #[test]
    fn identical_requests_get_identical_scores_regardless_of_batching() {
        let model = tiny_model(4, 2, true, 102);
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            seq_len: 64,
            ..quiet_cfg()
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        let a = h.score("r:abc|", "cba.").unwrap();
        let b = h.score("r:abc|", "cba.").unwrap();
        assert!((a - b).abs() < 1e-6);
        drop(h);
    }

    #[test]
    fn engine_construction_failure_degrades_not_hangs() {
        let model = tiny_model(4, 2, false, 103);
        let server = ScoringServer::start(model, quiet_cfg(), || -> Result<NativeEngine> {
            Err(anyhow!("no backend here"))
        })
        .unwrap();
        let h = server.handle();
        // the admission path fast-rejects once construction failed; a
        // request racing the construction gets failed by the worker instead
        let r = h.score("c:ab|", "ab.");
        assert!(
            matches!(r, Err(ServeError::Degraded)),
            "want Degraded, got {r:?}"
        );
        assert!(server.status().degraded());
        let m = server.shutdown();
        assert_eq!(m.requests + m.shed, m.errors + m.shed); // nothing succeeded
    }

    #[test]
    fn backoff_caps() {
        let base = Duration::from_millis(1);
        assert_eq!(backoff_delay(base, 1), Duration::from_millis(1));
        assert_eq!(backoff_delay(base, 3), Duration::from_millis(4));
        assert_eq!(backoff_delay(base, 30), Duration::from_millis(100));
    }

    #[test]
    fn queue_cap_env_fallback_is_sane() {
        // (does not set the env var — just pins the default)
        let cfg = ServerConfig::default();
        assert!(cfg.queue_cap >= 1);
        assert!(cfg.workers >= 1);
    }

    #[test]
    fn multi_lane_server_answers_everything_with_identical_scores() {
        let model = tiny_model(4, 2, false, 111);
        let cfg = ServerConfig {
            workers: 3,
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            seq_len: 64,
            ..quiet_cfg()
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap();
        assert_eq!(server.status().workers(), 3);
        let h = server.handle();
        let mut joins = Vec::new();
        for _ in 0..24 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || h.score("c:abcd|", "abcd.").unwrap()));
        }
        let scores: Vec<f64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        // identical requests score bit-identically no matter which lane ran
        // them or which batch they landed in (row independence)
        for s in &scores {
            assert_eq!(s.to_bits(), scores[0].to_bits());
        }
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.requests, 24);
        assert_eq!(m.errors, 0);
        // the per-lane counters partition the batch total
        assert_eq!(m.lane_batches.iter().sum::<u64>(), m.batches);
        assert!(m.lane_batches.len() <= 3);
    }

    #[test]
    fn hot_swap_commits_and_serves_the_new_weights() {
        let model = tiny_model(4, 2, false, 104);
        let server = ScoringServer::start(model, quiet_cfg(), || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        let before = h.score("c:abcd|", "abcd.").unwrap();
        assert_eq!(server.status().variant(), "tiny@local");
        // different seed → different weights → (almost surely) different score
        server.admin().swap_in(tiny_model(4, 2, false, 105), "tiny@v2").unwrap();
        assert_eq!(server.status().variant(), "tiny@v2");
        let after = h.score("c:abcd|", "abcd.").unwrap();
        assert!(
            (before - after).abs() > 1e-9,
            "swap did not change the serving weights ({before} vs {after})"
        );
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.swaps, 1);
        assert_eq!(m.swap_rollbacks, 0);
        assert_eq!(m.errors, 0, "no request failed across the swap");
    }

    #[test]
    fn incompatible_swap_rolls_back_and_incumbent_keeps_serving() {
        let model = tiny_model(4, 2, false, 106);
        let server = ScoringServer::start(model, quiet_cfg(), || Ok(NativeEngine)).unwrap();
        // a candidate with a truncated position table cannot serve seq_len
        let mut bad = tiny_model(4, 2, false, 107);
        let d = bad.cfg.d_model;
        bad.pos_emb = Tensor::from_vec(&[8, d], vec![0.0; 8 * d]).unwrap();
        bad.touch();
        let err = server.admin().swap_in(bad, "tiny@bad").unwrap_err();
        assert!(format!("{err:#}").contains("rolled back"), "{err:#}");
        assert_eq!(server.status().variant(), "tiny@local", "incumbent label intact");
        let h = server.handle();
        assert!(h.score("c:abcd|", "abcd.").unwrap().is_finite());
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.swaps, 0);
        assert_eq!(m.swap_rollbacks, 1);
    }

    #[test]
    fn tuning_reload_is_validate_then_commit() {
        let model = tiny_model(4, 2, false, 108);
        let cfg = ServerConfig { queue_cap: 8, ..quiet_cfg() };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap();
        let admin = server.admin();
        let status = server.status();
        assert_eq!(status.last_reload(), "never");
        // commit: tighten the soft cap and set a deadline
        let t = ServerTuning {
            queue_cap: Some(4),
            deadline_ms: Some(250),
            ..ServerTuning::default()
        };
        admin.apply_tuning(&t).unwrap();
        assert_eq!(status.last_reload(), "ok");
        // reject: soft cap above the structural channel capacity
        let bad = ServerTuning { queue_cap: Some(1000), ..ServerTuning::default() };
        assert!(admin.apply_tuning(&bad).is_err());
        assert!(status.last_reload().starts_with("rejected:"), "{}", status.last_reload());
        // the rejected document changed nothing; serving still works
        let h = server.handle();
        assert!(h.score("c:abcd|", "abcd.").unwrap().is_finite());
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.reloads, 1);
        assert_eq!(m.reload_failures, 1);
    }

    #[test]
    fn routed_score_serves_compressed_variant_with_outcome() {
        let model = tiny_model(4, 2, false, 112);
        let cfg = ServerConfig {
            cache: CacheConfig { n_calib_seqs: 8, ..Default::default() },
            ..quiet_cfg()
        };
        let server = ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap();
        let h = server.handle();
        let key = h.resolve_variant("average", 0.5, "copy").unwrap();
        let a = h.score_routed("c:abcd|", "abcd.", Some(key.clone())).unwrap();
        assert!(a.score.is_finite() && !a.fallback);
        // second request hits the cached variant — no rebuild — and is
        // bit-identical to the first
        let b = h.score_routed("c:abcd|", "abcd.", Some(key)).unwrap();
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        let stats = server.status().cache_stats();
        assert_eq!(stats.builds, 1);
        assert_eq!(stats.hits, 1);
        // unknown method rejected typed at resolution, not at compute
        assert!(matches!(
            h.resolve_variant("wat", 0.5, "copy"),
            Err(ServeError::Rejected(_))
        ));
        drop(h);
        let m = server.shutdown();
        assert_eq!(m.errors, 0);
        assert_eq!(m.fallbacks, 0);
    }

    #[test]
    fn healthz_survives_poisoned_observability_locks() {
        use super::super::http::HttpServer;
        use std::io::{Read as _, Write as _};

        let model = tiny_model(4, 2, false, 113);
        let server = ScoringServer::start(model, quiet_cfg(), || Ok(NativeEngine)).unwrap();
        let status = server.status();
        let mut http = HttpServer::bind("127.0.0.1:0", server.handle(), status.clone()).unwrap();
        let addr = http.addr();
        // poison every mutex /healthz and /metrics read: a thread panics
        // while holding each lock
        let shared = status.shared.clone();
        std::thread::spawn(move || {
            let _a = shared.degraded_reason.lock().unwrap();
            let _b = shared.metrics.lock().unwrap();
            let _c = shared.last_reload.lock().unwrap();
            let _d = shared.slot.lock().unwrap();
            panic!("poisoning observability locks");
        })
        .join()
        .unwrap_err();
        // direct getters keep answering
        assert_eq!(status.variant(), "tiny@local");
        assert_eq!(status.last_reload(), "never");
        let _ = status.metrics();
        assert!(status.degraded_reason().is_none());
        // and /healthz still answers over the wire
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(
            buf.starts_with("HTTP/1.1 200"),
            "poisoned locks must not take down health reporting:\n{buf}"
        );
        http.stop();
        server.shutdown();
    }

    #[test]
    fn probe_rejects_weights_that_panic_or_score_nonfinite() {
        let good = tiny_model(4, 2, false, 109);
        let pad = tasks::encode("\n")[0];
        assert!(probe_model(&good, 64, pad).is_ok());
        // NaN embeddings poison every logit → non-finite probe score
        let mut nan = tiny_model(4, 2, false, 110);
        let d = nan.cfg.d_model;
        let v = nan.tok_emb.shape()[0];
        nan.tok_emb = Tensor::from_vec(&[v, d], vec![f32::NAN; v * d]).unwrap();
        nan.touch();
        assert!(probe_model(&nan, 64, pad).is_err());
    }
}
