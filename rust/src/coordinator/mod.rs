//! Layer-3 coordination: the compression pipeline (offline path), the
//! batched scoring server (request path) with metrics, the memory-budgeted
//! variant cache behind per-request routing, and the crash-safe variant
//! registry feeding hot-swaps.

pub mod batcher;
pub mod cache;
pub mod http;
pub mod metrics;
pub mod pipeline;
pub mod registry;
pub mod server;

pub use crate::calib::CalibSource;
pub use cache::{CacheConfig, CacheError, CacheStats, VariantCache, VariantKey, VariantLease};
pub use http::{AdminState, HttpServer};
pub use pipeline::{
    capture_calibration, capture_calibration_source, compress, compress_with_calib,
    CompressReport, CompressSpec,
};
pub use registry::{Registry, RegistryError, VariantMeta, VariantSpec};
pub use server::{
    AdminHandle, FaultSetting, RouteFallback, ScoreOutcome, ScoringServer, ServeError,
    ServerConfig, ServerHandle, ServerStatus,
};
