//! Layer-3 coordination: the compression pipeline (offline path) and the
//! batched scoring server (request path), with metrics.

pub mod batcher;
pub mod http;
pub mod metrics;
pub mod pipeline;
pub mod server;

pub use crate::calib::CalibSource;
pub use http::HttpServer;
pub use pipeline::{
    capture_calibration, capture_calibration_source, compress, compress_with_calib,
    CompressReport, CompressSpec,
};
pub use server::{
    FaultSetting, ScoringServer, ServeError, ServerConfig, ServerHandle, ServerStatus,
};
