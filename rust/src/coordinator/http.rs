//! Dependency-free HTTP/1.1 front end for the scoring server (`mergemoe
//! serve`): the smallest wire surface that makes the hardened coordinator
//! drivable by external load generators, health checkers, and operators.
//!
//! Routes:
//!
//! * `POST /score` — body `{"prompt": "...", "completion": "..."}`, answers
//!   `{"score": <mean completion log-prob>}`. Optional routing fields
//!   `method`/`ratio`/`calib_source` score on a compressed variant served
//!   from the memory-budgeted [`VariantCache`](super::cache::VariantCache)
//!   (all absent = boot variant, exactly the unrouted behavior; `ratio` is
//!   required when routing, `method` defaults to `mergemoe`,
//!   `calib_source` to `mixture`). A reply served by the
//!   `--route-fallback base` policy carries `"fallback": true`. Typed
//!   refusals map to meaningful statuses: 429 overloaded, 504 deadline
//!   exceeded, 503 degraded/draining/variant-unavailable, 507 cache budget
//!   exceeded, 400 rejected, 500 engine/panic; 429/503 responses carry a
//!   numeric `Retry-After` header (queue-depth-derived for 429, fixed hint
//!   for 503) so well-behaved clients back off.
//! * `GET /healthz` — structured JSON: `status` (`ok`/`degraded`/
//!   `draining`, HTTP 200/503), current `variant` (`name@vN`), queue
//!   depth, worker restarts used vs budget, the outcome of the last config
//!   reload, and the degradation reason when degraded.
//! * `GET /metrics` — Prometheus-style text: request/batch counters, the
//!   shed/expired/retried/splits/restarted hardening counters, the
//!   reload/swap admin counters, the continuous-batching gauges (`workers`,
//!   `collector_idle`, `overlapped_batches_total`, per-lane
//!   `lane_batches_total{lane="i"}`), queue depth, and p50/p99 latencies.
//! * `POST /admin/swap` — body `{"name": "...", "version": N?}` (version
//!   omitted = latest good): load + verify the variant from the registry
//!   and atomically hot-swap it in. 404 unknown variant, 422 corrupt
//!   (quarantined), 409 staging/probe rollback — the incumbent keeps
//!   serving in every failure case. Requires [`HttpServer::bind_with_admin`]
//!   with a registry.
//! * `POST /admin/reload` — re-read the `--config-file` tuning document via
//!   validate-then-commit; 422 on rejection (incumbent tuning kept, outcome
//!   visible on `/healthz`).
//!
//! Every request head is parsed by [`parse_request`] under hard limits:
//! bounded header count/line length, `411 Length Required` for a `POST`
//! without `Content-Length`, `413` for a declared body over [`MAX_BODY`]
//! (rejected *before* any allocation or read), and allocation only from
//! validated sizes. Truncated requests are I/O errors, never panics.
//!
//! Deliberately minimal: thread-per-connection, one request per connection
//! (`Connection: close`), a read timeout and a body-size cap so a slow or
//! hostile client cannot wedge an accept slot forever. The protocol corners
//! this skips (keep-alive, chunked encoding, TLS) don't exercise the
//! serving stack; the overload behaviors — which do — all live behind
//! [`ServerHandle`] and are tested there.
#![warn(missing_docs)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::registry::{Registry, RegistryError};
use super::server::{AdminHandle, ServeError, ServerHandle, ServerStatus};
use crate::util::json::Json;

/// Largest accepted request body.
const MAX_BODY: usize = 64 * 1024;
/// Longest accepted header line (request line included).
const MAX_HEADER_LINE: usize = 8 * 1024;
/// Most header lines read before the head is rejected.
const MAX_HEADERS: usize = 128;
/// Per-connection read timeout: a stalled client is dropped, not waited on.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Admin wiring for the front end: the server's [`AdminHandle`] plus the
/// optional variant registry (`POST /admin/swap`) and tuning config file
/// (`POST /admin/reload`).
pub struct AdminState {
    /// Hot-swap / hot-reload handle of the scoring server being fronted.
    pub admin: AdminHandle,
    /// Variant source for `POST /admin/swap`; `None` disables the route.
    pub registry: Option<Arc<Registry>>,
    /// Tuning document re-read by `POST /admin/reload`; `None` disables
    /// the route.
    pub config_file: Option<PathBuf>,
}

/// The listening front end. Dropping it (or calling [`HttpServer::stop`])
/// closes the accept loop; the scoring server itself is shut down
/// separately by its owner.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, port 0 for ephemeral) and
    /// serve requests against `handle`, reporting health/metrics from
    /// `status`. Admin routes answer 409 (not wired) — use
    /// [`HttpServer::bind_with_admin`] to enable them.
    pub fn bind(addr: &str, handle: ServerHandle, status: ServerStatus) -> Result<HttpServer> {
        Self::bind_inner(addr, handle, status, None)
    }

    /// [`HttpServer::bind`] with the admin surface (`/admin/swap`,
    /// `/admin/reload`) wired up.
    pub fn bind_with_admin(
        addr: &str,
        handle: ServerHandle,
        status: ServerStatus,
        admin: AdminState,
    ) -> Result<HttpServer> {
        Self::bind_inner(addr, handle, status, Some(Arc::new(admin)))
    }

    fn bind_inner(
        addr: &str,
        handle: ServerHandle,
        status: ServerStatus,
        admin: Option<Arc<AdminState>>,
    ) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding HTTP listener on {addr}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::spawn(move || {
            accept_loop(listener, handle, status, admin, stop2);
        });
        crate::info!("http front end listening on {addr}");
        Ok(HttpServer { addr, stop, join: Some(join) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop. Idempotent.
    pub fn stop(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.stop.store(true, Ordering::Release);
        // unblock accept() with a throwaway self-connection
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    handle: ServerHandle,
    status: ServerStatus,
    admin: Option<Arc<AdminState>>,
    stop: Arc<AtomicBool>,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        match conn {
            Ok(stream) => {
                let handle = handle.clone();
                let status = status.clone();
                let admin = admin.clone();
                std::thread::spawn(move || {
                    if let Err(e) = serve_conn(stream, &handle, &status, admin.as_deref()) {
                        crate::debuglog!("http connection error: {e:#}");
                    }
                });
            }
            Err(e) => crate::debuglog!("accept failed: {e}"),
        }
    }
}

/// One parsed request, or a typed early rejection the caller answers with.
enum Parsed {
    /// A complete, within-limits request (body empty for bodiless methods).
    Request {
        method: String,
        path: String,
        body: Vec<u8>,
    },
    /// Malformed or over-limit head: answer `code`/`why` and close.
    Reject { code: u16, why: &'static str },
}

/// Read one request head (+ body) from `reader` under hard limits.
///
/// Protocol errors a client can fix get a typed [`Parsed::Reject`] (400
/// malformed line or `Content-Length`, 411 `POST` without a length, 413
/// declared body over [`MAX_BODY`] — checked *before* any body allocation).
/// Truncation — EOF mid-head or mid-body — is an `Err`: there is nobody to
/// answer. Body buffers are allocated only from a validated size, and
/// nothing past the declared body is consumed, so pipelined requests stay
/// intact for a subsequent call.
fn parse_request<R: BufRead>(reader: &mut R) -> Result<Parsed> {
    let mut line = String::new();
    let n = reader.read_line(&mut line).context("read request line")?;
    if n == 0 {
        bail!("connection closed before a request line");
    }
    if line.len() > MAX_HEADER_LINE {
        return Ok(Parsed::Reject { code: 400, why: "request line too long\n" });
    }
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return Ok(Parsed::Reject { code: 400, why: "malformed request line\n" }),
    };
    let mut content_length: Option<usize> = None;
    for _ in 0..MAX_HEADERS {
        let mut h = String::new();
        let n = reader.read_line(&mut h).context("read header")?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        if h.len() > MAX_HEADER_LINE {
            return Ok(Parsed::Reject { code: 400, why: "header line too long\n" });
        }
        let h = h.trim();
        if h.is_empty() {
            let body = match (method.as_str(), content_length) {
                ("POST", None) => {
                    return Ok(Parsed::Reject { code: 411, why: "Content-Length required\n" })
                }
                ("POST", Some(n)) if n > MAX_BODY => {
                    return Ok(Parsed::Reject { code: 413, why: "body too large\n" })
                }
                ("POST", Some(n)) => {
                    // n <= MAX_BODY just validated: bounded allocation
                    let mut body = vec![0u8; n];
                    reader.read_exact(&mut body).context("read body")?;
                    body
                }
                _ => Vec::new(),
            };
            return Ok(Parsed::Request { method, path, body });
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            // duplicate Content-Length headers desync the pipelined-read
            // framing (which value bounds the body?) — reject, don't pick one
            if content_length.is_some() {
                return Ok(Parsed::Reject { code: 400, why: "duplicate Content-Length\n" });
            }
            match v.trim().parse::<usize>() {
                Ok(n) => content_length = Some(n),
                Err(_) => {
                    return Ok(Parsed::Reject { code: 400, why: "bad Content-Length\n" })
                }
            }
        }
    }
    Ok(Parsed::Reject { code: 400, why: "too many headers\n" })
}

/// Handle exactly one request on `stream`, then close.
fn serve_conn(
    stream: TcpStream,
    handle: &ServerHandle,
    status: &ServerStatus,
    admin: Option<&AdminState>,
) -> Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT)).context("set read timeout")?;
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    match parse_request(&mut reader)? {
        Parsed::Reject { code, why } => respond(stream, code, "text/plain", why),
        Parsed::Request { method, path, body } => match (method.as_str(), path.as_str()) {
            ("POST", "/score") => handle_score(stream, handle, &body),
            ("POST", "/admin/swap") => handle_swap(stream, admin, &body),
            ("POST", "/admin/reload") => handle_reload(stream, admin),
            ("GET", "/healthz") => {
                let (code, body) = render_health(status);
                respond(stream, code, "application/json", &body)
            }
            ("GET", "/metrics") => respond(stream, 200, "text/plain", &render_metrics(status)),
            _ => respond(stream, 404, "text/plain", "not found\n"),
        },
    }
}

fn handle_score(stream: TcpStream, handle: &ServerHandle, body: &[u8]) -> Result<()> {
    let parsed = std::str::from_utf8(body)
        .map_err(anyhow::Error::from)
        .and_then(Json::parse)
        .and_then(|j| {
            let prompt = j.get("prompt")?.as_str()?.to_string();
            let completion = j.get("completion")?.as_str()?.to_string();
            let method = match j.opt("method") {
                Some(v) => Some(v.as_str()?.to_string()),
                None => None,
            };
            let ratio = match j.opt("ratio") {
                Some(v) => Some(v.as_f64()?),
                None => None,
            };
            let calib = match j.opt("calib_source") {
                Some(v) => Some(v.as_str()?.to_string()),
                None => None,
            };
            Ok((prompt, completion, method, ratio, calib))
        });
    let (prompt, completion, method, ratio, calib) = match parsed {
        Ok(pc) => pc,
        Err(e) => return respond_json_error(stream, 400, &format!("bad request: {e:#}")),
    };
    // all routing fields absent = boot variant (exactly the unrouted path)
    let variant = if method.is_none() && ratio.is_none() && calib.is_none() {
        None
    } else {
        let Some(ratio) = ratio else {
            return respond_json_error(
                stream,
                400,
                "ratio is required when routing (method/calib_source given)",
            );
        };
        let method = method.as_deref().unwrap_or("mergemoe");
        let calib = calib.as_deref().unwrap_or("mixture");
        match handle.resolve_variant(method, ratio, calib) {
            Ok(key) => Some(key),
            Err(e) => return respond_json_error(stream, 400, &e.to_string()),
        }
    };
    match handle.score_routed(&prompt, &completion, variant) {
        Ok(outcome) => {
            let mut fields = vec![("score", Json::Num(outcome.score))];
            // marker only when fallback actually happened: the common-case
            // response shape is unchanged
            if outcome.fallback {
                fields.push(("fallback", Json::Bool(true)));
            }
            let msg = Json::obj(fields);
            respond(stream, 200, "application/json", &msg.to_string())
        }
        Err(e) => {
            let code = status_of(&e);
            let mut extra = Vec::new();
            if let Some(secs) = retry_after_hint(code, handle.queue_depth()) {
                extra.push(("Retry-After", secs.to_string()));
            }
            let body = Json::obj(vec![("error", Json::str(&e.to_string()))]).to_string();
            respond_with_headers(stream, code, "application/json", &extra, &body)
        }
    }
}

/// `POST /admin/swap`: load `{"name", "version"?}` from the registry
/// (latest good when no version given) and hot-swap it in.
fn handle_swap(stream: TcpStream, admin: Option<&AdminState>, body: &[u8]) -> Result<()> {
    let Some(a) = admin else {
        return respond(stream, 409, "text/plain", "admin interface not configured\n");
    };
    let Some(reg) = &a.registry else {
        return respond(stream, 409, "text/plain", "no registry configured (--registry)\n");
    };
    let parsed = std::str::from_utf8(body)
        .map_err(anyhow::Error::from)
        .and_then(Json::parse)
        .and_then(|j| {
            let name = j.get("name")?.as_str()?.to_string();
            let version = match j.opt("version") {
                Some(v) => Some(v.as_usize()? as u64),
                None => None,
            };
            Ok((name, version))
        });
    let (name, version) = match parsed {
        Ok(x) => x,
        Err(e) => return respond_json_error(stream, 400, &format!("bad request: {e:#}")),
    };
    let loaded = match version {
        Some(v) => reg.load(&name, v),
        None => reg.load_latest_good(&name),
    };
    match loaded {
        Ok((model, meta)) => match a.admin.swap_in(model, &meta.label()) {
            Ok(()) => {
                let msg = Json::obj(vec![("variant", Json::str(&meta.label()))]);
                respond(stream, 200, "application/json", &msg.to_string())
            }
            // staging/probe failure: rolled back, incumbent still serving
            Err(e) => respond_json_error(stream, 409, &format!("{e:#}")),
        },
        Err(e) => {
            let code = match e.downcast_ref::<RegistryError>() {
                Some(RegistryError::NotFound { .. }) => 404,
                Some(RegistryError::Corrupt { .. }) => 422,
                Some(RegistryError::BadName { .. }) => 400,
                None => 500,
            };
            respond_json_error(stream, code, &format!("{e:#}"))
        }
    }
}

/// `POST /admin/reload`: re-read the `--config-file` tuning document.
fn handle_reload(stream: TcpStream, admin: Option<&AdminState>) -> Result<()> {
    let Some(a) = admin else {
        return respond(stream, 409, "text/plain", "admin interface not configured\n");
    };
    let Some(path) = &a.config_file else {
        return respond(stream, 409, "text/plain", "no config file to reload (--config-file)\n");
    };
    match a.admin.reload_from(path) {
        Ok(()) => {
            let msg = Json::obj(vec![("reload", Json::str("ok"))]);
            respond(stream, 200, "application/json", &msg.to_string())
        }
        // validation rejected the document; incumbent tuning kept
        Err(e) => respond_json_error(stream, 422, &format!("{e:#}")),
    }
}

/// HTTP status for each typed refusal.
fn status_of(e: &ServeError) -> u16 {
    match e {
        ServeError::Overloaded => 429,
        ServeError::DeadlineExceeded => 504,
        ServeError::Degraded | ServeError::ShuttingDown => 503,
        ServeError::VariantUnavailable(_) => 503,
        ServeError::BudgetExceeded(_) => 507,
        ServeError::Rejected(_) => 400,
        ServeError::WorkerPanicked | ServeError::Engine(_) => 500,
    }
}

/// Numeric `Retry-After` (seconds) for backpressure statuses: 429 scales
/// with the queue backlog (a deeper queue earns a longer back-off), 503 is
/// a fixed hint. Other statuses carry no header.
fn retry_after_hint(code: u16, queue_depth: usize) -> Option<u64> {
    match code {
        429 => Some(1 + queue_depth as u64 / 32),
        503 => Some(2),
        _ => None,
    }
}

/// The `/healthz` document: overall status plus the operational facts an
/// operator triages with — current variant, restart budget consumption,
/// and the outcome of the last config reload.
fn render_health(status: &ServerStatus) -> (u16, String) {
    let (code, state) = if status.degraded() {
        (503, "degraded")
    } else if status.draining() {
        (503, "draining")
    } else {
        (200, "ok")
    };
    let mut fields = vec![
        ("status", Json::str(state)),
        ("variant", Json::str(&status.variant())),
        ("queue_depth", Json::num(status.queue_depth() as f64)),
        ("restarts_used", Json::num(status.restarts_used() as f64)),
        ("restart_budget", Json::num(status.restart_budget() as f64)),
        ("last_reload", Json::str(&status.last_reload())),
    ];
    if let Some(why) = status.degraded_reason() {
        fields.push(("degraded_reason", Json::str(&why)));
    }
    (code, Json::obj(fields).to_string())
}

/// Prometheus-style exposition of the serving metrics.
fn render_metrics(status: &ServerStatus) -> String {
    let m = status.metrics();
    let mut out = String::new();
    let mut gauge = |name: &str, v: f64| {
        out.push_str(&format!("mergemoe_{name} {v}\n"));
    };
    gauge("requests_total", m.requests as f64);
    gauge("errors_total", m.errors as f64);
    gauge("shed_total", m.shed as f64);
    gauge("expired_total", m.expired as f64);
    gauge("retried_total", m.retried as f64);
    gauge("batch_splits_total", m.splits as f64);
    gauge("worker_restarts_total", m.restarted as f64);
    gauge("config_reloads_total", m.reloads as f64);
    gauge("config_reload_failures_total", m.reload_failures as f64);
    gauge("variant_swaps_total", m.swaps as f64);
    gauge("variant_swap_rollbacks_total", m.swap_rollbacks as f64);
    gauge("fallback_scores_total", m.fallbacks as f64);
    gauge("batches_total", m.batches as f64);
    gauge("batched_sequences_total", m.batched_sequences as f64);
    gauge("overlapped_batches_total", m.overlapped as f64);
    gauge("workers", status.workers() as f64);
    gauge("collector_idle", if status.collector_idle() { 1.0 } else { 0.0 });
    gauge("mean_batch_size", m.mean_batch_size());
    gauge("throughput_rps", m.throughput_rps());
    gauge("queue_depth", status.queue_depth() as f64);
    gauge("degraded", if status.degraded() { 1.0 } else { 0.0 });
    gauge("draining", if status.draining() { 1.0 } else { 0.0 });
    gauge("latency_p50_seconds", m.total_latency.quantile(0.5).as_secs_f64());
    gauge("latency_p99_seconds", m.total_latency.quantile(0.99).as_secs_f64());
    gauge("queue_wait_p50_seconds", m.queue_wait_p50().as_secs_f64());
    gauge("queue_wait_p99_seconds", m.queue_wait_p99().as_secs_f64());
    gauge("batch_latency_p50_seconds", m.batch_latency_p50().as_secs_f64());
    gauge("batch_latency_p99_seconds", m.batch_latency_p99().as_secs_f64());
    // variant-cache gauges: the bytes/budget pair is the acceptance
    // surface for "peak cache bytes never exceed the budget"
    let c = status.cache_stats();
    gauge("cache_bytes", c.bytes as f64);
    gauge("cache_bytes_peak", c.bytes_peak as f64);
    gauge("cache_budget_bytes", c.budget_bytes as f64);
    gauge("cache_entries", c.entries as f64);
    gauge("cache_hits_total", c.hits as f64);
    gauge("cache_misses_total", c.misses as f64);
    gauge("cache_builds_total", c.builds as f64);
    gauge("cache_build_failures_total", c.build_failures as f64);
    gauge("cache_registry_loads_total", c.registry_loads as f64);
    gauge("cache_evictions_total", c.evictions as f64);
    gauge("cache_quarantined", c.quarantined as f64);
    // labeled per-lane series last: the `gauge` closure's borrow of `out`
    // has ended by here
    for (i, b) in m.lane_batches.iter().enumerate() {
        out.push_str(&format!("mergemoe_lane_batches_total{{lane=\"{i}\"}} {b}\n"));
    }
    out
}

fn reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        507 => "Insufficient Storage",
        _ => "",
    }
}

fn respond_json_error(stream: TcpStream, code: u16, msg: &str) -> Result<()> {
    let body = Json::obj(vec![("error", Json::str(msg))]).to_string();
    respond(stream, code, "application/json", &body)
}

fn respond(stream: TcpStream, code: u16, ctype: &str, body: &str) -> Result<()> {
    respond_with_headers(stream, code, ctype, &[], body)
}

fn respond_with_headers(
    mut stream: TcpStream,
    code: u16,
    ctype: &str,
    extra: &[(&str, String)],
    body: &str,
) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n",
        reason(code),
        body.len(),
    );
    for (name, value) in extra {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("Connection: close\r\n\r\n");
    stream.write_all(head.as_bytes()).context("write response head")?;
    stream.write_all(body.as_bytes()).context("write response body")?;
    stream.flush().context("flush response")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::VariantSpec;
    use crate::coordinator::server::{FaultSetting, ScoringServer, ServerConfig};
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    /// Raw response text, head + body (for asserting on headers).
    fn request_raw(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        buf
    }

    fn request(addr: SocketAddr, raw: &str) -> (u16, String) {
        let buf = request_raw(addr, raw);
        let code = buf
            .split_whitespace()
            .nth(1)
            .and_then(|c| c.parse().ok())
            .unwrap_or(0);
        let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (code, body)
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        request(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
    }

    fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
        request(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    fn post_score(addr: SocketAddr, body: &str) -> (u16, String) {
        post(addr, "/score", body)
    }

    fn test_server() -> ScoringServer {
        let model = tiny_model(4, 2, false, 300);
        let cfg = ServerConfig { fault: FaultSetting::Off, ..ServerConfig::default() };
        ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap()
    }

    fn reject_code(p: Parsed) -> u16 {
        match p {
            Parsed::Reject { code, .. } => code,
            Parsed::Request { method, path, .. } => {
                panic!("expected a rejection, parsed {method} {path}")
            }
        }
    }

    #[test]
    fn parser_rejects_truncated_and_unsized_requests() {
        // truncated mid-headers / empty stream: I/O error, never a panic
        let mut r = BufReader::new(&b"POST /score HTTP/1.1\r\nContent-Le"[..]);
        assert!(parse_request(&mut r).is_err());
        let mut r = BufReader::new(&b""[..]);
        assert!(parse_request(&mut r).is_err());
        // truncated body: Content-Length promises more than arrives
        let mut r =
            BufReader::new(&b"POST /score HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"[..]);
        assert!(parse_request(&mut r).is_err());
        // POST without Content-Length
        let mut r = BufReader::new(&b"POST /score HTTP/1.1\r\nHost: x\r\n\r\n"[..]);
        assert_eq!(reject_code(parse_request(&mut r).unwrap()), 411);
        // declared body over the cap: rejected before any allocation
        let huge = format!(
            "POST /score HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        );
        let mut r = BufReader::new(huge.as_bytes());
        assert_eq!(reject_code(parse_request(&mut r).unwrap()), 413);
        // unparsable Content-Length
        let mut r =
            BufReader::new(&b"POST /s HTTP/1.1\r\nContent-Length: banana\r\n\r\n"[..]);
        assert_eq!(reject_code(parse_request(&mut r).unwrap()), 400);
        // duplicate Content-Length: last-one-wins would desync framing —
        // must be a 400, even when the values agree
        let mut r = BufReader::new(
            &b"POST /s HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 9\r\n\r\nhi"[..],
        );
        assert_eq!(reject_code(parse_request(&mut r).unwrap()), 400);
        let mut r = BufReader::new(
            &b"POST /s HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi"[..],
        );
        assert_eq!(reject_code(parse_request(&mut r).unwrap()), 400);
        // garbage request line
        let mut r = BufReader::new(&b"\r\n\r\n"[..]);
        assert_eq!(reject_code(parse_request(&mut r).unwrap()), 400);
    }

    #[test]
    fn parser_handles_pipelined_requests_without_overreading() {
        let data =
            b"POST /score HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /healthz HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(&data[..]);
        match parse_request(&mut r).unwrap() {
            Parsed::Request { method, path, body } => {
                assert_eq!((method.as_str(), path.as_str()), ("POST", "/score"));
                assert_eq!(body, b"hi");
            }
            Parsed::Reject { code, why } => panic!("rejected {code}: {why}"),
        }
        // the second pipelined request is fully intact
        match parse_request(&mut r).unwrap() {
            Parsed::Request { method, path, body } => {
                assert_eq!((method.as_str(), path.as_str()), ("GET", "/healthz"));
                assert!(body.is_empty());
            }
            Parsed::Reject { code, why } => panic!("rejected {code}: {why}"),
        }
        // then a clean end-of-stream
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn scores_health_and_metrics_over_http() {
        let server = test_server();
        let mut http =
            HttpServer::bind("127.0.0.1:0", server.handle(), server.status()).unwrap();
        let addr = http.addr();

        let (code, body) = get(addr, "/healthz");
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "ok");
        assert_eq!(j.get("variant").unwrap().as_str().unwrap(), "tiny@local");
        assert_eq!(j.get("last_reload").unwrap().as_str().unwrap(), "never");
        assert_eq!(j.get("restarts_used").unwrap().as_usize().unwrap(), 0);
        assert!(j.get("restart_budget").unwrap().as_usize().unwrap() >= 1);
        assert!(j.opt("degraded_reason").is_none(), "healthy server has no reason");

        let (code, body) =
            post_score(addr, r#"{"prompt": "c:abcd|", "completion": "abcd."}"#);
        assert_eq!(code, 200, "body: {body}");
        let score = Json::parse(&body).unwrap().get("score").unwrap().as_f64().unwrap();
        assert!(score.is_finite() && score < 0.0);

        let (code, body) = get(addr, "/metrics");
        assert_eq!(code, 200);
        assert!(body.contains("mergemoe_requests_total 1"));
        assert!(body.contains("mergemoe_shed_total 0"));
        assert!(body.contains("mergemoe_queue_depth 0"));
        assert!(body.contains("mergemoe_variant_swaps_total 0"));
        assert!(body.contains("mergemoe_config_reloads_total 0"));

        http.stop();
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_typed_statuses() {
        let server = test_server();
        let mut http =
            HttpServer::bind("127.0.0.1:0", server.handle(), server.status()).unwrap();
        let addr = http.addr();

        let (code, _) = post_score(addr, "not json");
        assert_eq!(code, 400);
        let (code, _) = post_score(addr, r#"{"prompt": "x"}"#); // missing completion
        assert_eq!(code, 400);
        let long = "a".repeat(200);
        let (code, body) =
            post_score(addr, &format!(r#"{{"prompt": "{long}", "completion": "b"}}"#));
        assert_eq!(code, 400, "oversized request must map to 400: {body}");
        let (code, _) = get(addr, "/nope");
        assert_eq!(code, 404);
        // wire-level head protections
        let (code, _) = request(addr, "POST /score HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(code, 411, "POST without Content-Length");
        let (code, _) = request(
            addr,
            &format!("POST /score HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1),
        );
        assert_eq!(code, 413, "oversized declared body");
        // admin routes answer 409 when not wired up
        let (code, _) = post(addr, "/admin/swap", r#"{"name": "x"}"#);
        assert_eq!(code, 409);
        let (code, _) = post(addr, "/admin/reload", "");
        assert_eq!(code, 409);

        http.stop();
        server.shutdown();
    }

    #[test]
    fn healthz_reflects_draining_server() {
        let server = test_server();
        let handle = server.handle();
        let mut http = HttpServer::bind("127.0.0.1:0", handle, server.status()).unwrap();
        let addr = http.addr();
        let status = server.status();
        server.shutdown();
        assert!(status.draining());
        let (code, body) = get(addr, "/healthz");
        assert_eq!(code, 503);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("status").unwrap().as_str().unwrap(), "draining");
        // scoring through the front end now gets the typed 503
        let (code, _) = post_score(addr, r#"{"prompt": "c:ab|", "completion": "ab."}"#);
        assert_eq!(code, 503);
        http.stop();
    }

    #[test]
    fn admin_endpoints_swap_and_reload_over_http() {
        let dir = std::env::temp_dir()
            .join(format!("mergemoe_http_admin_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let reg = Registry::open(&dir.join("registry")).unwrap();
        let spec = VariantSpec {
            method: "mergemoe".into(),
            ratio: 1.0,
            calib_source: "mixture".into(),
        };
        reg.add("tiny-swap", &tiny_model(4, 2, false, 301), &spec).unwrap();
        let cfg_path = dir.join("tuning.json");
        std::fs::write(&cfg_path, r#"{"queue_cap": 4}"#).unwrap();

        let server = test_server();
        let admin = AdminState {
            admin: server.admin(),
            registry: Some(Arc::new(reg)),
            config_file: Some(cfg_path.clone()),
        };
        let mut http = HttpServer::bind_with_admin(
            "127.0.0.1:0",
            server.handle(),
            server.status(),
            admin,
        )
        .unwrap();
        let addr = http.addr();

        // swap to the registered variant; /healthz reports the new label
        let (code, body) = post(addr, "/admin/swap", r#"{"name": "tiny-swap"}"#);
        assert_eq!(code, 200, "{body}");
        let (_, body) = get(addr, "/healthz");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("variant").unwrap().as_str().unwrap(), "tiny-swap@v1");
        // unknown variant → 404, serving untouched
        let (code, _) = post(addr, "/admin/swap", r#"{"name": "ghost"}"#);
        assert_eq!(code, 404);
        // valid reload commits; invalid reload is rejected and reported
        let (code, body) = post(addr, "/admin/reload", "");
        assert_eq!(code, 200, "{body}");
        std::fs::write(&cfg_path, r#"{"queue_cap": 0}"#).unwrap();
        let (code, _) = post(addr, "/admin/reload", "");
        assert_eq!(code, 422);
        let (_, body) = get(addr, "/healthz");
        let j = Json::parse(&body).unwrap();
        assert!(
            j.get("last_reload").unwrap().as_str().unwrap().starts_with("rejected:"),
            "{body}"
        );
        // scoring kept working across all of it
        let (code, _) = post_score(addr, r#"{"prompt": "c:ab|", "completion": "ab."}"#);
        assert_eq!(code, 200);

        http.stop();
        let m = server.shutdown();
        assert_eq!(m.swaps, 1);
        assert_eq!(m.reloads, 1);
        assert_eq!(m.reload_failures, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retry_after_hints_are_numeric_and_depth_scaled() {
        // 429 scales with the backlog; 503 is a fixed hint; success and
        // client-error statuses carry no header
        assert_eq!(retry_after_hint(429, 0), Some(1));
        assert_eq!(retry_after_hint(429, 64), Some(3));
        assert_eq!(retry_after_hint(503, 0), Some(2));
        assert_eq!(retry_after_hint(200, 10), None);
        assert_eq!(retry_after_hint(400, 10), None);
    }

    #[test]
    fn backpressure_responses_carry_numeric_retry_after_header() {
        let server = test_server();
        let handle = server.handle();
        let mut http = HttpServer::bind("127.0.0.1:0", handle, server.status()).unwrap();
        let addr = http.addr();
        // draining server: /score answers 503 — the deterministic
        // backpressure status to pin the header on
        server.shutdown();
        let body = r#"{"prompt": "c:ab|", "completion": "ab."}"#;
        let raw = request_raw(
            addr,
            &format!(
                "POST /score HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        );
        assert!(raw.starts_with("HTTP/1.1 503"), "{raw}");
        let head = raw.split("\r\n\r\n").next().unwrap();
        let value = head
            .lines()
            .find_map(|l| l.strip_prefix("Retry-After: "))
            .unwrap_or_else(|| panic!("no Retry-After header in:\n{head}"));
        let secs: u64 = value.trim().parse().expect("Retry-After must be numeric");
        assert!(secs >= 1);
        http.stop();
    }

    #[test]
    fn routed_score_builds_variant_and_validates_fields() {
        let server = test_server();
        let mut http =
            HttpServer::bind("127.0.0.1:0", server.handle(), server.status()).unwrap();
        let addr = http.addr();
        // cold routed request: the cache compresses the variant on demand
        let (code, body) = post_score(
            addr,
            r#"{"prompt": "c:abcd|", "completion": "abcd.", "method": "average", "ratio": 0.5, "calib_source": "copy"}"#,
        );
        assert_eq!(code, 200, "body: {body}");
        let j = Json::parse(&body).unwrap();
        let routed = j.get("score").unwrap().as_f64().unwrap();
        assert!(routed.is_finite() && routed < 0.0);
        assert!(j.opt("fallback").is_none(), "no fallback marker without fallback");
        // the boot-path score differs from the merged variant's
        let (_, body) = post_score(addr, r#"{"prompt": "c:abcd|", "completion": "abcd."}"#);
        let boot = Json::parse(&body).unwrap().get("score").unwrap().as_f64().unwrap();
        assert!((routed - boot).abs() > 0.0, "merge changed the weights");
        // routing field validation: missing ratio, bad ratio, bad method
        let (code, _) = post_score(addr, r#"{"prompt": "a|", "completion": "b.", "method": "average"}"#);
        assert_eq!(code, 400, "ratio required when routing");
        let (code, _) =
            post_score(addr, r#"{"prompt": "a|", "completion": "b.", "ratio": 1.5}"#);
        assert_eq!(code, 400);
        let (code, _) = post_score(
            addr,
            r#"{"prompt": "a|", "completion": "b.", "method": "wat", "ratio": 0.5}"#,
        );
        assert_eq!(code, 400);
        // cache gauges landed on /metrics
        let (_, body) = get(addr, "/metrics");
        assert!(body.contains("mergemoe_cache_builds_total 1"), "{body}");
        assert!(body.contains("mergemoe_cache_budget_bytes"), "{body}");
        http.stop();
        server.shutdown();
    }

    #[test]
    fn stop_is_idempotent_and_unblocks_accept() {
        let server = test_server();
        let mut http =
            HttpServer::bind("127.0.0.1:0", server.handle(), server.status()).unwrap();
        http.stop();
        http.stop(); // second call is a no-op, not a hang
        server.shutdown();
    }
}
