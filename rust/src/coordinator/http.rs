//! Dependency-free HTTP/1.1 front end for the scoring server (`mergemoe
//! serve`): the smallest wire surface that makes the hardened coordinator
//! drivable by external load generators and health checkers.
//!
//! Routes:
//!
//! * `POST /score` — body `{"prompt": "...", "completion": "..."}`, answers
//!   `{"score": <mean completion log-prob>}`. Typed refusals map to
//!   meaningful statuses: 429 overloaded, 504 deadline exceeded, 503
//!   degraded/draining, 400 rejected, 500 engine/panic.
//! * `GET /healthz` — `200 ok` while serving; `503 degraded` once the
//!   worker's restart budget is exhausted; `503 draining` during shutdown.
//! * `GET /metrics` — Prometheus-style text: request/batch counters, the
//!   shed/expired/retried/splits/restarted hardening counters, queue depth,
//!   and p50/p99 latencies.
//!
//! Deliberately minimal: thread-per-connection, one request per connection
//! (`Connection: close`), a read timeout and a body-size cap so a slow or
//! hostile client cannot wedge an accept slot forever. The protocol corners
//! this skips (keep-alive, chunked encoding, TLS) don't exercise the
//! serving stack; the overload behaviors — which do — all live behind
//! [`ServerHandle`] and are tested there.
#![warn(missing_docs)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::server::{ServeError, ServerHandle, ServerStatus};
use crate::util::json::Json;

/// Largest accepted `POST /score` body.
const MAX_BODY: usize = 64 * 1024;
/// Per-connection read timeout: a stalled client is dropped, not waited on.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// The listening front end. Dropping it (or calling [`HttpServer::stop`])
/// closes the accept loop; the scoring server itself is shut down
/// separately by its owner.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, port 0 for ephemeral) and
    /// serve requests against `handle`, reporting health/metrics from
    /// `status`.
    pub fn bind(addr: &str, handle: ServerHandle, status: ServerStatus) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding HTTP listener on {addr}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::spawn(move || {
            accept_loop(listener, handle, status, stop2);
        });
        crate::info!("http front end listening on {addr}");
        Ok(HttpServer { addr, stop, join: Some(join) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop. Idempotent.
    pub fn stop(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.stop.store(true, Ordering::Release);
        // unblock accept() with a throwaway self-connection
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    handle: ServerHandle,
    status: ServerStatus,
    stop: Arc<AtomicBool>,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        match conn {
            Ok(stream) => {
                let handle = handle.clone();
                let status = status.clone();
                std::thread::spawn(move || {
                    if let Err(e) = serve_conn(stream, &handle, &status) {
                        crate::debuglog!("http connection error: {e:#}");
                    }
                });
            }
            Err(e) => crate::debuglog!("accept failed: {e}"),
        }
    }
}

/// Handle exactly one request on `stream`, then close.
fn serve_conn(stream: TcpStream, handle: &ServerHandle, status: &ServerStatus) -> Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT)).context("set read timeout")?;
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut line = String::new();
    reader.read_line(&mut line).context("read request line")?;
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return respond(stream, 400, "text/plain", "malformed request line\n"),
    };
    // headers: we only need Content-Length
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("read header")?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    match (method.as_str(), path.as_str()) {
        ("POST", "/score") => {
            if content_length > MAX_BODY {
                return respond(stream, 413, "text/plain", "body too large\n");
            }
            let mut body = vec![0u8; content_length];
            reader.read_exact(&mut body).context("read body")?;
            handle_score(stream, handle, &body)
        }
        ("GET", "/healthz") => {
            let (code, msg) = if status.degraded() {
                (503, "degraded\n")
            } else if status.draining() {
                (503, "draining\n")
            } else {
                (200, "ok\n")
            };
            respond(stream, code, "text/plain", msg)
        }
        ("GET", "/metrics") => respond(stream, 200, "text/plain", &render_metrics(status)),
        _ => respond(stream, 404, "text/plain", "not found\n"),
    }
}

fn handle_score(stream: TcpStream, handle: &ServerHandle, body: &[u8]) -> Result<()> {
    let parsed = std::str::from_utf8(body)
        .map_err(anyhow::Error::from)
        .and_then(Json::parse)
        .and_then(|j| {
            let prompt = j.get("prompt")?.as_str()?.to_string();
            let completion = j.get("completion")?.as_str()?.to_string();
            Ok((prompt, completion))
        });
    let (prompt, completion) = match parsed {
        Ok(pc) => pc,
        Err(e) => {
            let msg = Json::obj(vec![("error", Json::Str(format!("bad request: {e:#}")))]);
            return respond(stream, 400, "application/json", &msg.to_string());
        }
    };
    match handle.score(&prompt, &completion) {
        Ok(score) => {
            let msg = Json::obj(vec![("score", Json::Num(score))]);
            respond(stream, 200, "application/json", &msg.to_string())
        }
        Err(e) => {
            let code = status_of(&e);
            let msg = Json::obj(vec![("error", Json::Str(e.to_string()))]);
            respond(stream, code, "application/json", &msg.to_string())
        }
    }
}

/// HTTP status for each typed refusal.
fn status_of(e: &ServeError) -> u16 {
    match e {
        ServeError::Overloaded => 429,
        ServeError::DeadlineExceeded => 504,
        ServeError::Degraded | ServeError::ShuttingDown => 503,
        ServeError::Rejected(_) => 400,
        ServeError::WorkerPanicked | ServeError::Engine(_) => 500,
    }
}

/// Prometheus-style exposition of the serving metrics.
fn render_metrics(status: &ServerStatus) -> String {
    let m = status.metrics();
    let mut out = String::new();
    let mut gauge = |name: &str, v: f64| {
        out.push_str(&format!("mergemoe_{name} {v}\n"));
    };
    gauge("requests_total", m.requests as f64);
    gauge("errors_total", m.errors as f64);
    gauge("shed_total", m.shed as f64);
    gauge("expired_total", m.expired as f64);
    gauge("retried_total", m.retried as f64);
    gauge("batch_splits_total", m.splits as f64);
    gauge("worker_restarts_total", m.restarted as f64);
    gauge("batches_total", m.batches as f64);
    gauge("batched_sequences_total", m.batched_sequences as f64);
    gauge("mean_batch_size", m.mean_batch_size());
    gauge("throughput_rps", m.throughput_rps());
    gauge("queue_depth", status.queue_depth() as f64);
    gauge("degraded", if status.degraded() { 1.0 } else { 0.0 });
    gauge("draining", if status.draining() { 1.0 } else { 0.0 });
    gauge("latency_p50_seconds", m.total_latency.quantile(0.5).as_secs_f64());
    gauge("latency_p99_seconds", m.total_latency.quantile(0.99).as_secs_f64());
    gauge("queue_wait_p50_seconds", m.queue_wait_p50().as_secs_f64());
    gauge("queue_wait_p99_seconds", m.queue_wait_p99().as_secs_f64());
    gauge("batch_latency_p50_seconds", m.batch_latency_p50().as_secs_f64());
    gauge("batch_latency_p99_seconds", m.batch_latency_p99().as_secs_f64());
    out
}

fn reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

fn respond(mut stream: TcpStream, code: u16, ctype: &str, body: &str) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(code),
        body.len(),
    );
    stream.write_all(head.as_bytes()).context("write response head")?;
    stream.write_all(body.as_bytes()).context("write response body")?;
    stream.flush().context("flush response")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{FaultSetting, ScoringServer, ServerConfig};
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    fn request(addr: SocketAddr, raw: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let code = buf
            .split_whitespace()
            .nth(1)
            .and_then(|c| c.parse().ok())
            .unwrap_or(0);
        let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (code, body)
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        request(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
    }

    fn post_score(addr: SocketAddr, body: &str) -> (u16, String) {
        request(
            addr,
            &format!(
                "POST /score HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    fn test_server() -> ScoringServer {
        let model = tiny_model(4, 2, false, 300);
        let cfg = ServerConfig { fault: FaultSetting::Off, ..ServerConfig::default() };
        ScoringServer::start(model, cfg, || Ok(NativeEngine)).unwrap()
    }

    #[test]
    fn scores_health_and_metrics_over_http() {
        let server = test_server();
        let mut http =
            HttpServer::bind("127.0.0.1:0", server.handle(), server.status()).unwrap();
        let addr = http.addr();

        let (code, body) = get(addr, "/healthz");
        assert_eq!(code, 200);
        assert_eq!(body, "ok\n");

        let (code, body) =
            post_score(addr, r#"{"prompt": "c:abcd|", "completion": "abcd."}"#);
        assert_eq!(code, 200, "body: {body}");
        let score = Json::parse(&body).unwrap().get("score").unwrap().as_f64().unwrap();
        assert!(score.is_finite() && score < 0.0);

        let (code, body) = get(addr, "/metrics");
        assert_eq!(code, 200);
        assert!(body.contains("mergemoe_requests_total 1"));
        assert!(body.contains("mergemoe_shed_total 0"));
        assert!(body.contains("mergemoe_queue_depth 0"));

        http.stop();
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_typed_statuses() {
        let server = test_server();
        let mut http =
            HttpServer::bind("127.0.0.1:0", server.handle(), server.status()).unwrap();
        let addr = http.addr();

        let (code, _) = post_score(addr, "not json");
        assert_eq!(code, 400);
        let (code, _) = post_score(addr, r#"{"prompt": "x"}"#); // missing completion
        assert_eq!(code, 400);
        let long = "a".repeat(200);
        let (code, body) =
            post_score(addr, &format!(r#"{{"prompt": "{long}", "completion": "b"}}"#));
        assert_eq!(code, 400, "oversized request must map to 400: {body}");
        let (code, _) = get(addr, "/nope");
        assert_eq!(code, 404);

        http.stop();
        server.shutdown();
    }

    #[test]
    fn healthz_reflects_draining_server() {
        let server = test_server();
        let handle = server.handle();
        let mut http = HttpServer::bind("127.0.0.1:0", handle, server.status()).unwrap();
        let addr = http.addr();
        let status = server.status();
        server.shutdown();
        assert!(status.draining());
        let (code, body) = get(addr, "/healthz");
        assert_eq!(code, 503);
        assert_eq!(body, "draining\n");
        // scoring through the front end now gets the typed 503
        let (code, _) = post_score(addr, r#"{"prompt": "c:ab|", "completion": "ab."}"#);
        assert_eq!(code, 503);
        http.stop();
    }

    #[test]
    fn stop_is_idempotent_and_unblocks_accept() {
        let server = test_server();
        let mut http =
            HttpServer::bind("127.0.0.1:0", server.handle(), server.status()).unwrap();
        http.stop();
        http.stop(); // second call is a no-op, not a hang
        server.shutdown();
    }
}
