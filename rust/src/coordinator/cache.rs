//! Memory-budgeted in-process variant cache: single-flight builds, failure
//! quarantine, pin-aware LRU eviction — the serving-side realization of the
//! paper's "one base model yields a family of compressed variants" claim.
//!
//! [`VariantCache`] resolves a [`VariantKey`] `{method, m, calib}` to
//! ready-to-score [`ModelWeights`] under a hard byte budget:
//!
//! * **hit** — pin the cached entry (LRU-refreshed) and hand out a
//!   [`VariantLease`]; the pin count guarantees an entry with in-flight
//!   batches is never evicted (the lease's `Drop` unpins).
//! * **miss** — mark the slot *building* and populate it outside the lock:
//!   first from the registry ([`Registry::load_latest_good`] under the
//!   canonical variant name), else by compressing from the base model
//!   ([`capture_calibration_source`] + [`compress_with_calib`]). Both the
//!   capture and the merge are seeded, so a rebuild of an evicted variant
//!   is **bit-identical** to the original (`tests/variant_cache.rs` pins
//!   routed-score ≡ direct-compression identity on this).
//! * **concurrent miss** — single-flight: every other requester parks on a
//!   condvar, so N cold requests trigger exactly one build. Parked
//!   requesters keep their deadlines: one that expires while parked fails
//!   [`CacheError::DeadlineExceeded`] without computing anything.
//! * **failed build** — transient failures retry under capped backoff
//!   (deterministically drillable via `MERGEMOE_FAULT=…,build-fail:N`); a
//!   fatal failure, a build panic, or retry exhaustion **quarantines** the
//!   key, so subsequent requests fail fast and typed
//!   ([`CacheError::VariantUnavailable`]) instead of re-triggering doomed
//!   builds. The server's `--route-fallback base` policy may then route
//!   that traffic to the boot variant with a `fallback=true` marker.
//! * **admission** — entries account `n_params × 4` bytes against the
//!   budget (`--cache-budget-mb` / `MERGEMOE_CACHE_BUDGET_MB`); unpinned
//!   entries are LRU-evicted to make room, and a variant that cannot fit
//!   even after evicting every unpinned entry is rejected typed
//!   ([`CacheError::BudgetExceeded`]) — never an OOM. The base model lives
//!   *outside* the budget: it is the compression source and the fallback
//!   target, so it must never be evictable.
//!
//! Every lock acquisition is poison-tolerant (`unwrap_or_else(|e|
//! e.into_inner())`): a panicking builder thread must not wedge the cache
//! for the lanes that share it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::calib::CalibSource;
use crate::coordinator::pipeline::{
    capture_calibration_source, compress_with_calib, CompressSpec,
};
use crate::coordinator::registry::Registry;
use crate::info;
use crate::merge::{Algorithm, NativeGram};
use crate::model::workspace::Workspace;
use crate::model::ModelWeights;
use crate::util::fault::{classify, FaultAction, FaultClass, FaultPlan, InjectedFault};

/// Canonical identity of a compressed variant: `{method, m, calib}` where
/// `m` is the resolved per-layer expert target. Requests carry the paper's
/// `{method, ratio, calib_source}` triple; [`VariantKey::resolve`]
/// canonicalizes it (ratio → `m`, method/calib spellings normalized) so
/// `"MergeMoE"` and `"mergemoe"` at the same ratio share one cache slot.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct VariantKey {
    /// Canonical lowercase method name (round-trips [`Algorithm::from_name`]).
    pub method: String,
    /// Target expert count per merged layer (`round(ratio × n_experts)`).
    pub m: usize,
    /// Canonical calibration-source label (`"mixture"`, `"copy+parity"`, …).
    pub calib: String,
}

impl VariantKey {
    /// Validate and canonicalize a routing triple. `ratio` is the target
    /// expert fraction `m / n_experts` in `(0, 1]`; the resolved `m` is
    /// clamped to `[1, n_experts]`.
    pub fn resolve(method: &str, ratio: f64, calib: &str, n_experts: usize) -> Result<VariantKey> {
        let alg = Algorithm::from_name(method)
            .with_context(|| format!("unknown compression method {method:?}"))?;
        let source = CalibSource::parse(calib)
            .with_context(|| format!("bad calibration source {calib:?}"))?;
        if !(ratio > 0.0 && ratio <= 1.0) || !ratio.is_finite() {
            bail!("ratio {ratio} outside (0, 1]");
        }
        let m = ((ratio * n_experts as f64).round() as usize).clamp(1, n_experts);
        Ok(VariantKey {
            method: alg.name().to_ascii_lowercase(),
            m,
            calib: source.label,
        })
    }

    /// Human-readable identity, used in errors and logs: `mergemoe-m4-mixture`.
    pub fn label(&self) -> String {
        format!("{}-m{}-{}", self.method, self.m, self.calib)
    }

    /// The canonical registry name the cache probes before compressing:
    /// `<base>-<method>-m<m>-<calib>` with every character the registry
    /// rejects (e.g. the `+` in `"copy+parity"`) mapped to `_`.
    pub fn registry_name(&self, base: &str) -> String {
        let raw = format!("{base}-{}", self.label());
        raw.chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '_' || c == '-' { c } else { '_' })
            .collect()
    }
}

/// Typed cache outcomes — every failure mode a routed request can hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheError {
    /// The requester's deadline expired while parked on a build in flight.
    DeadlineExceeded,
    /// The variant is quarantined (its build failed fatally or exhausted
    /// retries); requests fail fast instead of re-triggering the build.
    VariantUnavailable {
        /// [`VariantKey::label`] of the quarantined variant.
        variant: String,
        /// Why the variant was quarantined.
        reason: String,
    },
    /// The variant cannot fit in the budget right now (or ever, if its own
    /// size exceeds the whole budget — that case also quarantines).
    BudgetExceeded {
        /// [`VariantKey::label`] of the rejected variant.
        variant: String,
        /// Bytes the variant needs.
        need_bytes: usize,
        /// The configured cache budget in bytes.
        budget_bytes: usize,
    },
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheError::DeadlineExceeded => write!(f, "deadline exceeded while variant build in flight"),
            CacheError::VariantUnavailable { variant, reason } => {
                write!(f, "variant {variant} unavailable: {reason}")
            }
            CacheError::BudgetExceeded { variant, need_bytes, budget_bytes } => write!(
                f,
                "variant {variant} needs {need_bytes} B, cache budget {budget_bytes} B cannot admit it"
            ),
        }
    }
}

impl std::error::Error for CacheError {}

/// Tuning knobs for [`VariantCache`].
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Hard byte budget over all cached variants (`--cache-budget-mb`).
    pub budget_bytes: usize,
    /// Transient-build retries before quarantine.
    pub max_retries: u32,
    /// Base backoff between build retries (doubled per retry, capped).
    pub retry_backoff: Duration,
    /// Calibration sequences per cold compression (the build spec).
    pub n_calib_seqs: usize,
    /// Calibration/merge seed — fixed, so rebuilds are bit-identical.
    pub seed: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            budget_bytes: default_budget_bytes(),
            max_retries: 2,
            retry_backoff: Duration::from_millis(1),
            n_calib_seqs: 48,
            seed: 0xC0FFEE,
        }
    }
}

/// `MERGEMOE_CACHE_BUDGET_MB` (MiB), default 256 MiB.
pub fn default_budget_bytes() -> usize {
    std::env::var("MERGEMOE_CACHE_BUDGET_MB")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|mb| mb * 1024 * 1024)
        .unwrap_or(256 * 1024 * 1024)
}

/// Monotonic counters the cache exposes on `/metrics` (`cache_*` gauges).
#[derive(Debug, Default)]
struct CacheCounters {
    bytes: AtomicU64,
    bytes_peak: AtomicU64,
    entries: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    builds: AtomicU64,
    build_failures: AtomicU64,
    registry_loads: AtomicU64,
    evictions: AtomicU64,
    quarantined: AtomicU64,
}

/// Point-in-time copy of the cache counters (see [`VariantCache::snapshot`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Bytes currently admitted (sum over ready entries; base excluded).
    pub bytes: u64,
    /// High-water mark of `bytes` — the acceptance gauge for "peak cache
    /// bytes never exceed the budget".
    pub bytes_peak: u64,
    /// Configured budget in bytes.
    pub budget_bytes: u64,
    /// Ready entries currently cached.
    pub entries: u64,
    /// Checkouts served from a ready entry.
    pub hits: u64,
    /// Checkouts that took the builder role (cold slots).
    pub misses: u64,
    /// Successful builds (registry loads + compressions).
    pub builds: u64,
    /// Failed build attempts (each retry that failed counts once).
    pub build_failures: u64,
    /// Builds satisfied by [`Registry::load_latest_good`].
    pub registry_loads: u64,
    /// Entries LRU-evicted to admit another variant.
    pub evictions: u64,
    /// Keys moved to quarantine (fatal/exhausted/oversized builds).
    pub quarantined: u64,
}

struct Entry {
    model: Arc<ModelWeights>,
    bytes: usize,
    pins: usize,
    last_use: u64,
}

enum Slot {
    /// A build is in flight; requesters park on the condvar.
    Building,
    /// Ready to score.
    Ready(Entry),
    /// Build failed fatally — fail fast until the process restarts.
    Quarantined { reason: String },
}

struct CacheInner {
    slots: HashMap<VariantKey, Slot>,
    /// Sum of `Ready` entry bytes (the budget accounting).
    bytes: usize,
    /// Monotonic LRU clock.
    tick: u64,
}

/// The memory-budgeted variant cache (see the module docs for the contract).
pub struct VariantCache {
    base: Arc<ModelWeights>,
    registry: Option<Arc<Registry>>,
    cfg: CacheConfig,
    fault: Option<Arc<FaultPlan>>,
    inner: Mutex<CacheInner>,
    cv: Condvar,
    stats: CacheCounters,
}

impl std::fmt::Debug for VariantCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VariantCache")
            .field("base", &self.base.cfg.name)
            .field("budget_bytes", &self.cfg.budget_bytes)
            .finish()
    }
}

/// A pinned checkout of one variant. Holding the lease guarantees the
/// entry cannot be evicted; dropping it unpins (and wakes admission
/// waiters). Lanes hold exactly one lease, for the duration of one batch.
pub struct VariantLease {
    cache: Arc<VariantCache>,
    key: VariantKey,
    model: Arc<ModelWeights>,
}

impl VariantLease {
    /// The pinned weights.
    pub fn model(&self) -> &Arc<ModelWeights> {
        &self.model
    }

    /// The variant this lease pins.
    pub fn key(&self) -> &VariantKey {
        &self.key
    }
}

impl Drop for VariantLease {
    fn drop(&mut self) {
        let mut g = self.cache.lock();
        if let Some(Slot::Ready(e)) = g.slots.get_mut(&self.key) {
            e.pins = e.pins.saturating_sub(1);
        }
        drop(g);
        self.cache.cv.notify_all();
    }
}

impl VariantCache {
    /// Build a cache over `base` (the compression source, held outside the
    /// budget). `registry` is probed before compressing; `fault` supplies
    /// the `build-fail` schedule of a chaos plan (usually the server's).
    pub fn new(
        base: ModelWeights,
        registry: Option<Arc<Registry>>,
        cfg: CacheConfig,
        fault: Option<Arc<FaultPlan>>,
    ) -> VariantCache {
        VariantCache {
            base: Arc::new(base),
            registry,
            cfg,
            fault,
            inner: Mutex::new(CacheInner { slots: HashMap::new(), bytes: 0, tick: 0 }),
            cv: Condvar::new(),
            stats: CacheCounters::default(),
        }
    }

    /// The base/boot weights (compression source and fallback target).
    pub fn base(&self) -> &Arc<ModelWeights> {
        &self.base
    }

    /// The configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.cfg.budget_bytes
    }

    /// The exact [`CompressSpec`] a cold build uses for `key` — tests
    /// rebuild reference variants from this to assert bit-identity between
    /// routed scores and direct compression.
    pub fn build_spec(&self, key: &VariantKey) -> CompressSpec {
        let alg = Algorithm::from_name(&key.method)
            .expect("VariantKey.method is canonical (resolve() validated it)");
        let mut spec =
            CompressSpec::new((0..self.base.cfg.n_layers).collect(), key.m, alg);
        spec.n_calib_seqs = self.cfg.n_calib_seqs;
        spec.calib_tasks = CalibSource::parse(&key.calib).ok().and_then(|s| s.tasks);
        spec.seed = self.cfg.seed;
        spec
    }

    /// Counter snapshot for `/metrics`.
    pub fn snapshot(&self) -> CacheStats {
        let s = &self.stats;
        CacheStats {
            bytes: s.bytes.load(Ordering::Relaxed),
            bytes_peak: s.bytes_peak.load(Ordering::Relaxed),
            budget_bytes: self.cfg.budget_bytes as u64,
            entries: s.entries.load(Ordering::Relaxed),
            hits: s.hits.load(Ordering::Relaxed),
            misses: s.misses.load(Ordering::Relaxed),
            builds: s.builds.load(Ordering::Relaxed),
            build_failures: s.build_failures.load(Ordering::Relaxed),
            registry_loads: s.registry_loads.load(Ordering::Relaxed),
            evictions: s.evictions.load(Ordering::Relaxed),
            quarantined: s.quarantined.load(Ordering::Relaxed),
        }
    }

    /// Whether `key` currently has a ready (scoreable) entry.
    pub fn contains(&self, key: &VariantKey) -> bool {
        matches!(self.lock().slots.get(key), Some(Slot::Ready(_)))
    }

    /// Whether `key` is quarantined.
    pub fn is_quarantined(&self, key: &VariantKey) -> bool {
        matches!(self.lock().slots.get(key), Some(Slot::Quarantined { .. }))
    }

    fn lock(&self) -> MutexGuard<'_, CacheInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Refresh the `bytes`/`entries` gauges from the locked state.
    fn publish(&self, g: &CacheInner) {
        self.stats.bytes.store(g.bytes as u64, Ordering::Relaxed);
        self.stats.bytes_peak.fetch_max(g.bytes as u64, Ordering::Relaxed);
        let n = g.slots.values().filter(|s| matches!(s, Slot::Ready(_))).count();
        self.stats.entries.store(n as u64, Ordering::Relaxed);
    }

    /// Resolve `key` to a pinned lease: cache hit, or single-flight
    /// build-and-admit, or a typed failure (see [`CacheError`]). `deadline`
    /// bounds only the *parked* wait — the thread that takes the builder
    /// role always finishes its build so the waiters (and later requests)
    /// benefit from the work.
    pub fn checkout(
        self: &Arc<Self>,
        key: &VariantKey,
        deadline: Option<Instant>,
    ) -> std::result::Result<VariantLease, CacheError> {
        let mut g = self.lock();
        loop {
            match g.slots.get_mut(key) {
                Some(Slot::Ready(e)) => {
                    e.pins += 1;
                    g.tick += 1;
                    e.last_use = g.tick;
                    let model = e.model.clone();
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(VariantLease { cache: self.clone(), key: key.clone(), model });
                }
                Some(Slot::Quarantined { reason }) => {
                    return Err(CacheError::VariantUnavailable {
                        variant: key.label(),
                        reason: reason.clone(),
                    });
                }
                Some(Slot::Building) => match deadline {
                    Some(d) => {
                        let now = Instant::now();
                        if now >= d {
                            return Err(CacheError::DeadlineExceeded);
                        }
                        let (g2, _) = self
                            .cv
                            .wait_timeout(g, d - now)
                            .unwrap_or_else(|e| e.into_inner());
                        g = g2;
                    }
                    None => g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner()),
                },
                None => {
                    g.slots.insert(key.clone(), Slot::Building);
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
        }
        drop(g);
        // builder role: build outside the lock, then admit or quarantine.
        // Every exit path below re-takes the lock, replaces the Building
        // slot, and notifies — parked waiters can never wedge.
        match self.build(key) {
            Ok(model) => self.admit(key, model),
            Err(reason) => {
                let mut g = self.lock();
                g.slots
                    .insert(key.clone(), Slot::Quarantined { reason: reason.clone() });
                drop(g);
                self.stats.quarantined.fetch_add(1, Ordering::Relaxed);
                self.cv.notify_all();
                Err(CacheError::VariantUnavailable { variant: key.label(), reason })
            }
        }
    }

    /// Run build attempts under the retry policy. `Err` carries the
    /// quarantine reason.
    fn build(&self, key: &VariantKey) -> std::result::Result<ModelWeights, String> {
        let mut attempt: u32 = 0;
        loop {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.build_once(key)
            }));
            let err = match caught {
                Ok(Ok(model)) => {
                    self.stats.builds.fetch_add(1, Ordering::Relaxed);
                    return Ok(model);
                }
                Ok(Err(e)) => e,
                Err(p) => {
                    // a panicking build is fatal: the state it left behind
                    // is unknown, so retrying could compound the damage
                    self.stats.build_failures.fetch_add(1, Ordering::Relaxed);
                    return Err(format!("build panicked: {}", panic_msg(&p)));
                }
            };
            self.stats.build_failures.fetch_add(1, Ordering::Relaxed);
            if classify(&err) == FaultClass::Fatal {
                return Err(format!("fatal build failure: {err:#}"));
            }
            if attempt >= self.cfg.max_retries {
                return Err(format!(
                    "build failed after {} attempt(s): {err:#}",
                    attempt + 1
                ));
            }
            attempt += 1;
            std::thread::sleep(build_backoff(self.cfg.retry_backoff, attempt));
        }
    }

    /// One build attempt: fault gate, then registry, then compression.
    fn build_once(&self, key: &VariantKey) -> Result<ModelWeights> {
        if let Some(plan) = &self.fault {
            match plan.next_build() {
                FaultAction::None => {}
                FaultAction::Transient => {
                    return Err(InjectedFault { class: FaultClass::Transient }.into())
                }
                FaultAction::Fatal => {
                    return Err(InjectedFault { class: FaultClass::Fatal }.into())
                }
                FaultAction::Slow(d) => std::thread::sleep(d),
                FaultAction::Panic => panic!("injected build panic"),
            }
        }
        if let Some(reg) = &self.registry {
            let name = key.registry_name(&self.base.cfg.name);
            // contains() first: "never registered" is the expected cold
            // path and stays silent; a *registered* variant that will not
            // load is worth a warning before falling back to compression
            if reg.contains(&name) {
                match reg.load_latest_good(&name) {
                    Ok((model, meta))
                        if model.cfg.n_layers == self.base.cfg.n_layers
                            && model.cfg.d_model == self.base.cfg.d_model =>
                    {
                        info!("cache: {} served from registry ({})", key.label(), meta.label());
                        self.stats.registry_loads.fetch_add(1, Ordering::Relaxed);
                        return Ok(model);
                    }
                    Ok((_, meta)) => info!(
                        "cache: registry variant {} shape-incompatible with base; compressing",
                        meta.label()
                    ),
                    Err(e) => crate::warnlog!(
                        "cache: registry variant {name} unloadable ({e:#}); compressing"
                    ),
                }
            }
        }
        let spec = self.build_spec(key);
        let source = CalibSource::parse(&key.calib).context("variant calibration source")?;
        let calib =
            capture_calibration_source(&self.base, spec.n_calib_seqs, &source, spec.seed)?;
        let mut ws = Workspace::new();
        // NativeGram on purpose: cold builds must be deterministic and
        // runnable on a bare checkout (no pallas artifact required)
        let (model, _report) =
            compress_with_calib(&self.base, &spec, &mut NativeGram, &calib, &mut ws)?;
        Ok(model)
    }

    /// Account and insert a built model, LRU-evicting unpinned entries as
    /// needed. Returns the first pinned lease, or a typed budget rejection.
    fn admit(
        self: &Arc<Self>,
        key: &VariantKey,
        model: ModelWeights,
    ) -> std::result::Result<VariantLease, CacheError> {
        let bytes = model.n_params() * 4;
        let mut g = self.lock();
        if bytes > self.cfg.budget_bytes {
            // can never fit — quarantine so later requests fail fast
            let reason = format!(
                "needs {bytes} B, exceeds the whole cache budget ({} B)",
                self.cfg.budget_bytes
            );
            g.slots.insert(key.clone(), Slot::Quarantined { reason });
            self.publish(&g);
            drop(g);
            self.stats.quarantined.fetch_add(1, Ordering::Relaxed);
            self.cv.notify_all();
            return Err(CacheError::BudgetExceeded {
                variant: key.label(),
                need_bytes: bytes,
                budget_bytes: self.cfg.budget_bytes,
            });
        }
        while g.bytes + bytes > self.cfg.budget_bytes {
            let victim = g
                .slots
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready(e) if e.pins == 0 => Some((k.clone(), e.last_use)),
                    _ => None,
                })
                .min_by_key(|&(_, last_use)| last_use)
                .map(|(k, _)| k);
            match victim {
                Some(vk) => {
                    if let Some(Slot::Ready(e)) = g.slots.remove(&vk) {
                        g.bytes -= e.bytes;
                        self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => {
                    // everything still cached is pinned: reject typed and
                    // clear the Building slot so a later request (after
                    // pins release) may rebuild
                    g.slots.remove(key);
                    self.publish(&g);
                    drop(g);
                    self.cv.notify_all();
                    return Err(CacheError::BudgetExceeded {
                        variant: key.label(),
                        need_bytes: bytes,
                        budget_bytes: self.cfg.budget_bytes,
                    });
                }
            }
        }
        g.bytes += bytes;
        g.tick += 1;
        let entry = Entry { model: Arc::new(model), bytes, pins: 1, last_use: g.tick };
        let model = entry.model.clone();
        g.slots.insert(key.clone(), Slot::Ready(entry));
        self.publish(&g);
        drop(g);
        self.cv.notify_all();
        Ok(VariantLease { cache: self.clone(), key: key.clone(), model })
    }
}

/// Capped exponential backoff between build retries (mirrors the lane
/// retry policy: base × 2^(attempt−1), never more than 100 ms).
fn build_backoff(base: Duration, attempt: u32) -> Duration {
    let mult = 1u32 << attempt.saturating_sub(1).min(10);
    base.saturating_mul(mult).min(Duration::from_millis(100))
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    fn test_cfg(budget: usize) -> CacheConfig {
        CacheConfig {
            budget_bytes: budget,
            max_retries: 1,
            retry_backoff: Duration::from_micros(100),
            n_calib_seqs: 4,
            seed: 7,
        }
    }

    fn key(m: usize) -> VariantKey {
        VariantKey::resolve("mergemoe", m as f64 / 4.0, "mixture", 4).unwrap()
    }

    /// Bytes one m-expert variant of the 4-expert tiny model occupies.
    fn variant_bytes(m: usize) -> usize {
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 500),
            None,
            test_cfg(usize::MAX / 8),
            None,
        ));
        let lease = cache.checkout(&key(m), None).unwrap();
        drop(lease);
        cache.snapshot().bytes as usize
    }

    #[test]
    fn resolve_canonicalizes_and_validates() {
        let a = VariantKey::resolve("MergeMoE", 0.5, "mixture", 8).unwrap();
        let b = VariantKey::resolve("mergemoe", 0.5, "all", 8).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.m, 4);
        assert_eq!(a.label(), "mergemoe-m4-mixture");
        assert!(VariantKey::resolve("wat", 0.5, "mixture", 8).is_err());
        assert!(VariantKey::resolve("average", 0.0, "mixture", 8).is_err());
        assert!(VariantKey::resolve("average", 1.5, "mixture", 8).is_err());
        assert!(VariantKey::resolve("average", 0.5, "wat", 8).is_err());
        // registry names never contain charset the registry rejects
        let k = VariantKey::resolve("average", 0.5, "copy+parity", 8).unwrap();
        let name = k.registry_name("beta");
        assert!(name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '.' || c == '_' || c == '-'));
        assert_eq!(name, "beta-average-m4-copy_parity");
    }

    #[test]
    fn cold_build_then_hits() {
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 501),
            None,
            test_cfg(usize::MAX / 8),
            None,
        ));
        let k = key(2);
        let a = cache.checkout(&k, None).unwrap();
        let uid = a.model().uid;
        assert_eq!(a.model().layers[0].moe.n_experts(), 2);
        drop(a);
        let b = cache.checkout(&k, None).unwrap();
        assert_eq!(b.model().uid, uid, "hit must return the same weights");
        let s = cache.snapshot();
        assert_eq!((s.builds, s.misses, s.hits), (1, 1, 1));
        assert!(s.bytes > 0 && s.bytes_peak >= s.bytes);
    }

    #[test]
    fn single_flight_concurrent_cold_requests_build_once() {
        let plan = Arc::new(
            FaultPlan::scripted(vec![])
                .with_build_script(vec![FaultAction::Slow(Duration::from_millis(30))]),
        );
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 502),
            None,
            test_cfg(usize::MAX / 8),
            Some(plan),
        ));
        let k = key(2);
        let mut joins = Vec::new();
        for _ in 0..8 {
            let c = cache.clone();
            let kk = k.clone();
            joins.push(std::thread::spawn(move || {
                c.checkout(&kk, None).map(|l| l.model().uid)
            }));
        }
        let uids: Vec<u64> = joins.into_iter().map(|j| j.join().unwrap().unwrap()).collect();
        assert!(uids.windows(2).all(|w| w[0] == w[1]), "all see one build");
        let s = cache.snapshot();
        assert_eq!(s.builds, 1, "exactly one build for 8 concurrent requests");
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 7);
    }

    #[test]
    fn parked_deadline_fails_without_computing() {
        let plan = Arc::new(
            FaultPlan::scripted(vec![])
                .with_build_script(vec![FaultAction::Slow(Duration::from_millis(300))]),
        );
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 503),
            None,
            test_cfg(usize::MAX / 8),
            Some(plan),
        ));
        let k = key(2);
        let builder = {
            let c = cache.clone();
            let kk = k.clone();
            std::thread::spawn(move || c.checkout(&kk, None).map(|_| ()))
        };
        // wait for the builder to claim the slot
        let t0 = Instant::now();
        while cache.snapshot().misses == 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_micros(200));
        }
        let d = Instant::now() + Duration::from_millis(10);
        let err = cache.checkout(&k, Some(d)).unwrap_err();
        assert_eq!(err, CacheError::DeadlineExceeded);
        assert!(Instant::now() >= d, "must have parked until the deadline");
        builder.join().unwrap().unwrap();
        assert_eq!(cache.snapshot().builds, 1);
    }

    #[test]
    fn fatal_build_quarantines_and_fails_fast() {
        let plan = Arc::new(
            FaultPlan::scripted(vec![]).with_build_script(vec![FaultAction::Fatal]),
        );
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 504),
            None,
            test_cfg(usize::MAX / 8),
            Some(plan.clone()),
        ));
        let k = key(2);
        match cache.checkout(&k, None) {
            Err(CacheError::VariantUnavailable { variant, .. }) => {
                assert_eq!(variant, k.label())
            }
            other => panic!("expected VariantUnavailable, got {other:?}"),
        }
        assert!(cache.is_quarantined(&k));
        let attempts = plan.build_attempts();
        assert_eq!(attempts, 1, "fatal fault must not retry");
        // second request fails fast without a new build attempt
        assert!(matches!(
            cache.checkout(&k, None),
            Err(CacheError::VariantUnavailable { .. })
        ));
        assert_eq!(plan.build_attempts(), attempts);
        assert_eq!(cache.snapshot().quarantined, 1);
    }

    #[test]
    fn transient_build_retries_then_succeeds() {
        let plan = Arc::new(
            FaultPlan::scripted(vec![]).with_build_script(vec![FaultAction::Transient]),
        );
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 505),
            None,
            test_cfg(usize::MAX / 8),
            Some(plan),
        ));
        let lease = cache.checkout(&key(2), None).unwrap();
        drop(lease);
        let s = cache.snapshot();
        assert_eq!((s.builds, s.build_failures), (1, 1));
    }

    #[test]
    fn retry_exhaustion_quarantines() {
        let plan = Arc::new(FaultPlan::scripted(vec![]).with_build_script(vec![
            FaultAction::Transient,
            FaultAction::Transient, // max_retries = 1 → both attempts fail
        ]));
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 506),
            None,
            test_cfg(usize::MAX / 8),
            Some(plan),
        ));
        assert!(matches!(
            cache.checkout(&key(2), None),
            Err(CacheError::VariantUnavailable { .. })
        ));
        assert!(cache.is_quarantined(&key(2)));
        assert_eq!(cache.snapshot().build_failures, 2);
    }

    #[test]
    fn oversized_variant_rejected_typed_and_quarantined() {
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 507),
            None,
            test_cfg(8), // 8 bytes: nothing fits
            None,
        ));
        match cache.checkout(&key(2), None) {
            Err(CacheError::BudgetExceeded { need_bytes, budget_bytes, .. }) => {
                assert!(need_bytes > budget_bytes);
            }
            other => panic!("expected BudgetExceeded, got {other:?}"),
        }
        assert!(cache.is_quarantined(&key(2)));
        assert_eq!(cache.snapshot().bytes, 0);
    }

    #[test]
    fn lru_evicts_oldest_unpinned_never_pinned() {
        // budget fits exactly two m=2 variants (distinct calib sources)
        let two = 2 * variant_bytes(2);
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 500),
            None,
            test_cfg(two),
            None,
        ));
        let ka = VariantKey::resolve("mergemoe", 0.5, "copy", 4).unwrap();
        let kb = VariantKey::resolve("mergemoe", 0.5, "parity", 4).unwrap();
        let kc = VariantKey::resolve("mergemoe", 0.5, "mixture", 4).unwrap();
        drop(cache.checkout(&ka, None).unwrap());
        drop(cache.checkout(&kb, None).unwrap());
        assert!(cache.contains(&ka) && cache.contains(&kb));
        // third variant evicts the LRU (ka)
        drop(cache.checkout(&kc, None).unwrap());
        assert!(!cache.contains(&ka), "LRU entry must be evicted");
        assert!(cache.contains(&kb) && cache.contains(&kc));
        let s = cache.snapshot();
        assert_eq!(s.evictions, 1);
        assert!(s.bytes_peak as usize <= two, "peak {} > budget {two}", s.bytes_peak);
        // pinned entries are never evicted: pin kb, ask for ka again —
        // kc (unpinned) must be the victim
        let pinned = cache.checkout(&kb, None).unwrap();
        drop(cache.checkout(&ka, None).unwrap());
        assert!(cache.contains(&kb), "pinned entry evicted");
        assert!(!cache.contains(&kc));
        // with both slots pinned, a third variant is rejected typed
        let pinned2 = cache.checkout(&ka, None).unwrap();
        match cache.checkout(&kc, None) {
            Err(CacheError::BudgetExceeded { .. }) => {}
            other => panic!("expected BudgetExceeded, got {other:?}"),
        }
        assert!(!cache.is_quarantined(&kc), "pin-blocked rejection is not a quarantine");
        drop(pinned);
        drop(pinned2);
        // pins released → the same variant admits fine now
        drop(cache.checkout(&kc, None).unwrap());
        assert!(cache.contains(&kc));
        let s = cache.snapshot();
        assert!(s.bytes_peak as usize <= two, "peak {} > budget {two}", s.bytes_peak);
    }

    #[test]
    fn rebuild_after_eviction_is_bit_identical() {
        let two = 2 * variant_bytes(2);
        let cache = Arc::new(VariantCache::new(
            tiny_model(4, 2, false, 500),
            None,
            test_cfg(two),
            None,
        ));
        let ka = VariantKey::resolve("mergemoe", 0.5, "copy", 4).unwrap();
        let kb = VariantKey::resolve("mergemoe", 0.5, "parity", 4).unwrap();
        let kc = VariantKey::resolve("mergemoe", 0.5, "mixture", 4).unwrap();
        let first = cache.checkout(&ka, None).unwrap();
        let wg0: Vec<f32> = first.model().layers[0].moe.experts[0].wg.data().to_vec();
        drop(first);
        // churn ka out, then fault it back in
        drop(cache.checkout(&kb, None).unwrap());
        drop(cache.checkout(&kc, None).unwrap());
        assert!(!cache.contains(&ka));
        let again = cache.checkout(&ka, None).unwrap();
        assert_eq!(
            again.model().layers[0].moe.experts[0].wg.data(),
            &wg0[..],
            "seeded rebuild must be bit-identical"
        );
    }

    #[test]
    fn registry_variant_preferred_over_compression() {
        let dir = tempdir("cache-reg");
        let reg = Arc::new(Registry::open(&dir).unwrap());
        let base = tiny_model(4, 2, false, 508);
        // pre-register a variant under the canonical cache name, with
        // sentinel weights distinguishable from a fresh compression
        let k = key(2);
        let mut sentinel = base.clone();
        for l in &mut sentinel.layers {
            l.moe.experts.truncate(2);
            for e in &mut l.moe.experts {
                for v in e.wg.data_mut() {
                    *v = 0.125;
                }
            }
            l.moe.map = Some(crate::tensor::Tensor::zeros(&[2, 4]));
        }
        sentinel.touch();
        let spec = crate::coordinator::registry::VariantSpec {
            method: "mergemoe".into(),
            ratio: 0.5,
            calib_source: "mixture".into(),
        };
        reg.add(&k.registry_name(&base.cfg.name), &sentinel, &spec).unwrap();
        let cache = Arc::new(VariantCache::new(
            base,
            Some(reg),
            test_cfg(usize::MAX / 8),
            None,
        ));
        let lease = cache.checkout(&k, None).unwrap();
        assert!(lease.model().layers[0].moe.experts[0]
            .wg
            .data()
            .iter()
            .all(|&v| v == 0.125));
        let s = cache.snapshot();
        assert_eq!((s.builds, s.registry_loads), (1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn tempdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "mergemoe-{tag}-{}-{}",
            std::process::id(),
            crate::model::fresh_uid()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }
}
