//! Dynamic batcher: groups concurrent scoring requests into engine-sized
//! batches under a latency deadline — the vLLM-router-style admission layer
//! in front of the single compiled backend.
//!
//! Policy: a batch is flushed when (a) it reaches `max_batch` sequences,
//! (b) `max_wait` has elapsed since the *oldest* queued request, or (c) the
//! earliest per-request **deadline** among the collected items is about to
//! pass — waiting longer could only expire work that is still servable.
//! Bucketed executables mean a flush at any size ≤ `max_batch` costs the
//! same as the next bucket up, so the deadline only trades latency against
//! padding waste, never against correctness (padding-invariance is a scorer
//! test).
//!
//! Items whose deadline has already passed are partitioned into
//! [`Batch::expired`] so the server can fail them *without* spending a
//! forward pass on them. Dead-on-arrival items are diverted the moment they
//! are received: they never cap `flush_by` (an already-past deadline would
//! collapse the batching window and flush live items as an undersized
//! batch) and never count toward `max_batch`.
//!
//! The channel carries [`Ctl`] frames rather than bare payloads: a
//! [`Ctl::Close`] sentinel enqueued behind the last admitted request is the
//! explicit drain protocol — the batcher flushes everything ahead of it,
//! then reports `close`, so shutdown never depends on every last sender
//! clone being dropped.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Safety margin for the deadline-aware flush: a batch capped by a
/// per-item deadline flushes this much *before* that deadline, so the
/// capping item is dispatched while it is still servable instead of
/// expiring exactly at the flush boundary.
pub const DEADLINE_FLUSH_MARGIN: Duration = Duration::from_millis(10);

/// The instant a batch containing an item with deadline `d` must flush by.
fn flush_cap(d: Instant) -> Instant {
    d.checked_sub(DEADLINE_FLUSH_MARGIN).unwrap_or(d)
}

/// One queued sequence to score.
pub struct WorkItem<T> {
    /// The request payload.
    pub payload: T,
    /// When the batcher received it (queue-wait metrics).
    pub enqueued: Instant,
}

/// A control frame on the admission channel.
pub enum Ctl<T> {
    /// An admitted request.
    Item(T),
    /// Drain sentinel: flush everything queued ahead of this frame, then
    /// shut down.
    Close,
}

/// One flushed batch.
pub struct Batch<T> {
    /// Items to run now.
    pub ready: Vec<WorkItem<T>>,
    /// Items whose deadline passed while queued — fail these without
    /// running their forward pass.
    pub expired: Vec<WorkItem<T>>,
    /// A [`Ctl::Close`] sentinel was consumed: process this batch, then
    /// shut down.
    pub close: bool,
}

/// Outcome of one poll of the queue.
pub enum BatchDecision<T> {
    /// Run these items now.
    Flush(Batch<T>),
    /// Channel closed (or [`Ctl::Close`] arrived on an empty queue) — shut
    /// down.
    Shutdown,
}

/// Admit one received payload: dead-on-arrival items (deadline already
/// past) go straight to `dead` — they must never open or shrink the flush
/// window — while live items land in `live`, opening the `max_wait` window
/// on the first one and capping it by their own (future) deadline.
fn admit<T>(
    payload: T,
    deadline: Option<Instant>,
    max_wait: Duration,
    live: &mut Vec<WorkItem<T>>,
    dead: &mut Vec<WorkItem<T>>,
    flush_by: &mut Option<Instant>,
) {
    let enqueued = Instant::now();
    let item = WorkItem { payload, enqueued };
    if deadline.is_some_and(|d| d <= enqueued) {
        dead.push(item);
        return;
    }
    let fb = flush_by.get_or_insert(enqueued + max_wait);
    if let Some(d) = deadline {
        *fb = (*fb).min(flush_cap(d));
    }
    live.push(item);
}

/// Collect the next batch from `rx` under the (max_batch, max_wait) policy,
/// with per-item deadlines supplied by `deadline_of`. Blocks until there is
/// at least one item, a close sentinel, or the channel closes.
pub fn next_batch<T>(
    rx: &Receiver<Ctl<T>>,
    max_batch: usize,
    max_wait: Duration,
    deadline_of: impl Fn(&T) -> Option<Instant>,
) -> BatchDecision<T> {
    let mut live: Vec<WorkItem<T>> = Vec::new();
    let mut dead: Vec<WorkItem<T>> = Vec::new();
    let mut close = false;
    // the flush window opens when the first *live* item arrives; a batch
    // of only dead-on-arrival items flushes immediately so their failure
    // replies are prompt
    let mut flush_by: Option<Instant> = None;
    // block for the first frame
    match rx.recv() {
        Ok(Ctl::Item(p)) => {
            let d = deadline_of(&p);
            admit(p, d, max_wait, &mut live, &mut dead, &mut flush_by);
        }
        Ok(Ctl::Close) | Err(_) => return BatchDecision::Shutdown,
    }
    // greedy non-blocking drain: anything already queued joins the batch
    // without waiting out the flush deadline (a zero `max_wait` policy
    // still batches whatever has accumulated)
    while live.len() < max_batch && !close {
        match rx.try_recv() {
            Ok(Ctl::Item(p)) => {
                let d = deadline_of(&p);
                admit(p, d, max_wait, &mut live, &mut dead, &mut flush_by);
            }
            Ok(Ctl::Close) => close = true,
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
        }
    }
    // timed fill: wait out the remaining window, capped by the earliest
    // live per-item deadline (deadline-aware flush); skipped entirely when
    // no live item has opened a window
    while live.len() < max_batch && !close {
        let Some(fb) = flush_by else { break };
        let now = Instant::now();
        if now >= fb {
            break;
        }
        match rx.recv_timeout(fb - now) {
            Ok(Ctl::Item(p)) => {
                let d = deadline_of(&p);
                admit(p, d, max_wait, &mut live, &mut dead, &mut flush_by);
            }
            Ok(Ctl::Close) => close = true,
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // re-check the live side: a deadline may have passed while we waited;
    // the common no-deadline path allocates nothing extra (an empty Vec
    // has no buffer)
    let now = Instant::now();
    let mut expired = dead;
    let ready = if live.iter().any(|it| deadline_of(&it.payload).is_some_and(|d| d <= now)) {
        let (newly_dead, still_live): (Vec<_>, Vec<_>) = live
            .into_iter()
            .partition(|it| deadline_of(&it.payload).is_some_and(|d| d <= now));
        expired.extend(newly_dead);
        still_live
    } else {
        live
    };
    BatchDecision::Flush(Batch { ready, expired, close })
}

/// Partition a flushed batch into per-key groups — stable: arrival order
/// is preserved inside each group, and groups appear in order of their
/// first item. The collector uses this to split a mixed flush into
/// per-variant batches, so batches handed to the compute lanes never mix
/// variants (a lane resolves exactly one model per batch).
pub fn partition_by_key<T, K: PartialEq>(
    items: Vec<WorkItem<T>>,
    key_of: impl Fn(&T) -> K,
) -> Vec<(K, Vec<WorkItem<T>>)> {
    let mut groups: Vec<(K, Vec<WorkItem<T>>)> = Vec::new();
    for it in items {
        let k = key_of(&it.payload);
        match groups.iter_mut().find(|(gk, _)| *gk == k) {
            Some((_, group)) => group.push(it),
            None => groups.push((k, vec![it])),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn no_deadline(_: &i32) -> Option<Instant> {
        None
    }

    fn flush_of(d: BatchDecision<i32>) -> Batch<i32> {
        match d {
            BatchDecision::Flush(b) => b,
            BatchDecision::Shutdown => panic!("expected flush"),
        }
    }

    #[test]
    fn flushes_full_batch_immediately() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(Ctl::Item(i)).unwrap();
        }
        let t0 = Instant::now();
        let b = flush_of(next_batch(&rx, 4, Duration::from_secs(5), no_deadline));
        assert_eq!(b.ready.len(), 4);
        assert!(b.expired.is_empty());
        assert!(!b.close);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn flushes_partial_batch_at_deadline() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        let t0 = Instant::now();
        let b = flush_of(next_batch(&rx, 64, Duration::from_millis(30), no_deadline));
        assert_eq!(b.ready.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn shutdown_on_closed_channel() {
        let (tx, rx) = channel::<Ctl<u32>>();
        drop(tx);
        assert!(matches!(
            next_batch(&rx, 4, Duration::from_millis(1), |_| None),
            BatchDecision::Shutdown
        ));
    }

    #[test]
    fn drains_queue_then_stops_waiting_when_closed() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        tx.send(Ctl::Item(2)).unwrap();
        drop(tx);
        let b = flush_of(next_batch(&rx, 10, Duration::from_secs(1), no_deadline));
        assert_eq!(b.ready.len(), 2);
        assert!(matches!(
            next_batch(&rx, 10, Duration::from_millis(1), no_deadline),
            BatchDecision::Shutdown
        ));
    }

    #[test]
    fn zero_max_wait_still_batches_queued_items() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(Ctl::Item(i)).unwrap();
        }
        let t0 = Instant::now();
        let b = flush_of(next_batch(&rx, 8, Duration::ZERO, no_deadline));
        // the greedy drain picks up everything already queued; the timed
        // fill adds no wait
        assert_eq!(b.ready.len(), 3);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn already_expired_items_are_partitioned_out() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap(); // expired (deadline in the past)
        tx.send(Ctl::Item(2)).unwrap(); // live
        let past = Instant::now() - Duration::from_millis(50);
        let b = flush_of(next_batch(&rx, 8, Duration::ZERO, |&x| {
            (x == 1).then_some(past)
        }));
        assert_eq!(b.expired.len(), 1);
        assert_eq!(b.expired[0].payload, 1);
        assert_eq!(b.ready.len(), 1);
        assert_eq!(b.ready[0].payload, 2);
    }

    #[test]
    fn dead_on_arrival_item_does_not_collapse_the_batching_window() {
        // Regression: an item arriving with an already-past deadline used to
        // pull `flush_by` into the past, so live items trickling in behind it
        // flushed as an undersized batch instead of filling the window. One
        // expired + three live items under a long `max_wait` must still batch
        // the live three.
        let (tx, rx) = channel();
        let past = Instant::now() - Duration::from_millis(50);
        tx.send(Ctl::Item(1)).unwrap(); // live, opens the window
        tx.send(Ctl::Item(99)).unwrap(); // dead on arrival
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            tx.send(Ctl::Item(2)).unwrap();
            std::thread::sleep(Duration::from_millis(40));
            tx.send(Ctl::Item(3)).unwrap();
        });
        // max_batch 3 so the batch closes as soon as the third live item
        // lands; old code flushed after the greedy drain (ready == 1)
        let b = flush_of(next_batch(&rx, 3, Duration::from_millis(300), |&x| {
            (x == 99).then_some(past)
        }));
        sender.join().unwrap();
        let mut ready: Vec<i32> = b.ready.iter().map(|it| it.payload).collect();
        ready.sort_unstable();
        assert_eq!(ready, vec![1, 2, 3], "live items must fill the window");
        assert_eq!(b.expired.len(), 1);
        assert_eq!(b.expired[0].payload, 99);
    }

    #[test]
    fn batch_of_only_expired_items_flushes_empty_ready() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(7)).unwrap();
        let past = Instant::now() - Duration::from_millis(5);
        let b = flush_of(next_batch(&rx, 8, Duration::ZERO, |_| Some(past)));
        assert!(b.ready.is_empty());
        assert_eq!(b.expired.len(), 1);
    }

    #[test]
    fn deadline_aware_flush_cuts_the_wait_short() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        let t0 = Instant::now();
        let soon = t0 + Duration::from_millis(150);
        // max_wait is long, but the item's own deadline caps the wait: the
        // flush happens DEADLINE_FLUSH_MARGIN before `soon`, leaving the
        // item servable instead of expired at the boundary
        let b = flush_of(next_batch(&rx, 8, Duration::from_secs(5), |_| Some(soon)));
        let waited = t0.elapsed();
        assert!(waited < Duration::from_millis(600), "flush waited {waited:?}");
        assert_eq!(b.ready.len(), 1, "deadline-capped flush must leave slack");
        assert!(b.expired.is_empty());
    }

    #[test]
    fn close_sentinel_flushes_pending_then_reports_close() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        tx.send(Ctl::Item(2)).unwrap();
        tx.send(Ctl::Close).unwrap();
        let b = flush_of(next_batch(&rx, 8, Duration::from_secs(5), no_deadline));
        assert_eq!(b.ready.len(), 2);
        assert!(b.close, "close sentinel must be reported with the final flush");
    }

    #[test]
    fn partition_by_key_is_stable_and_exhaustive() {
        let now = Instant::now();
        let items: Vec<WorkItem<i32>> =
            [3, 1, 3, 2, 1, 3].iter().map(|&p| WorkItem { payload: p, enqueued: now }).collect();
        let groups = partition_by_key(items, |&p| p % 10);
        // groups in first-seen order, items in arrival order within each
        let keys: Vec<i32> = groups.iter().map(|(k, _)| *k).collect();
        assert_eq!(keys, vec![3, 1, 2]);
        let sizes: Vec<usize> = groups.iter().map(|(_, g)| g.len()).collect();
        assert_eq!(sizes, vec![3, 2, 1]);
        assert_eq!(groups.iter().map(|(_, g)| g.len()).sum::<usize>(), 6);
        // single-key batches collapse to one group (the common path)
        let uniform: Vec<WorkItem<i32>> =
            (0..4).map(|_| WorkItem { payload: 7, enqueued: now }).collect();
        assert_eq!(partition_by_key(uniform, |&p| p).len(), 1);
    }

    #[test]
    fn close_on_empty_queue_is_shutdown() {
        let (tx, rx) = channel::<Ctl<i32>>();
        tx.send(Ctl::Close).unwrap();
        assert!(matches!(
            next_batch(&rx, 8, Duration::from_secs(5), |_| None),
            BatchDecision::Shutdown
        ));
    }

    #[test]
    fn close_interrupts_the_timed_fill() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        let t0 = Instant::now();
        let tx2 = tx;
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx2.send(Ctl::Close).unwrap();
        });
        let b = flush_of(next_batch(&rx, 8, Duration::from_secs(5), no_deadline));
        j.join().unwrap();
        assert!(b.close);
        assert_eq!(b.ready.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "close must cut the wait");
    }
}
