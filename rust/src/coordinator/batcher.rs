//! Dynamic batcher: groups concurrent scoring requests into engine-sized
//! batches under a latency deadline — the vLLM-router-style admission layer
//! in front of the single compiled backend.
//!
//! Policy: a batch is flushed when (a) it reaches `max_batch` sequences, or
//! (b) `max_wait` has elapsed since the *oldest* queued request. Bucketed
//! executables mean a flush at any size ≤ `max_batch` costs the same as the
//! next bucket up, so the deadline only trades latency against padding
//! waste, never against correctness (padding-invariance is a scorer test).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// One queued sequence to score.
pub struct WorkItem<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Outcome of one poll of the queue.
pub enum BatchDecision<T> {
    /// Run these items now.
    Flush(Vec<WorkItem<T>>),
    /// Channel closed and queue drained — shut down.
    Shutdown,
}

/// Collect the next batch from `rx` under the (max_batch, max_wait) policy.
/// Blocks until there is at least one item or the channel closes.
pub fn next_batch<T>(
    rx: &Receiver<T>,
    max_batch: usize,
    max_wait: Duration,
) -> BatchDecision<T> {
    // block for the first item
    let first = match rx.recv() {
        Ok(p) => WorkItem { payload: p, enqueued: Instant::now() },
        Err(_) => return BatchDecision::Shutdown,
    };
    let deadline = first.enqueued + max_wait;
    let mut items = vec![first];
    while items.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(p) => items.push(WorkItem { payload: p, enqueued: Instant::now() }),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    BatchDecision::Flush(items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn flushes_full_batch_immediately() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let t0 = Instant::now();
        match next_batch(&rx, 4, Duration::from_secs(5)) {
            BatchDecision::Flush(items) => {
                assert_eq!(items.len(), 4);
                assert!(t0.elapsed() < Duration::from_millis(500));
            }
            _ => panic!("expected flush"),
        }
    }

    #[test]
    fn flushes_partial_batch_at_deadline() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        match next_batch(&rx, 64, Duration::from_millis(30)) {
            BatchDecision::Flush(items) => {
                assert_eq!(items.len(), 1);
                assert!(t0.elapsed() >= Duration::from_millis(25));
            }
            _ => panic!("expected flush"),
        }
    }

    #[test]
    fn shutdown_on_closed_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(matches!(
            next_batch(&rx, 4, Duration::from_millis(1)),
            BatchDecision::Shutdown
        ));
    }

    #[test]
    fn drains_queue_then_stops_waiting_when_closed() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        match next_batch(&rx, 10, Duration::from_secs(1)) {
            BatchDecision::Flush(items) => assert_eq!(items.len(), 2),
            _ => panic!("expected flush"),
        }
        assert!(matches!(
            next_batch(&rx, 10, Duration::from_millis(1)),
            BatchDecision::Shutdown
        ));
    }
}
