//! Dynamic batcher: groups concurrent scoring requests into engine-sized
//! batches under a latency deadline — the vLLM-router-style admission layer
//! in front of the single compiled backend.
//!
//! Policy: a batch is flushed when (a) it reaches `max_batch` sequences,
//! (b) `max_wait` has elapsed since the *oldest* queued request, or (c) the
//! earliest per-request **deadline** among the collected items is about to
//! pass — waiting longer could only expire work that is still servable.
//! Bucketed executables mean a flush at any size ≤ `max_batch` costs the
//! same as the next bucket up, so the deadline only trades latency against
//! padding waste, never against correctness (padding-invariance is a scorer
//! test).
//!
//! Items whose deadline has already passed at flush time are partitioned
//! into [`Batch::expired`] so the server can fail them *without* spending a
//! forward pass on them.
//!
//! The channel carries [`Ctl`] frames rather than bare payloads: a
//! [`Ctl::Close`] sentinel enqueued behind the last admitted request is the
//! explicit drain protocol — the batcher flushes everything ahead of it,
//! then reports `close`, so shutdown never depends on every last sender
//! clone being dropped.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Safety margin for the deadline-aware flush: a batch capped by a
/// per-item deadline flushes this much *before* that deadline, so the
/// capping item is dispatched while it is still servable instead of
/// expiring exactly at the flush boundary.
pub const DEADLINE_FLUSH_MARGIN: Duration = Duration::from_millis(10);

/// The instant a batch containing an item with deadline `d` must flush by.
fn flush_cap(d: Instant) -> Instant {
    d.checked_sub(DEADLINE_FLUSH_MARGIN).unwrap_or(d)
}

/// One queued sequence to score.
pub struct WorkItem<T> {
    /// The request payload.
    pub payload: T,
    /// When the batcher received it (queue-wait metrics).
    pub enqueued: Instant,
}

/// A control frame on the admission channel.
pub enum Ctl<T> {
    /// An admitted request.
    Item(T),
    /// Drain sentinel: flush everything queued ahead of this frame, then
    /// shut down.
    Close,
}

/// One flushed batch.
pub struct Batch<T> {
    /// Items to run now.
    pub ready: Vec<WorkItem<T>>,
    /// Items whose deadline passed while queued — fail these without
    /// running their forward pass.
    pub expired: Vec<WorkItem<T>>,
    /// A [`Ctl::Close`] sentinel was consumed: process this batch, then
    /// shut down.
    pub close: bool,
}

/// Outcome of one poll of the queue.
pub enum BatchDecision<T> {
    /// Run these items now.
    Flush(Batch<T>),
    /// Channel closed (or [`Ctl::Close`] arrived on an empty queue) — shut
    /// down.
    Shutdown,
}

/// Collect the next batch from `rx` under the (max_batch, max_wait) policy,
/// with per-item deadlines supplied by `deadline_of`. Blocks until there is
/// at least one item, a close sentinel, or the channel closes.
pub fn next_batch<T>(
    rx: &Receiver<Ctl<T>>,
    max_batch: usize,
    max_wait: Duration,
    deadline_of: impl Fn(&T) -> Option<Instant>,
) -> BatchDecision<T> {
    // block for the first item
    let first = loop {
        match rx.recv() {
            Ok(Ctl::Item(p)) => break WorkItem { payload: p, enqueued: Instant::now() },
            Ok(Ctl::Close) | Err(_) => return BatchDecision::Shutdown,
        }
    };
    let mut close = false;
    let mut flush_by = first.enqueued + max_wait;
    if let Some(d) = deadline_of(&first.payload) {
        flush_by = flush_by.min(flush_cap(d));
    }
    let mut items = vec![first];
    // greedy non-blocking drain: anything already queued joins the batch
    // without waiting out the flush deadline (a zero `max_wait` policy
    // still batches whatever has accumulated)
    while items.len() < max_batch && !close {
        match rx.try_recv() {
            Ok(Ctl::Item(p)) => {
                if let Some(d) = deadline_of(&p) {
                    flush_by = flush_by.min(flush_cap(d));
                }
                items.push(WorkItem { payload: p, enqueued: Instant::now() });
            }
            Ok(Ctl::Close) => close = true,
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
        }
    }
    // timed fill: wait out the remaining window, capped by the earliest
    // per-item deadline (deadline-aware flush)
    while items.len() < max_batch && !close {
        let now = Instant::now();
        if now >= flush_by {
            break;
        }
        match rx.recv_timeout(flush_by - now) {
            Ok(Ctl::Item(p)) => {
                if let Some(d) = deadline_of(&p) {
                    flush_by = flush_by.min(flush_cap(d));
                }
                items.push(WorkItem { payload: p, enqueued: Instant::now() });
            }
            Ok(Ctl::Close) => close = true,
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // partition out already-expired items; the common no-deadline path
    // allocates nothing extra (an empty Vec has no buffer)
    let now = Instant::now();
    let any_expired =
        items.iter().any(|it| deadline_of(&it.payload).is_some_and(|d| d <= now));
    let (expired, ready): (Vec<_>, Vec<_>) = if any_expired {
        items
            .into_iter()
            .partition(|it| deadline_of(&it.payload).is_some_and(|d| d <= now))
    } else {
        (Vec::new(), items)
    };
    BatchDecision::Flush(Batch { ready, expired, close })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn no_deadline(_: &i32) -> Option<Instant> {
        None
    }

    fn flush_of(d: BatchDecision<i32>) -> Batch<i32> {
        match d {
            BatchDecision::Flush(b) => b,
            BatchDecision::Shutdown => panic!("expected flush"),
        }
    }

    #[test]
    fn flushes_full_batch_immediately() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(Ctl::Item(i)).unwrap();
        }
        let t0 = Instant::now();
        let b = flush_of(next_batch(&rx, 4, Duration::from_secs(5), no_deadline));
        assert_eq!(b.ready.len(), 4);
        assert!(b.expired.is_empty());
        assert!(!b.close);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn flushes_partial_batch_at_deadline() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        let t0 = Instant::now();
        let b = flush_of(next_batch(&rx, 64, Duration::from_millis(30), no_deadline));
        assert_eq!(b.ready.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn shutdown_on_closed_channel() {
        let (tx, rx) = channel::<Ctl<u32>>();
        drop(tx);
        assert!(matches!(
            next_batch(&rx, 4, Duration::from_millis(1), |_| None),
            BatchDecision::Shutdown
        ));
    }

    #[test]
    fn drains_queue_then_stops_waiting_when_closed() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        tx.send(Ctl::Item(2)).unwrap();
        drop(tx);
        let b = flush_of(next_batch(&rx, 10, Duration::from_secs(1), no_deadline));
        assert_eq!(b.ready.len(), 2);
        assert!(matches!(
            next_batch(&rx, 10, Duration::from_millis(1), no_deadline),
            BatchDecision::Shutdown
        ));
    }

    #[test]
    fn zero_max_wait_still_batches_queued_items() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(Ctl::Item(i)).unwrap();
        }
        let t0 = Instant::now();
        let b = flush_of(next_batch(&rx, 8, Duration::ZERO, no_deadline));
        // the greedy drain picks up everything already queued; the timed
        // fill adds no wait
        assert_eq!(b.ready.len(), 3);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn already_expired_items_are_partitioned_out() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap(); // expired (deadline in the past)
        tx.send(Ctl::Item(2)).unwrap(); // live
        let past = Instant::now() - Duration::from_millis(50);
        let b = flush_of(next_batch(&rx, 8, Duration::ZERO, |&x| {
            (x == 1).then_some(past)
        }));
        assert_eq!(b.expired.len(), 1);
        assert_eq!(b.expired[0].payload, 1);
        assert_eq!(b.ready.len(), 1);
        assert_eq!(b.ready[0].payload, 2);
    }

    #[test]
    fn batch_of_only_expired_items_flushes_empty_ready() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(7)).unwrap();
        let past = Instant::now() - Duration::from_millis(5);
        let b = flush_of(next_batch(&rx, 8, Duration::ZERO, |_| Some(past)));
        assert!(b.ready.is_empty());
        assert_eq!(b.expired.len(), 1);
    }

    #[test]
    fn deadline_aware_flush_cuts_the_wait_short() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        let t0 = Instant::now();
        let soon = t0 + Duration::from_millis(150);
        // max_wait is long, but the item's own deadline caps the wait: the
        // flush happens DEADLINE_FLUSH_MARGIN before `soon`, leaving the
        // item servable instead of expired at the boundary
        let b = flush_of(next_batch(&rx, 8, Duration::from_secs(5), |_| Some(soon)));
        let waited = t0.elapsed();
        assert!(waited < Duration::from_millis(600), "flush waited {waited:?}");
        assert_eq!(b.ready.len(), 1, "deadline-capped flush must leave slack");
        assert!(b.expired.is_empty());
    }

    #[test]
    fn close_sentinel_flushes_pending_then_reports_close() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        tx.send(Ctl::Item(2)).unwrap();
        tx.send(Ctl::Close).unwrap();
        let b = flush_of(next_batch(&rx, 8, Duration::from_secs(5), no_deadline));
        assert_eq!(b.ready.len(), 2);
        assert!(b.close, "close sentinel must be reported with the final flush");
    }

    #[test]
    fn close_on_empty_queue_is_shutdown() {
        let (tx, rx) = channel::<Ctl<i32>>();
        tx.send(Ctl::Close).unwrap();
        assert!(matches!(
            next_batch(&rx, 8, Duration::from_secs(5), |_| None),
            BatchDecision::Shutdown
        ));
    }

    #[test]
    fn close_interrupts_the_timed_fill() {
        let (tx, rx) = channel();
        tx.send(Ctl::Item(1)).unwrap();
        let t0 = Instant::now();
        let tx2 = tx;
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx2.send(Ctl::Close).unwrap();
        });
        let b = flush_of(next_batch(&rx, 8, Duration::from_secs(5), no_deadline));
        j.join().unwrap();
        assert!(b.close);
        assert_eq!(b.ready.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "close must cut the wait");
    }
}
