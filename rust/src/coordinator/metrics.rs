//! Serving metrics: latency histogram + throughput counters (reported by the
//! scoring server and the benches).

use std::time::Duration;

/// Fixed-bucket latency histogram (log-spaced, 1µs .. ~100s).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i µs, 2^{i+1} µs)
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: vec![0; 28], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate quantile (upper bound of the containing bucket, clamped
    /// to the observed maximum — the bucket bound alone can overshoot
    /// `max()`, and the overflow bucket's bound is ~268s regardless of the
    /// true tail).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros((1u64 << (i + 1)).min(self.max_us));
            }
        }
        self.max()
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Rolled-up serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    /// Submission → batcher pickup, per request.
    pub queue_latency: LatencyHistogram,
    /// Submission → reply, per request.
    pub total_latency: LatencyHistogram,
    /// Forward + scoring compute, per batch (the quantity pool dispatch and
    /// workspace reuse shave — visible from the serving side, not just
    /// microbenches).
    pub batch_latency: LatencyHistogram,
    /// Every replied-to request, success or failure.
    pub requests: u64,
    pub batches: u64,
    pub batched_sequences: u64,
    pub wall_seconds: f64,
    /// Requests replied to with an error (subset of `requests`).
    pub errors: u64,
    /// Requests shed at admission because the bounded queue was full
    /// (these never become `requests`).
    pub shed: u64,
    /// Requests failed because their deadline passed before the forward
    /// pass (subset of `errors`).
    pub expired: u64,
    /// Transient batch-failure retries performed.
    pub retried: u64,
    /// Batches split in half after exhausting retries (poison isolation).
    pub splits: u64,
    /// Worker respawns after a caught panic.
    pub restarted: u64,
    /// Config hot-reloads committed (validate-then-commit succeeded).
    pub reloads: u64,
    /// Config hot-reloads rejected at validation (incumbent kept).
    pub reload_failures: u64,
    /// Variant hot-swaps committed.
    pub swaps: u64,
    /// Variant hot-swaps rolled back (staging/probe failed; incumbent
    /// untouched).
    pub swap_rollbacks: u64,
    /// Batches whose formation overlapped an in-flight forward pass (the
    /// collector handed off while at least one lane was computing) — the
    /// continuous-batching win made visible.
    pub overlapped: u64,
    /// Requests answered on the boot variant because their requested
    /// variant was quarantined (`--route-fallback base`); each such reply
    /// carries `fallback=true`.
    pub fallbacks: u64,
    /// Batches computed per lane, indexed by lane id (empty until the
    /// first lane reports).
    pub lane_batches: Vec<u64>,
}

impl ServerMetrics {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batched_sequences as f64 / self.batches as f64
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.requests as f64 / self.wall_seconds
    }

    /// Median queue wait (submission → batcher pickup).
    pub fn queue_wait_p50(&self) -> Duration {
        self.queue_latency.quantile(0.5)
    }

    /// Tail queue wait.
    pub fn queue_wait_p99(&self) -> Duration {
        self.queue_latency.quantile(0.99)
    }

    /// Median per-batch compute time.
    pub fn batch_latency_p50(&self) -> Duration {
        self.batch_latency.quantile(0.5)
    }

    /// Tail per-batch compute time.
    pub fn batch_latency_p99(&self) -> Duration {
        self.batch_latency.quantile(0.99)
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} throughput={:.1} req/s \
             kernel={} latency: mean {:?} p50 {:?} p99 {:?} max {:?} \
             (queue p50 {:?} p99 {:?}; batch compute p50 {:?} p99 {:?})",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.throughput_rps(),
            crate::kernel::name(),
            self.total_latency.mean(),
            self.total_latency.quantile(0.5),
            self.total_latency.quantile(0.99),
            self.total_latency.max(),
            self.queue_wait_p50(),
            self.queue_wait_p99(),
            self.batch_latency_p50(),
            self.batch_latency_p99(),
        ) + &if self.errors + self.shed + self.retried + self.restarted > 0 {
            format!(
                " faults: errors={} shed={} expired={} retried={} splits={} restarted={}",
                self.errors, self.shed, self.expired, self.retried, self.splits, self.restarted,
            )
        } else {
            String::new()
        } + &if self.overlapped > 0 || self.lane_batches.len() > 1 {
            format!(
                " lanes: n={} batches={:?} overlapped={}",
                self.lane_batches.len().max(1),
                self.lane_batches,
                self.overlapped,
            )
        } else {
            String::new()
        } + &if self.reloads + self.reload_failures + self.swaps + self.swap_rollbacks > 0 {
            format!(
                " admin: reloads={} reload_failures={} swaps={} swap_rollbacks={}",
                self.reloads, self.reload_failures, self.swaps, self.swap_rollbacks,
            )
        } else {
            String::new()
        } + &if self.fallbacks > 0 {
            format!(" routing: fallbacks={}", self.fallbacks)
        } else {
            String::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_ordered() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 17 % 5000 + 1));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(h.mean() > Duration::ZERO);
        assert!(p99 <= h.max());
    }

    #[test]
    fn quantile_never_exceeds_recorded_max() {
        // Regression: the containing bucket's upper bound (2048µs here)
        // used to be returned verbatim, overshooting the observed max.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(1500));
        assert_eq!(h.quantile(0.5), Duration::from_micros(1500));
        assert_eq!(h.quantile(0.99), h.max());
        // the overflow bucket must clamp too, not report ~268s
        let mut big = LatencyHistogram::default();
        big.record(Duration::from_secs(200));
        assert_eq!(big.quantile(0.99), big.max());
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record(Duration::from_millis(1));
        b.record(Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn server_metrics_report() {
        let mut m = ServerMetrics::default();
        m.requests = 100;
        m.batches = 10;
        m.batched_sequences = 80;
        m.wall_seconds = 2.0;
        assert_eq!(m.mean_batch_size(), 8.0);
        assert_eq!(m.throughput_rps(), 50.0);
        assert!(m.report().contains("mean_batch=8.00"));
        assert!(m.report().contains("batch compute"));
    }

    #[test]
    fn fault_counters_appear_in_report_only_when_nonzero() {
        let mut m = ServerMetrics::default();
        m.requests = 10;
        assert!(!m.report().contains("faults:"));
        m.errors = 2;
        m.shed = 1;
        m.expired = 1;
        assert!(m.report().contains("faults: errors=2 shed=1 expired=1"));
    }

    #[test]
    fn fallback_counter_appears_in_report_only_when_nonzero() {
        let mut m = ServerMetrics::default();
        m.requests = 10;
        assert!(!m.report().contains("routing:"));
        m.fallbacks = 3;
        assert!(m.report().contains("routing: fallbacks=3"));
    }

    #[test]
    fn admin_counters_appear_in_report_only_when_nonzero() {
        let mut m = ServerMetrics::default();
        m.requests = 10;
        assert!(!m.report().contains("admin:"));
        m.reloads = 2;
        m.swap_rollbacks = 1;
        assert!(m
            .report()
            .contains("admin: reloads=2 reload_failures=0 swaps=0 swap_rollbacks=1"));
    }

    #[test]
    fn lane_counters_appear_in_report_only_when_multi_lane_or_overlapped() {
        let mut m = ServerMetrics::default();
        m.requests = 10;
        m.lane_batches = vec![5];
        assert!(!m.report().contains("lanes:"));
        m.lane_batches = vec![3, 2];
        m.overlapped = 4;
        assert!(m.report().contains("lanes: n=2 batches=[3, 2] overlapped=4"));
    }

    #[test]
    fn queue_and_batch_summaries_track_recorded_latencies() {
        let mut m = ServerMetrics::default();
        for i in 1..=100u64 {
            m.queue_latency.record(Duration::from_micros(i * 10));
            m.batch_latency.record(Duration::from_micros(i * 100));
        }
        assert!(m.queue_wait_p50() <= m.queue_wait_p99());
        assert!(m.batch_latency_p50() <= m.batch_latency_p99());
        // batches are ~10x slower than queue waits here; the bucketed
        // quantiles must preserve that separation
        assert!(m.batch_latency_p50() > m.queue_wait_p50());
    }
}
