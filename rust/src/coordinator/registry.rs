//! Crash-safe, integrity-verified on-disk registry of compressed variants.
//!
//! Every (method, ratio, calib_source) cell the sweep or the compression
//! pipeline produces is a servable model; the registry is what makes those
//! variants **durable** (they survive the process), **tamper-evident**
//! (every blob is SHA-256-pinned by a manifest), and **shareable** (a fleet
//! loads compressed checkpoints instead of recompressing).
//!
//! ## Layout
//!
//! ```text
//! <root>/
//!   .tmp/                 in-flight stagings (quarantined on open)
//!   .quarantine/          partial or corrupt entries, kept for forensics
//!   <name>/
//!     v1/
//!       weights.npz       stored-zip of NPY tensors (deterministic bytes)
//!       manifest.json     name/version/method/ratio/calib_source/arch +
//!                         sha256 per tensor blob
//!     v2/ …
//! ```
//!
//! ## Crash safety
//!
//! [`Registry::add`] stages the complete entry under `.tmp/` — weights
//! first, manifest **last**, both fsynced — then publishes with one atomic
//! directory rename. A crash at any point leaves either nothing or a
//! partial staging in `.tmp/`, never a partially-visible published entry;
//! [`Registry::open`] sweeps `.tmp/` leftovers (and published dirs missing
//! their manifest) into `.quarantine/` — detected and preserved, never
//! silently deleted. Each step crosses a named
//! [`crate::util::fault::io_gate`], so the chaos suite (`tests/registry.rs`)
//! can kill the writer at *every* fsync/rename point and assert the
//! registry always reopens clean with the prior version intact.
//!
//! ## Integrity
//!
//! [`Registry::load`] re-hashes every blob against the manifest (on top of
//! the zip layer's CRC-32). Any mismatch — or any parse failure — is a
//! typed [`RegistryError::Corrupt`]: the entry is quarantined and the
//! caller can fall back to [`Registry::load_latest_good`], which walks
//! versions newest-first. Serving keeps running on the incumbent variant
//! throughout (the hot-swap path in `coordinator::server` only commits a
//! fully verified, probe-scored model).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::io::npz;
use crate::model::ModelWeights;
use crate::util::fault::io_gate;
use crate::util::json::Json;

/// Typed registry failures (wrapped in `anyhow` and recognized by
/// downcast, like `InjectedFault`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// An entry failed integrity verification (hash mismatch, unreadable
    /// archive, manifest/weights disagreement). The entry has been moved
    /// to `.quarantine/`.
    Corrupt {
        /// Variant name.
        name: String,
        /// Version that failed.
        version: u64,
        /// What the verifier found.
        reason: String,
    },
    /// No (good) version of the variant exists.
    NotFound {
        /// Variant name.
        name: String,
    },
    /// A name that cannot be a registry entry (path separators, leading
    /// dots, empty).
    BadName {
        /// The offending name.
        name: String,
    },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::Corrupt { name, version, reason } => {
                write!(f, "registry entry {name}@v{version} is corrupt (quarantined): {reason}")
            }
            RegistryError::NotFound { name } => {
                write!(f, "no good version of {name:?} in the registry")
            }
            RegistryError::BadName { name } => {
                write!(
                    f,
                    "invalid registry name {name:?} (want [A-Za-z0-9._-]+, no leading dot)"
                )
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// The manifest-side description of one stored variant (the nanoserde-
/// style `name/version/arch/sha256` idiom).
#[derive(Debug, Clone)]
pub struct VariantMeta {
    /// Variant name (directory component).
    pub name: String,
    /// Monotonic version within the name.
    pub version: u64,
    /// Compression method that produced it (e.g. `mergemoe`, `average`).
    pub method: String,
    /// Compression ratio (params_after / params_before).
    pub ratio: f64,
    /// Calibration source label (Table-4 axis).
    pub calib_source: String,
    /// Architecture of the stored model — enough to reload it without the
    /// artifacts manifest.
    pub arch: ModelConfig,
    /// SHA-256 (hex) of every tensor blob, keyed by tensor name.
    pub blobs: BTreeMap<String, String>,
}

impl VariantMeta {
    /// `name@vN`, the label serving surfaces on `/healthz`.
    pub fn label(&self) -> String {
        format!("{}@v{}", self.name, self.version)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("mergemoe-registry/1")),
            ("name", Json::str(&self.name)),
            ("version", Json::num(self.version as f64)),
            ("method", Json::str(&self.method)),
            ("ratio", Json::num(self.ratio)),
            ("calib_source", Json::str(&self.calib_source)),
            ("arch", self.arch.to_json()),
            (
                "blobs",
                Json::Obj(
                    self.blobs.iter().map(|(k, v)| (k.clone(), Json::str(v))).collect(),
                ),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<VariantMeta> {
        let format = j.get("format")?.as_str()?;
        if format != "mergemoe-registry/1" {
            bail!("unknown manifest format {format:?}");
        }
        let name = j.get("name")?.as_str()?.to_string();
        let arch = ModelConfig::from_json(j.get("arch")?.get("name")?.as_str()?, j.get("arch")?)?;
        let mut blobs = BTreeMap::new();
        for (k, v) in j.get("blobs")?.as_obj()? {
            blobs.insert(k.clone(), v.as_str()?.to_string());
        }
        Ok(VariantMeta {
            name,
            version: j.get("version")?.as_usize()? as u64,
            method: j.get("method")?.as_str()?.to_string(),
            ratio: j.get("ratio")?.as_f64()?,
            calib_source: j.get("calib_source")?.as_str()?.to_string(),
            arch,
            blobs,
        })
    }
}

/// Descriptive fields for [`Registry::add`] (everything the manifest
/// records beyond what the model itself carries).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    /// Compression method label.
    pub method: String,
    /// Compression ratio.
    pub ratio: f64,
    /// Calibration source label.
    pub calib_source: String,
}

/// One entry of [`Registry::verify`]'s report.
#[derive(Debug, Clone)]
pub struct VerifyEntry {
    /// `name@vN`.
    pub label: String,
    /// `None` = verified clean; `Some(reason)` = failed.
    pub problem: Option<String>,
}

/// Unique-suffix source for staging directories (several writers — or one
/// writer retrying after injected crashes — must never collide in `.tmp/`).
static STAGE_SEQ: AtomicU64 = AtomicU64::new(0);

/// A versioned on-disk variant registry rooted at one directory.
#[derive(Debug)]
pub struct Registry {
    root: PathBuf,
}

impl Registry {
    /// Open (creating if needed) a registry at `root`. Sweeps crash
    /// leftovers — `.tmp/` stagings and published version dirs with no
    /// manifest — into `.quarantine/`.
    pub fn open(root: &Path) -> Result<Registry> {
        std::fs::create_dir_all(root.join(".tmp"))
            .with_context(|| format!("creating {}", root.join(".tmp").display()))?;
        std::fs::create_dir_all(root.join(".quarantine"))
            .with_context(|| format!("creating {}", root.join(".quarantine").display()))?;
        let reg = Registry { root: root.to_path_buf() };
        // a crash mid-add leaves its staging in .tmp — quarantine, never
        // delete (the operator may want the partial bytes)
        for entry in std::fs::read_dir(root.join(".tmp"))? {
            let path = entry?.path();
            reg.quarantine(&path, "unfinished staging")?;
        }
        // a published dir without a manifest cannot happen via the atomic
        // publish path; treat any found (tampering, partial restore) the
        // same way
        for (name, version, dir) in reg.scan()? {
            if !dir.join("manifest.json").is_file() {
                reg.quarantine(&dir, &format!("{name}@v{version} has no manifest"))?;
            }
        }
        Ok(reg)
    }

    /// The registry root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Persist `model` as the next version of `name`. Crash-safe: stages
    /// under `.tmp/` (weights, then manifest, both fsynced), then
    /// publishes with one atomic rename. Returns the recorded manifest.
    pub fn add(&self, name: &str, model: &ModelWeights, spec: &VariantSpec) -> Result<VariantMeta> {
        check_name(name)?;
        let stage = self.root.join(".tmp").join(format!(
            "{name}-{}-{}",
            std::process::id(),
            STAGE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&stage)
            .with_context(|| format!("creating staging dir {}", stage.display()))?;

        // -- stage: weights first (write_npz_with_digests fsyncs) --
        io_gate("registry.weights.write")?;
        let arrays = model.to_arrays()?;
        let blobs = npz::write_npz_with_digests(&stage.join("weights.npz"), &arrays)?;
        io_gate("registry.weights.synced")?;

        // -- stage: manifest last, so its presence certifies completeness --
        let version = self.next_version(name)?;
        let meta = VariantMeta {
            name: name.to_string(),
            version,
            method: spec.method.clone(),
            ratio: spec.ratio,
            calib_source: spec.calib_source.clone(),
            arch: model.cfg.clone(),
            blobs,
        };
        io_gate("registry.manifest.write")?;
        write_file_synced(&stage.join("manifest.json"), meta.to_json().to_string().as_bytes())?;
        io_gate("registry.manifest.synced")?;

        // -- publish: one atomic rename --
        let name_dir = self.root.join(name);
        std::fs::create_dir_all(&name_dir)
            .with_context(|| format!("creating {}", name_dir.display()))?;
        io_gate("registry.publish.rename")?;
        let dst = name_dir.join(format!("v{version}"));
        std::fs::rename(&stage, &dst)
            .with_context(|| format!("publishing {} -> {}", stage.display(), dst.display()))?;
        // make the publish itself durable (the rename is atomic either
        // way; the dir fsync pins it across power loss)
        io_gate("registry.publish.dirsync")?;
        sync_dir(&name_dir);
        crate::info!(
            "registry: published {} (method={}, ratio={:.3}, calib={})",
            meta.label(),
            meta.method,
            meta.ratio,
            meta.calib_source
        );
        Ok(meta)
    }

    /// Every published version's manifest, newest first within each name
    /// (best-effort: entries whose manifest will not parse are reported as
    /// corrupt by [`Registry::verify`], and skipped here).
    pub fn list(&self) -> Result<Vec<VariantMeta>> {
        let mut out = Vec::new();
        for (_, _, dir) in self.scan()? {
            if let Ok(j) = Json::parse_file(&dir.join("manifest.json")) {
                if let Ok(meta) = VariantMeta::from_json(&j) {
                    out.push(meta);
                }
            }
        }
        out.sort_by(|a, b| a.name.cmp(&b.name).then(b.version.cmp(&a.version)));
        Ok(out)
    }

    /// Latest published version number of `name`, if any.
    pub fn latest(&self, name: &str) -> Result<Option<u64>> {
        check_name(name)?;
        Ok(self
            .scan()?
            .into_iter()
            .filter(|(n, _, _)| n == name)
            .map(|(_, v, _)| v)
            .max())
    }

    /// True iff at least one version of `name` is published (loadable or
    /// not — integrity is [`Registry::load`]'s job). Lets callers
    /// distinguish "never registered" (expected, silent) from "registered
    /// but unloadable" (worth a warning) without attempting a full load.
    pub fn contains(&self, name: &str) -> bool {
        matches!(self.latest(name), Ok(Some(_)))
    }

    /// Load and verify one specific version. On any integrity failure the
    /// entry is quarantined and a typed [`RegistryError::Corrupt`] is
    /// returned (callers fall back via [`Registry::load_latest_good`]).
    pub fn load(&self, name: &str, version: u64) -> Result<(ModelWeights, VariantMeta)> {
        check_name(name)?;
        let dir = self.root.join(name).join(format!("v{version}"));
        if !dir.is_dir() {
            bail!(RegistryError::NotFound { name: name.to_string() });
        }
        match self.load_dir(&dir) {
            Ok(ok) => Ok(ok),
            Err(reason) => {
                let reason = format!("{reason:#}");
                crate::warnlog!("registry: {name}@v{version} corrupt ({reason}); quarantining");
                self.quarantine(&dir, &reason)?;
                bail!(RegistryError::Corrupt { name: name.to_string(), version, reason })
            }
        }
    }

    /// Load the newest version of `name` that passes verification,
    /// quarantining every corrupt newer one along the way. Typed
    /// [`RegistryError::NotFound`] when nothing loadable remains.
    pub fn load_latest_good(&self, name: &str) -> Result<(ModelWeights, VariantMeta)> {
        check_name(name)?;
        loop {
            let Some(version) = self.latest(name)? else {
                bail!(RegistryError::NotFound { name: name.to_string() });
            };
            match self.load(name, version) {
                Ok(ok) => return Ok(ok),
                Err(e) if e.downcast_ref::<RegistryError>().is_some_and(
                    |r| matches!(r, RegistryError::Corrupt { .. }),
                ) =>
                {
                    // that version is now quarantined; scan again for the
                    // next-newest
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Re-hash every published entry against its manifest. Report-only:
    /// nothing is quarantined (that is [`Registry::load`]'s job), so an
    /// operator can inspect a suspect registry without mutating it.
    pub fn verify(&self) -> Result<Vec<VerifyEntry>> {
        let mut out = Vec::new();
        for (name, version, dir) in self.scan()? {
            let label = format!("{name}@v{version}");
            let problem = match self.load_dir(&dir) {
                Ok(_) => None,
                Err(e) => Some(format!("{e:#}")),
            };
            out.push(VerifyEntry { label, problem });
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // internals
    // ------------------------------------------------------------------

    /// Parse + verify one version dir (no quarantining here).
    fn load_dir(&self, dir: &Path) -> Result<(ModelWeights, VariantMeta)> {
        let meta = VariantMeta::from_json(&Json::parse_file(&dir.join("manifest.json"))?)?;
        let (arrays, digests) = npz::read_npz_with_digests(&dir.join("weights.npz"))?;
        // exact two-way match: a missing blob and an extra blob are both
        // manifest/weights disagreements
        if digests != meta.blobs {
            let detail = diff_digests(&meta.blobs, &digests);
            bail!("blob digests disagree with manifest: {detail}");
        }
        let mut tensors = BTreeMap::new();
        for (k, v) in arrays {
            tensors.insert(k.clone(), v.to_tensor().with_context(|| k)?);
        }
        let model = ModelWeights::from_arrays(tensors, &meta.arch)?;
        Ok((model, meta))
    }

    /// All published `(name, version, dir)` triples.
    fn scan(&self) -> Result<Vec<(String, u64, PathBuf)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.root)
            .with_context(|| format!("reading registry root {}", self.root.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with('.') || !entry.path().is_dir() {
                continue;
            }
            for ventry in std::fs::read_dir(entry.path())? {
                let vdir = ventry?.path();
                let vname = vdir.file_name().unwrap_or_default().to_string_lossy().into_owned();
                if let Some(v) = vname.strip_prefix('v').and_then(|s| s.parse::<u64>().ok()) {
                    if vdir.is_dir() {
                        out.push((name.clone(), v, vdir));
                    }
                }
            }
        }
        Ok(out)
    }

    fn next_version(&self, name: &str) -> Result<u64> {
        Ok(self.latest(name)?.map_or(1, |v| v + 1))
    }

    /// Move `path` into `.quarantine/` under a unique name. Never deletes.
    fn quarantine(&self, path: &Path, why: &str) -> Result<()> {
        let base = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "entry".into());
        // parent dir name disambiguates name/vN collisions across variants
        let parent = path
            .parent()
            .and_then(|p| p.file_name())
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let mut n = 0u64;
        loop {
            let dst = self.root.join(".quarantine").join(if n == 0 {
                format!("{parent}-{base}")
            } else {
                format!("{parent}-{base}.{n}")
            });
            if dst.exists() {
                n += 1;
                continue;
            }
            std::fs::rename(path, &dst).with_context(|| {
                format!("quarantining {} -> {}", path.display(), dst.display())
            })?;
            crate::warnlog!("registry: quarantined {} ({why})", dst.display());
            return Ok(());
        }
    }
}

/// Registry names become path components; reject anything else.
fn check_name(name: &str) -> Result<()> {
    let ok = !name.is_empty()
        && !name.starts_with('.')
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-');
    if !ok {
        bail!(RegistryError::BadName { name: name.to_string() });
    }
    Ok(())
}

/// Human-readable first difference between manifest and on-disk digests.
fn diff_digests(want: &BTreeMap<String, String>, got: &BTreeMap<String, String>) -> String {
    for (k, w) in want {
        match got.get(k) {
            None => return format!("blob {k:?} missing from weights"),
            Some(g) if g != w => return format!("blob {k:?} hash mismatch"),
            _ => {}
        }
    }
    for k in got.keys() {
        if !want.contains_key(k) {
            return format!("unexpected blob {k:?} in weights");
        }
    }
    "identical (internal error)".into()
}

/// Write + fsync a small file (the manifest). The containing directory is
/// still unpublished staging, so per-file atomicity is not needed — only
/// durability before the publish rename.
fn write_file_synced(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(bytes)?;
    f.sync_all().with_context(|| format!("fsyncing {}", path.display()))?;
    Ok(())
}

/// Best-effort directory fsync (pins a rename across power loss; opening
/// a directory read-only works on the platforms we serve from, and a
/// failure here must not fail an already-atomic publish).
fn sync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("mergemoe_registry_unit")
            .join(format!("{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn spec() -> VariantSpec {
        VariantSpec { method: "mergemoe".into(), ratio: 0.7, calib_source: "mixture".into() }
    }

    #[test]
    fn add_load_roundtrip_and_versioning() {
        let root = tmp_root("rt");
        let reg = Registry::open(&root).unwrap();
        let m = tiny_model(4, 2, false, 11);
        let meta1 = reg.add("tiny", &m, &spec()).unwrap();
        assert_eq!(meta1.version, 1);
        let meta2 = reg.add("tiny", &m, &spec()).unwrap();
        assert_eq!(meta2.version, 2);
        assert_eq!(reg.latest("tiny").unwrap(), Some(2));
        let (back, meta) = reg.load("tiny", 1).unwrap();
        assert_eq!(meta.label(), "tiny@v1");
        assert_eq!(meta.arch.n_experts, 4);
        assert_eq!(back.layers[0].moe.experts[0].wg.data(), m.layers[0].moe.experts[0].wg.data());
        let listed = reg.list().unwrap();
        assert_eq!(listed.len(), 2);
        assert_eq!(listed[0].version, 2, "newest first");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn bad_names_are_typed_errors() {
        let root = tmp_root("names");
        let reg = Registry::open(&root).unwrap();
        let m = tiny_model(4, 2, false, 12);
        for bad in ["", "..", "a/b", ".hidden", "x y"] {
            let err = reg.add(bad, &m, &spec()).unwrap_err();
            assert!(
                matches!(err.downcast_ref::<RegistryError>(), Some(RegistryError::BadName { .. })),
                "{bad:?}: {err:#}"
            );
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn missing_variant_is_notfound() {
        let root = tmp_root("nf");
        let reg = Registry::open(&root).unwrap();
        let err = reg.load_latest_good("ghost").unwrap_err();
        assert!(matches!(
            err.downcast_ref::<RegistryError>(),
            Some(RegistryError::NotFound { .. })
        ));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn merged_variant_roundtrips_through_registry() {
        use crate::coordinator::pipeline::{compress, CompressSpec};
        use crate::merge::{Algorithm, NativeGram};
        let root = tmp_root("merged");
        let reg = Registry::open(&root).unwrap();
        let m = tiny_model(8, 2, false, 13);
        let mut cspec = CompressSpec::new(vec![0, 1], 4, Algorithm::MergeMoe);
        cspec.n_calib_seqs = 4;
        let (compressed, report) = compress(&m, &cspec, &mut NativeGram).unwrap();
        let vspec = VariantSpec {
            method: "mergemoe".into(),
            ratio: report.compression_ratio(),
            calib_source: "mixture".into(),
        };
        reg.add("tiny-m4", &compressed, &vspec).unwrap();
        let (back, meta) = reg.load_latest_good("tiny-m4").unwrap();
        assert!(meta.ratio < 1.0);
        assert_eq!(back.layers[0].moe.n_experts(), 4);
        assert!(back.layers[0].moe.map.is_some(), "routing map survives the registry");
        std::fs::remove_dir_all(&root).ok();
    }
}
