//! The compression pipeline — the coordinator-side realization of the
//! paper's algorithm (§4 + Appendix B):
//!
//! 1. sample a calibration batch (task corpus lines, Table-4 selectable);
//! 2. run the uncompressed model once, capturing per-layer MoE inputs X̂
//!    and usage frequencies;
//! 3. traverse the selected layers **back to front** (merging layer ℓ does
//!    not disturb the captured activations of layers < ℓ);
//! 4. per layer: build the merge plan (clustering + Theorem-1 weights) and
//!    hand it to the chosen [`Algorithm`];
//! 5. report per-layer output error, timing and the resulting model size.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::calib::{self, CalibData};
use crate::eval::tasks::Task;
use crate::merge::{self, Algorithm, GramBackend, MergePlan};
use crate::model::workspace::Workspace;
use crate::model::ModelWeights;

/// What to compress and how.
#[derive(Debug, Clone)]
pub struct CompressSpec {
    /// Layer indices to merge (any order; the pipeline sorts descending).
    pub layers: Vec<usize>,
    /// Target expert count per merged layer.
    pub m: usize,
    pub algorithm: Algorithm,
    /// Calibration sequences (paper's "number of input samples").
    pub n_calib_seqs: usize,
    /// Restrict calibration data to these tasks (Table 4); None = mixture.
    pub calib_tasks: Option<Vec<Task>>,
    pub seed: u64,
    /// Relative ridge of the least-squares solve.
    pub ridge: f64,
    /// Cap the number of calibration *tokens* fed to the least-squares solve
    /// (Fig. 4's sample-size axis; the failure threshold sits near d_ff where
    /// the Gram matrix loses rank). `None` = use the full capture.
    pub max_calib_tokens: Option<usize>,
}

impl CompressSpec {
    pub fn new(layers: Vec<usize>, m: usize, algorithm: Algorithm) -> CompressSpec {
        CompressSpec {
            layers,
            m,
            algorithm,
            n_calib_seqs: 64,
            calib_tasks: None,
            seed: 0xC0FFEE,
            ridge: 1e-6,
            max_calib_tokens: None,
        }
    }
}

/// Per-layer merge outcome.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: usize,
    pub n_before: usize,
    pub n_after: usize,
    /// ‖MoE'(X̂) − MoE(X̂)‖_F / ‖MoE(X̂)‖_F on the calibration batch.
    pub output_rel_err: f64,
    pub merge_seconds: f64,
}

/// Whole-pipeline outcome.
#[derive(Debug, Clone)]
pub struct CompressReport {
    pub algorithm: Algorithm,
    pub layers: Vec<LayerReport>,
    pub params_before: usize,
    pub params_after: usize,
    pub calib_seconds: f64,
    pub merge_seconds: f64,
    pub n_calib_tokens: usize,
}

impl CompressReport {
    pub fn compression_ratio(&self) -> f64 {
        self.params_after as f64 / self.params_before as f64
    }
}

/// Run the pipeline. Returns the compressed model and the report.
/// `gram` is the Gram backend for the MergeMoE solve (native or PJRT/pallas).
pub fn compress(
    model: &ModelWeights,
    spec: &CompressSpec,
    gram: &mut dyn GramBackend,
) -> Result<(ModelWeights, CompressReport)> {
    for &l in &spec.layers {
        if l >= model.layers.len() {
            bail!("layer {l} out of range ({} layers)", model.layers.len());
        }
        if model.layers[l].moe.map.is_some() {
            bail!("layer {l} is already merged");
        }
    }
    if spec.algorithm != Algorithm::Oracle && spec.m > model.cfg.n_experts {
        bail!("target {} > {} experts", spec.m, model.cfg.n_experts);
    }
    // (1)+(2) calibration capture on the uncompressed model
    let t0 = Instant::now();
    let seq_len = 64; // = configs.SEQ_LEN; manifest-checked on the PJRT path
    let tokens = calib::sample_sequences(
        spec.calib_tasks.as_deref(),
        spec.n_calib_seqs,
        seq_len,
        spec.seed,
    );
    let calib: CalibData = calib::capture(model, &tokens, spec.n_calib_seqs, seq_len)?;
    let calib_seconds = t0.elapsed().as_secs_f64();

    // (3)–(5) merge back to front. One workspace serves every layer's
    // MergeMoE solve: the Gram panels reach their high-water size on the
    // first layer and are reused for the rest (workspaces are per-thread;
    // the pipeline is the only owner of this one).
    let mut ws = Workspace::new();
    let mut out = model.clone();
    let mut layer_reports = Vec::new();
    let mut order = spec.layers.clone();
    order.sort_unstable_by(|a, b| b.cmp(a));
    order.dedup();
    let t1 = Instant::now();
    for &li in &order {
        let lt = Instant::now();
        let moe = &model.layers[li].moe;
        let lc = &calib.layers[li];
        let plan = if spec.algorithm == Algorithm::Oracle {
            merge::clustering::build_plan(moe, &lc.stats, spec.m)?
        } else if spec.m == moe.n_experts() {
            MergePlan::identity(spec.m)
        } else {
            merge::clustering::build_plan(moe, &lc.stats, spec.m)?
        };
        let x = match spec.max_calib_tokens {
            Some(cap) if cap < lc.x.shape()[0] => lc.x.rows_slice(0, cap.max(1)),
            _ => lc.x.clone(),
        };
        let merged = merge::merge_layer(
            spec.algorithm,
            moe,
            &plan,
            Some(&x),
            gram,
            spec.ridge,
            &mut ws,
        )?;
        let err = merge::layer_output_error(moe, &merged, &lc.x)?;
        layer_reports.push(LayerReport {
            layer: li,
            n_before: moe.n_experts(),
            n_after: merged.n_experts(),
            output_rel_err: err,
            merge_seconds: lt.elapsed().as_secs_f64(),
        });
        out.layers[li].moe = merged;
    }
    out.touch(); // new weight identity for runtime caches
    let report = CompressReport {
        algorithm: spec.algorithm,
        layers: layer_reports,
        params_before: model.n_params(),
        params_after: out.n_params(),
        calib_seconds,
        merge_seconds: t1.elapsed().as_secs_f64(),
        n_calib_tokens: calib.n_tokens(),
    };
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::NativeGram;
    use crate::model::testutil::tiny_model;

    #[test]
    fn pipeline_compresses_selected_layers() {
        let model = tiny_model(8, 2, true, 90);
        let mut spec = CompressSpec::new(vec![1], 4, Algorithm::MergeMoe);
        spec.n_calib_seqs = 8;
        let (out, report) = compress(&model, &spec, &mut NativeGram).unwrap();
        assert_eq!(out.layers[0].moe.n_experts(), 8); // untouched
        assert_eq!(out.layers[1].moe.n_experts(), 4); // merged
        assert!(out.layers[1].moe.map.is_some());
        assert!(report.params_after < report.params_before);
        assert_eq!(report.layers.len(), 1);
        assert!(report.layers[0].output_rel_err.is_finite());
        // shared expert untouched byte-for-byte
        assert_eq!(
            out.layers[1].moe.shared.as_ref().unwrap().wg.data(),
            model.layers[1].moe.shared.as_ref().unwrap().wg.data()
        );
    }

    #[test]
    fn oracle_keeps_param_count() {
        let model = tiny_model(8, 2, false, 91);
        let mut spec = CompressSpec::new(vec![0, 1], 4, Algorithm::Oracle);
        spec.n_calib_seqs = 4;
        let (_, report) = compress(&model, &spec, &mut NativeGram).unwrap();
        assert_eq!(report.params_before, report.params_after);
    }

    #[test]
    fn rejects_double_merge_and_bad_layers() {
        let model = tiny_model(8, 2, false, 92);
        let mut spec = CompressSpec::new(vec![0], 4, Algorithm::MSmoe);
        spec.n_calib_seqs = 2;
        let (compressed, _) = compress(&model, &spec, &mut NativeGram).unwrap();
        assert!(compress(&compressed, &spec, &mut NativeGram).is_err());
        let spec2 = CompressSpec::new(vec![9], 4, Algorithm::MSmoe);
        assert!(compress(&model, &spec2, &mut NativeGram).is_err());
    }

    #[test]
    fn per_algorithm_error_ordering_holds_on_average() {
        // The paper's headline: MergeMoE <= M-SMoE on calibration error.
        let model = tiny_model(8, 2, false, 93);
        let mk = |alg| {
            let mut spec = CompressSpec::new(vec![0, 1], 4, alg);
            spec.n_calib_seqs = 16;
            let (_, r) = compress(&model, &spec, &mut NativeGram).unwrap();
            r.layers.iter().map(|l| l.output_rel_err).sum::<f64>()
        };
        let e_mm = mk(Algorithm::MergeMoe);
        let e_ms = mk(Algorithm::MSmoe);
        assert!(e_mm <= e_ms + 1e-9, "mergemoe {e_mm} msmoe {e_ms}");
    }
}
