//! The compression pipeline — the coordinator-side realization of the
//! paper's algorithm (§4 + Appendix B):
//!
//! 1. sample a calibration batch (task corpus lines, Table-4 selectable);
//! 2. run the uncompressed model once, capturing per-layer MoE inputs X̂
//!    and usage frequencies;
//! 3. traverse the selected layers **back to front** (merging layer ℓ does
//!    not disturb the captured activations of layers < ℓ);
//! 4. per layer: build the merge plan (clustering + Theorem-1 weights) and
//!    hand it to the chosen [`Algorithm`];
//! 5. report per-layer output error, timing and the resulting model size.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::calib::{self, CalibData, CalibSource};
use crate::eval::tasks::Task;
use crate::merge::{self, Algorithm, GramBackend, MergePlan};
use crate::model::workspace::Workspace;
use crate::model::ModelWeights;

/// What to compress and how.
#[derive(Debug, Clone)]
pub struct CompressSpec {
    /// Layer indices to merge (any order; the pipeline sorts descending).
    pub layers: Vec<usize>,
    /// Target expert count per merged layer.
    pub m: usize,
    pub algorithm: Algorithm,
    /// Calibration sequences (paper's "number of input samples").
    pub n_calib_seqs: usize,
    /// Restrict calibration data to these tasks (Table 4); None = mixture.
    pub calib_tasks: Option<Vec<Task>>,
    pub seed: u64,
    /// Relative ridge of the least-squares solve.
    pub ridge: f64,
    /// Cap the number of calibration *tokens* fed to the least-squares solve
    /// (Fig. 4's sample-size axis; the failure threshold sits near d_ff where
    /// the Gram matrix loses rank). `None` = use the full capture.
    pub max_calib_tokens: Option<usize>,
}

impl CompressSpec {
    pub fn new(layers: Vec<usize>, m: usize, algorithm: Algorithm) -> CompressSpec {
        CompressSpec {
            layers,
            m,
            algorithm,
            n_calib_seqs: 64,
            calib_tasks: None,
            seed: 0xC0FFEE,
            ridge: 1e-6,
            max_calib_tokens: None,
        }
    }
}

/// Per-layer merge outcome.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: usize,
    pub n_before: usize,
    pub n_after: usize,
    /// ‖MoE'(X̂) − MoE(X̂)‖_F / ‖MoE(X̂)‖_F on the calibration batch.
    pub output_rel_err: f64,
    pub merge_seconds: f64,
}

/// Whole-pipeline outcome.
#[derive(Debug, Clone)]
pub struct CompressReport {
    pub algorithm: Algorithm,
    pub layers: Vec<LayerReport>,
    pub params_before: usize,
    pub params_after: usize,
    pub calib_seconds: f64,
    pub merge_seconds: f64,
    pub n_calib_tokens: usize,
}

impl CompressReport {
    pub fn compression_ratio(&self) -> f64 {
        self.params_after as f64 / self.params_before as f64
    }
}

/// Steps (1)+(2) of the pipeline: sample a calibration batch and capture
/// per-layer activations + routing statistics on the uncompressed model.
/// Because merging runs back to front, **one capture of the original model
/// serves every merge built from it** — the evaluation sweep captures once
/// and reuses the data across all of its (method, ratio) variants through
/// [`compress_with_calib`].
pub fn capture_calibration(
    model: &ModelWeights,
    n_calib_seqs: usize,
    calib_tasks: Option<&[Task]>,
    seed: u64,
) -> Result<CalibData> {
    let seq_len = 64; // = configs.SEQ_LEN; manifest-checked on the PJRT path
    let tokens = calib::sample_sequences(calib_tasks, n_calib_seqs, seq_len, seed);
    calib::capture(model, &tokens, n_calib_seqs, seq_len)
}

/// [`capture_calibration`] keyed by a named [`CalibSource`] — the
/// evaluation sweep's per-source capture entry point. The sweep's fourth
/// axis runs on this: one capture per source, reused across every
/// (method, ratio) variant built from that source, exactly as the single
/// capture served the whole grid before the axis existed.
pub fn capture_calibration_source(
    model: &ModelWeights,
    n_calib_seqs: usize,
    source: &CalibSource,
    seed: u64,
) -> Result<CalibData> {
    capture_calibration(model, n_calib_seqs, source.tasks.as_deref(), seed)
}

/// Spec checks shared by [`compress`] (before the expensive capture) and
/// [`compress_with_calib`] (callers may pass hand-built specs directly).
fn validate_spec(model: &ModelWeights, spec: &CompressSpec) -> Result<()> {
    for &l in &spec.layers {
        if l >= model.layers.len() {
            bail!("layer {l} out of range ({} layers)", model.layers.len());
        }
        if model.layers[l].moe.map.is_some() {
            bail!("layer {l} is already merged");
        }
    }
    if spec.algorithm != Algorithm::Oracle && spec.m > model.cfg.n_experts {
        bail!("target {} > {} experts", spec.m, model.cfg.n_experts);
    }
    Ok(())
}

/// Run the pipeline. Returns the compressed model and the report.
/// `gram` is the Gram backend for the MergeMoE solve (native or PJRT/pallas).
pub fn compress(
    model: &ModelWeights,
    spec: &CompressSpec,
    gram: &mut dyn GramBackend,
) -> Result<(ModelWeights, CompressReport)> {
    validate_spec(model, spec)?; // fail fast, before the capture
    let t0 = Instant::now();
    let calib = capture_calibration(
        model,
        spec.n_calib_seqs,
        spec.calib_tasks.as_deref(),
        spec.seed,
    )?;
    let calib_seconds = t0.elapsed().as_secs_f64();
    let mut ws = Workspace::new();
    let (out, mut report) = compress_with_calib(model, spec, gram, &calib, &mut ws)?;
    report.calib_seconds = calib_seconds;
    Ok((out, report))
}

/// Steps (3)–(5) against a pre-captured calibration set: merge back to
/// front and report. `calib` must come from [`capture_calibration`] (or
/// [`calib::capture`]) on *this* model; `ws` supplies the MergeMoE
/// Gram-panel scratch — callers compressing several variants (the sweep)
/// pass one workspace so the panels are reused throughout.
pub fn compress_with_calib(
    model: &ModelWeights,
    spec: &CompressSpec,
    gram: &mut dyn GramBackend,
    calib: &CalibData,
    ws: &mut Workspace,
) -> Result<(ModelWeights, CompressReport)> {
    validate_spec(model, spec)?;
    for &l in &spec.layers {
        if l >= calib.layers.len() {
            bail!("calibration capture has {} layers, need layer {l}", calib.layers.len());
        }
    }
    let mut out = model.clone();
    let mut layer_reports = Vec::new();
    let mut order = spec.layers.clone();
    order.sort_unstable_by(|a, b| b.cmp(a));
    order.dedup();
    let t1 = Instant::now();
    for &li in &order {
        let lt = Instant::now();
        let moe = &model.layers[li].moe;
        let lc = &calib.layers[li];
        let plan = if spec.algorithm == Algorithm::Oracle {
            merge::clustering::build_plan(moe, &lc.stats, spec.m)?
        } else if spec.m == moe.n_experts() {
            MergePlan::identity(spec.m)
        } else {
            merge::clustering::build_plan(moe, &lc.stats, spec.m)?
        };
        let x = match spec.max_calib_tokens {
            Some(cap) if cap < lc.x.shape()[0] => lc.x.rows_slice(0, cap.max(1)),
            _ => lc.x.clone(),
        };
        let merged = merge::merge_layer(
            spec.algorithm,
            moe,
            &plan,
            Some(&x),
            gram,
            spec.ridge,
            ws,
        )?;
        let err = merge::layer_output_error(moe, &merged, &lc.x)?;
        layer_reports.push(LayerReport {
            layer: li,
            n_before: moe.n_experts(),
            n_after: merged.n_experts(),
            output_rel_err: err,
            merge_seconds: lt.elapsed().as_secs_f64(),
        });
        out.layers[li].moe = merged;
    }
    out.touch(); // new weight identity for runtime caches
    let report = CompressReport {
        algorithm: spec.algorithm,
        layers: layer_reports,
        params_before: model.n_params(),
        params_after: out.n_params(),
        calib_seconds: 0.0, // the capture is the caller's (amortized) cost
        merge_seconds: t1.elapsed().as_secs_f64(),
        n_calib_tokens: calib.n_tokens(),
    };
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::NativeGram;
    use crate::model::testutil::tiny_model;

    #[test]
    fn pipeline_compresses_selected_layers() {
        let model = tiny_model(8, 2, true, 90);
        let mut spec = CompressSpec::new(vec![1], 4, Algorithm::MergeMoe);
        spec.n_calib_seqs = 8;
        let (out, report) = compress(&model, &spec, &mut NativeGram).unwrap();
        assert_eq!(out.layers[0].moe.n_experts(), 8); // untouched
        assert_eq!(out.layers[1].moe.n_experts(), 4); // merged
        assert!(out.layers[1].moe.map.is_some());
        assert!(report.params_after < report.params_before);
        assert_eq!(report.layers.len(), 1);
        assert!(report.layers[0].output_rel_err.is_finite());
        // shared expert untouched byte-for-byte
        assert_eq!(
            out.layers[1].moe.shared.as_ref().unwrap().wg.data(),
            model.layers[1].moe.shared.as_ref().unwrap().wg.data()
        );
    }

    #[test]
    fn compress_with_shared_calib_matches_compress() {
        // one capture + one workspace reused across variants (the sweep's
        // pattern) must reproduce the capture-per-call wrapper exactly
        let model = tiny_model(8, 2, false, 94);
        let mut spec = CompressSpec::new(vec![0, 1], 4, Algorithm::MergeMoe);
        spec.n_calib_seqs = 8;
        let (want, _) = compress(&model, &spec, &mut NativeGram).unwrap();
        let calib =
            capture_calibration(&model, spec.n_calib_seqs, None, spec.seed).unwrap();
        let mut ws = Workspace::new();
        for alg in [Algorithm::Average, Algorithm::MergeMoe] {
            let mut s2 = spec.clone();
            s2.algorithm = alg;
            let (got, rep) =
                compress_with_calib(&model, &s2, &mut NativeGram, &calib, &mut ws).unwrap();
            assert_eq!(rep.n_calib_tokens, calib.n_tokens());
            if alg == Algorithm::MergeMoe {
                for (a, b) in got.layers.iter().zip(&want.layers) {
                    for (ea, eb) in a.moe.experts.iter().zip(&b.moe.experts) {
                        assert_eq!(ea.wd.data(), eb.wd.data());
                        assert_eq!(ea.wg.data(), eb.wg.data());
                        assert_eq!(ea.wu.data(), eb.wu.data());
                    }
                }
            }
        }
    }

    #[test]
    fn source_keyed_capture_matches_task_filter_capture() {
        let model = tiny_model(4, 2, false, 95);
        let src = CalibSource::single(crate::eval::tasks::Task::Parity);
        let a = capture_calibration_source(&model, 4, &src, 7).unwrap();
        let b = capture_calibration(&model, 4, Some(&[crate::eval::tasks::Task::Parity]), 7)
            .unwrap();
        assert_eq!(a.layers.len(), b.layers.len());
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.x.data(), lb.x.data());
        }
        // and the mixture source matches the None filter
        let m = capture_calibration_source(&model, 4, &CalibSource::mixture(), 7).unwrap();
        let n = capture_calibration(&model, 4, None, 7).unwrap();
        assert_eq!(m.layers[0].x.data(), n.layers[0].x.data());
    }

    #[test]
    fn oracle_keeps_param_count() {
        let model = tiny_model(8, 2, false, 91);
        let mut spec = CompressSpec::new(vec![0, 1], 4, Algorithm::Oracle);
        spec.n_calib_seqs = 4;
        let (_, report) = compress(&model, &spec, &mut NativeGram).unwrap();
        assert_eq!(report.params_before, report.params_after);
    }

    #[test]
    fn rejects_double_merge_and_bad_layers() {
        let model = tiny_model(8, 2, false, 92);
        let mut spec = CompressSpec::new(vec![0], 4, Algorithm::MSmoe);
        spec.n_calib_seqs = 2;
        let (compressed, _) = compress(&model, &spec, &mut NativeGram).unwrap();
        assert!(compress(&compressed, &spec, &mut NativeGram).is_err());
        let spec2 = CompressSpec::new(vec![9], 4, Algorithm::MSmoe);
        assert!(compress(&model, &spec2, &mut NativeGram).is_err());
    }

    #[test]
    fn per_algorithm_error_ordering_holds_on_average() {
        // The paper's headline: MergeMoE <= M-SMoE on calibration error.
        let model = tiny_model(8, 2, false, 93);
        let mk = |alg| {
            let mut spec = CompressSpec::new(vec![0, 1], 4, alg);
            spec.n_calib_seqs = 16;
            let (_, r) = compress(&model, &spec, &mut NativeGram).unwrap();
            r.layers.iter().map(|l| l.output_rel_err).sum::<f64>()
        };
        let e_mm = mk(Algorithm::MergeMoe);
        let e_ms = mk(Algorithm::MSmoe);
        assert!(e_mm <= e_ms + 1e-9, "mergemoe {e_mm} msmoe {e_ms}");
    }
}
