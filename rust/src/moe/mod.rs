//! MoE-specific primitives: routing and usage-frequency statistics.

pub mod routing;
pub mod stats;

pub use stats::UsageStats;
