//! Router math, mirroring the paper's §3.1 formulation (and the L2 model):
//! `probs = softmax(W_r x)`, keep the top-K entries as mixing weights
//! (no renormalization — Eq. 1's `mask_top_K`).

use anyhow::Result;

use crate::tensor::{ops, Tensor};

/// Route each row of `x` (T, d) with router weights (E, d).
/// Returns, per token, the selected `(expert, weight)` pairs in descending
/// weight order (ties broken by lower expert index, matching
/// `jax.lax.top_k`). Convenience wrapper over [`route_tokens_into`]: one
/// `top_k_order` scratch buffer serves every row (the deprecated
/// allocating `ops::top_k` is no longer on any production path).
pub fn route_tokens(router: &Tensor, x: &Tensor, top_k: usize) -> Result<Vec<Vec<(usize, f32)>>> {
    let t = x.shape()[0];
    let mut logits = Tensor::default();
    let mut order = Vec::new();
    let mut pairs = Vec::new();
    let k = route_tokens_into(router, x, top_k, &mut logits, &mut order, &mut pairs)?;
    if k == 0 {
        return Ok(vec![Vec::new(); t]);
    }
    Ok(pairs.chunks(k).map(|c| c.to_vec()).collect())
}

/// [`route_tokens`] into reusable buffers — the zero-alloc serving path.
/// `logits` receives the (T, E) routing probabilities, `order` is per-row
/// top-k scratch, and `pairs` receives the flat token-major selection:
/// entry `ti * k + j` is the j-th `(expert, weight)` pair of token `ti`,
/// in the same descending order as [`route_tokens`]. Returns `k`, the
/// number of pairs per token (`top_k` clamped to the expert count).
pub fn route_tokens_into(
    router: &Tensor,
    x: &Tensor,
    top_k: usize,
    logits: &mut Tensor,
    order: &mut Vec<usize>,
    pairs: &mut Vec<(usize, f32)>,
) -> Result<usize> {
    let t = x.shape()[0];
    let e = router.shape()[0];
    logits.reuse2(t, e);
    ops::matmul_bt_into(x, router, logits)?;
    ops::softmax_rows_inplace(logits);
    let k = top_k.min(e);
    pairs.clear();
    pairs.reserve(t * k);
    for ti in 0..t {
        let row = logits.row(ti);
        ops::top_k_order(row, k, order);
        for &ei in order.iter() {
            pairs.push((ei, row[ei]));
        }
    }
    Ok(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn routing_selects_topk_softmax() {
        let mut rng = Rng::new(61);
        let router = Tensor::randn(&[6, 8], 1.0, &mut rng);
        let x = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let routes = route_tokens(&router, &x, 2).unwrap();
        assert_eq!(routes.len(), 5);
        for r in &routes {
            assert_eq!(r.len(), 2);
            assert!(r[0].1 >= r[1].1);
            // weights are softmax probs: in (0,1), sum <= 1
            let s: f32 = r.iter().map(|&(_, w)| w).sum();
            assert!(s > 0.0 && s <= 1.0 + 1e-6);
            assert_ne!(r[0].0, r[1].0);
        }
    }

    #[test]
    fn into_variant_matches_independent_reference_exactly() {
        // `route_tokens` is now a thin wrapper over `route_tokens_into`, so
        // the reference here is computed independently (dense softmax +
        // per-row stable sort) instead of through the wrapper — a bug in
        // the shared path cannot cancel itself out.
        let mut rng = Rng::new(63);
        let router = Tensor::randn(&[6, 8], 1.0, &mut rng);
        let x = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let probs = ops::softmax_rows(&ops::matmul_bt(&x, &router).unwrap());
        let want: Vec<Vec<(usize, f32)>> = (0..5)
            .map(|ti| {
                let mut full: Vec<(usize, f32)> =
                    probs.row(ti).iter().cloned().enumerate().collect();
                full.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
                full.truncate(2);
                full
            })
            .collect();
        let mut logits = Tensor::default();
        let mut order = Vec::new();
        let mut pairs = Vec::new();
        // run twice through the same buffers: reuse must not change results
        for round in 0..2 {
            let k =
                route_tokens_into(&router, &x, 2, &mut logits, &mut order, &mut pairs).unwrap();
            assert_eq!(k, 2);
            for (ti, tok) in want.iter().enumerate() {
                assert_eq!(&pairs[ti * k..(ti + 1) * k], &tok[..], "round {round} token {ti}");
            }
        }
        // and the wrapper agrees with the same independent reference
        assert_eq!(route_tokens(&router, &x, 2).unwrap(), want);
        // top_k larger than the expert count clamps
        let k = route_tokens_into(&router, &x, 99, &mut logits, &mut order, &mut pairs).unwrap();
        assert_eq!(k, 6);
        assert_eq!(pairs.len(), 5 * 6);
    }

    #[test]
    fn topk_equals_full_sort() {
        let mut rng = Rng::new(62);
        let router = Tensor::randn(&[9, 4], 1.0, &mut rng);
        let x = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let logits = ops::matmul_bt(&x, &router).unwrap();
        let probs = ops::softmax_rows(&logits);
        let routes = route_tokens(&router, &x, 3).unwrap();
        for (ti, r) in routes.iter().enumerate() {
            let mut full: Vec<(usize, f32)> =
                probs.row(ti).iter().cloned().enumerate().collect();
            full.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
            assert_eq!(r[..], full[..3]);
        }
    }
}
