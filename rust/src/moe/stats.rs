//! Expert usage-frequency statistics.
//!
//! Theorem 1 shows the optimal intra-cluster merge weights are the relative
//! usage frequencies `f_j / Σ_{k∈C} f_k`; this accumulator collects the
//! `f_i` over calibration batches (per layer) from either engine's routing
//! outputs.

/// Per-layer expert usage accumulator.
#[derive(Debug, Clone)]
pub struct UsageStats {
    /// Hard assignment counts (tokens that selected the expert in top-K).
    pub counts: Vec<f64>,
    /// Soft mass (sum of routing weights) — exposed for ablations.
    pub weight_mass: Vec<f64>,
    pub tokens_seen: u64,
}

impl UsageStats {
    pub fn new(n_experts: usize) -> UsageStats {
        UsageStats {
            counts: vec![0.0; n_experts],
            weight_mass: vec![0.0; n_experts],
            tokens_seen: 0,
        }
    }

    pub fn n_experts(&self) -> usize {
        self.counts.len()
    }

    pub fn add(&mut self, counts: &[f64], mass: &[f64], tokens: u64) {
        assert_eq!(counts.len(), self.counts.len());
        for (a, b) in self.counts.iter_mut().zip(counts) {
            *a += b;
        }
        for (a, b) in self.weight_mass.iter_mut().zip(mass) {
            *a += b;
        }
        self.tokens_seen += tokens;
    }

    /// Relative frequencies `f_i / Σ f` with a floor so that never-used
    /// experts still receive an infinitesimal weight (keeps Theorem-1
    /// denominators non-zero; the paper's models never hit the floor but
    /// tiny calibration sets can).
    pub fn frequencies(&self) -> Vec<f64> {
        let total: f64 = self.counts.iter().sum();
        if total <= 0.0 {
            return vec![1.0 / self.counts.len() as f64; self.counts.len()];
        }
        self.counts.iter().map(|&c| (c + 1e-9) / total).collect()
    }

    /// Expert indices sorted by descending usage (cluster-center selection:
    /// "experts with top-M usage frequencies are selected as the clustering
    /// center").
    pub fn by_usage_desc(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.counts.len()).collect();
        idx.sort_by(|&a, &b| {
            self.counts[b]
                .partial_cmp(&self.counts[a])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_normalizes() {
        let mut s = UsageStats::new(3);
        s.add(&[2.0, 0.0, 6.0], &[0.9, 0.0, 2.4], 4);
        s.add(&[2.0, 0.0, 2.0], &[0.8, 0.0, 0.9], 2);
        assert_eq!(s.tokens_seen, 6);
        let f = s.frequencies();
        assert!((f[0] - 4.0 / 12.0).abs() < 1e-6);
        assert!((f[2] - 8.0 / 12.0).abs() < 1e-6);
        assert_eq!(s.by_usage_desc(), vec![2, 0, 1]);
    }

    #[test]
    fn empty_stats_fall_back_to_uniform() {
        let s = UsageStats::new(4);
        let f = s.frequencies();
        assert!(f.iter().all(|&x| (x - 0.25).abs() < 1e-9));
    }
}
