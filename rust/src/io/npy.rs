//! NPY (numpy array file) format v1.0 reader/writer.
//!
//! Supports the dtypes this project exchanges with the trainer: `<f4`, `<f8`,
//! `<i4`, `<i8` in C order. Everything is converted to f32/i32 on load (the
//! model is f32 end to end; i64 appears only in numpy defaults).

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NpyDtype {
    F32,
    F64,
    I32,
    I64,
}

impl NpyDtype {
    fn descr(self) -> &'static str {
        match self {
            NpyDtype::F32 => "<f4",
            NpyDtype::F64 => "<f8",
            NpyDtype::I32 => "<i4",
            NpyDtype::I64 => "<i8",
        }
    }

    fn size(self) -> usize {
        match self {
            NpyDtype::F32 | NpyDtype::I32 => 4,
            NpyDtype::F64 | NpyDtype::I64 => 8,
        }
    }
}

/// A parsed NPY array (payload kept in its declared dtype).
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub dtype: NpyDtype,
    pub shape: Vec<usize>,
    data: Vec<u8>,
}

impl NpyArray {
    pub fn parse(bytes: &[u8]) -> Result<NpyArray> {
        if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
            bail!("not an NPY file");
        }
        let major = bytes[6];
        let (header_len, header_start) = match major {
            1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10),
            2 | 3 => (
                u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
                12,
            ),
            v => bail!("unsupported NPY version {v}"),
        };
        let header = std::str::from_utf8(&bytes[header_start..header_start + header_len])
            .context("NPY header not utf-8")?;
        let descr = extract_str(header, "descr")?;
        let dtype = match descr.as_str() {
            "<f4" => NpyDtype::F32,
            "<f8" => NpyDtype::F64,
            "<i4" => NpyDtype::I32,
            "<i8" => NpyDtype::I64,
            d => bail!("unsupported NPY dtype {d:?}"),
        };
        if extract_raw(header, "fortran_order")?.trim() != "False" {
            bail!("fortran_order arrays not supported");
        }
        let shape = parse_shape(&extract_raw(header, "shape")?)?;
        let n: usize = shape.iter().product();
        let payload = &bytes[header_start + header_len..];
        if payload.len() < n * dtype.size() {
            bail!("NPY payload truncated: {} < {}", payload.len(), n * dtype.size());
        }
        Ok(NpyArray { dtype, shape, data: payload[..n * dtype.size()].to_vec() })
    }

    /// Convert to an f32 [`Tensor`] (lossy for i64/f64 beyond f32 range,
    /// which never occurs for our weights).
    pub fn to_tensor(&self) -> Result<Tensor> {
        let n: usize = self.shape.iter().product();
        let mut out = Vec::with_capacity(n);
        match self.dtype {
            NpyDtype::F32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            NpyDtype::F64 => {
                for c in self.data.chunks_exact(8) {
                    out.push(f64::from_le_bytes(c.try_into().unwrap()) as f32);
                }
            }
            NpyDtype::I32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32);
                }
            }
            NpyDtype::I64 => {
                for c in self.data.chunks_exact(8) {
                    out.push(i64::from_le_bytes(c.try_into().unwrap()) as f32);
                }
            }
        }
        Tensor::from_vec(&self.shape, out)
    }

    /// Integer view (router indices, token ids).
    pub fn to_i32(&self) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        match self.dtype {
            NpyDtype::I32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            NpyDtype::I64 => {
                for c in self.data.chunks_exact(8) {
                    out.push(i64::from_le_bytes(c.try_into().unwrap()) as i32);
                }
            }
            d => bail!("to_i32 on non-integer dtype {d:?}"),
        }
        Ok(out)
    }

    /// Serialize an f32 tensor as NPY v1.0 bytes.
    pub fn encode_f32(t: &Tensor) -> Vec<u8> {
        let shape_str = match t.shape().len() {
            1 => format!("({},)", t.shape()[0]),
            _ => format!(
                "({})",
                t.shape().iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
            NpyDtype::F32.descr(),
            shape_str
        );
        // Pad so that (10 + len) % 64 == 0, ending in \n.
        let total = 10 + header.len() + 1;
        let pad = (64 - total % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');
        let mut out = Vec::with_capacity(10 + header.len() + t.len() * 4);
        out.extend_from_slice(b"\x93NUMPY\x01\x00");
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for &v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }
}

fn extract_raw(header: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let idx = header.find(&pat).with_context(|| format!("NPY header missing {key}"))?;
    let rest = &header[idx + pat.len()..];
    // value runs until the next top-level comma or closing brace
    let mut depth = 0usize;
    let mut out = String::new();
    for c in rest.chars() {
        match c {
            '(' | '[' => {
                depth += 1;
                out.push(c);
            }
            ')' | ']' => {
                depth = depth.saturating_sub(1);
                out.push(c);
            }
            ',' | '}' if depth == 0 => break,
            c => out.push(c),
        }
    }
    Ok(out.trim().to_string())
}

fn extract_str(header: &str, key: &str) -> Result<String> {
    let raw = extract_raw(header, key)?;
    Ok(raw.trim_matches(|c| c == '\'' || c == '"').to_string())
}

fn parse_shape(raw: &str) -> Result<Vec<usize>> {
    let inner = raw.trim().trim_start_matches('(').trim_end_matches(')');
    let mut out = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        out.push(p.parse::<usize>().with_context(|| format!("bad shape component {p:?}"))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_f32() {
        let mut rng = Rng::new(41);
        for shape in [vec![7usize], vec![3, 5], vec![2, 3, 4]] {
            let t = Tensor::randn(&shape, 1.0, &mut rng);
            let bytes = NpyArray::encode_f32(&t);
            let arr = NpyArray::parse(&bytes).unwrap();
            assert_eq!(arr.shape, shape);
            let back = arr.to_tensor().unwrap();
            assert_eq!(back.data(), t.data());
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(NpyArray::parse(b"not an npy").is_err());
        assert!(NpyArray::parse(b"\x93NUMPY\x09\x00\x00\x00").is_err());
    }

    #[test]
    fn header_alignment() {
        let t = Tensor::zeros(&[5]);
        let bytes = NpyArray::encode_f32(&t);
        // numpy requires the data section to start at a multiple of 64
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
    }
}
