//! Interchange with the build-time python trainer: NPY/NPZ reading and
//! writing (weights, calibration dumps, compressed-model exports).

pub mod npy;
pub mod npz;

pub use npy::{NpyArray, NpyDtype};
pub use npz::{
    read_npz, read_npz_tensors, read_npz_with_digests, write_npz, write_npz_with_digests, NpzError,
};
