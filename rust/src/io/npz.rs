//! NPZ (zip of NPY members) reading/writing via the `zip` crate.
//!
//! `np.savez` produces stored or deflated members named `<key>.npy`; we
//! accept both and write stored members (fast, and numpy reads them fine).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::npy::NpyArray;
use crate::tensor::Tensor;

/// Read every array in an `.npz` file into a name → array map.
pub fn read_npz(path: &Path) -> Result<BTreeMap<String, NpyArray>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut zip = zip::ZipArchive::new(file)
        .with_context(|| format!("reading zip {}", path.display()))?;
    let mut out = BTreeMap::new();
    for i in 0..zip.len() {
        let mut entry = zip.by_index(i)?;
        let name = entry
            .name()
            .strip_suffix(".npy")
            .unwrap_or(entry.name())
            .to_string();
        let mut bytes = Vec::with_capacity(entry.size() as usize);
        entry.read_to_end(&mut bytes)?;
        let arr = NpyArray::parse(&bytes)
            .with_context(|| format!("parsing member {name} of {}", path.display()))?;
        out.insert(name, arr);
    }
    Ok(out)
}

/// Read an `.npz` file, converting every member to an f32 [`Tensor`].
pub fn read_npz_tensors(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    read_npz(path)?
        .into_iter()
        .map(|(k, v)| Ok((k.clone(), v.to_tensor().with_context(|| k)?)))
        .collect()
}

/// Write f32 tensors as an `.npz` file (stored, no compression — these are
/// local interchange files, and stored members round-trip fastest).
pub fn write_npz(path: &Path, arrays: &BTreeMap<String, Tensor>) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut zip = zip::ZipWriter::new(file);
    let opts = zip::write::FileOptions::default()
        .compression_method(zip::CompressionMethod::Stored);
    for (name, t) in arrays {
        zip.start_file(format!("{name}.npy"), opts)?;
        zip.write_all(&NpyArray::encode_f32(t))?;
    }
    zip.finish()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("mergemoe_npz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.npz");
        let mut rng = Rng::new(51);
        let mut map = BTreeMap::new();
        map.insert("alpha".to_string(), Tensor::randn(&[4, 6], 1.0, &mut rng));
        map.insert("L0.wg".to_string(), Tensor::randn(&[2, 3, 5], 1.0, &mut rng));
        write_npz(&path, &map).unwrap();
        let back = read_npz_tensors(&path).unwrap();
        assert_eq!(back.len(), 2);
        for (k, t) in &map {
            assert_eq!(back[k].shape(), t.shape());
            assert_eq!(back[k].data(), t.data());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(read_npz(Path::new("/nonexistent/x.npz")).is_err());
    }
}
