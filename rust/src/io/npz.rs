//! NPZ (zip of NPY members) reading/writing, dependency-free.
//!
//! `np.savez` produces a plain ZIP archive of stored (uncompressed)
//! members named `<key>.npy`. This module hand-rolls exactly that subset:
//! stored members, ZIP version ≤ 2.0, no zip64. Deflated members
//! (`np.savez_compressed`) are rejected with a typed error rather than
//! silently misread — the build-time trainer uses `np.savez`.
//!
//! The writer emits fully deterministic bytes (zeroed timestamps, sorted
//! members): the same tensor map always serializes to the same archive,
//! which is what makes registry manifest digests stable across rebuilds
//! (see `coordinator::registry`).
//!
//! Robustness contract (pinned by the negative tests below): malformed
//! input — truncated archives, bad magic, lying size fields, short tensor
//! payloads — returns a typed [`NpzError`] and never panics. Allocations
//! are bounded by *validated* sizes only: every declared length is checked
//! against the actual file length before any buffer is sized from it.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::npy::NpyArray;
use crate::tensor::Tensor;
use crate::util::sha256;

/// Typed NPZ container errors (NPY-level errors surface via `anyhow`
/// context from [`NpyArray::parse`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NpzError {
    /// No end-of-central-directory signature — not a ZIP file at all.
    BadMagic,
    /// Structurally valid prefix but byte ranges run past the file end.
    Truncated { what: &'static str },
    /// Member uses a compression method the stored-only reader rejects.
    UnsupportedCompression { name: String, method: u16 },
    /// Stored payload does not match the member's declared CRC-32.
    CrcMismatch { name: String },
    /// Header fields contradict each other (e.g. stored member with
    /// compressed size ≠ uncompressed size).
    Inconsistent { what: String },
}

impl std::fmt::Display for NpzError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NpzError::BadMagic => write!(f, "not a zip archive (no end-of-central-directory)"),
            NpzError::Truncated { what } => write!(f, "zip archive truncated: {what}"),
            NpzError::UnsupportedCompression { name, method } => {
                write!(f, "member {name:?} uses compression method {method} (stored-only reader)")
            }
            NpzError::CrcMismatch { name } => {
                write!(f, "member {name:?} payload does not match its CRC-32")
            }
            NpzError::Inconsistent { what } => write!(f, "zip header inconsistency: {what}"),
        }
    }
}

impl std::error::Error for NpzError {}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE, poly 0xEDB88320) — the ZIP member checksum.
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC-32 of a byte slice (ZIP member checksum).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Reading
// ---------------------------------------------------------------------------

const EOCD_SIG: u32 = 0x0605_4b50;
const CDIR_SIG: u32 = 0x0201_4b50;
const LOCAL_SIG: u32 = 0x0403_4b50;
const EOCD_MIN: usize = 22;

fn le16(b: &[u8], off: usize) -> u16 {
    u16::from_le_bytes([b[off], b[off + 1]])
}

fn le32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// A member's raw stored payload located inside the archive buffer.
struct RawMember<'a> {
    name: String,
    payload: &'a [u8],
}

/// Locate and validate every stored member via the central directory.
fn parse_members(buf: &[u8]) -> Result<Vec<RawMember<'_>>, NpzError> {
    if buf.len() < EOCD_MIN {
        return Err(NpzError::BadMagic);
    }
    // EOCD is within the last 64 KiB + 22 bytes (comment is u16-sized).
    let scan_start = buf.len().saturating_sub(EOCD_MIN + u16::MAX as usize);
    let mut eocd = None;
    let mut pos = buf.len() - EOCD_MIN;
    loop {
        if le32(buf, pos) == EOCD_SIG {
            eocd = Some(pos);
            break;
        }
        if pos == scan_start {
            break;
        }
        pos -= 1;
    }
    let eocd = eocd.ok_or(NpzError::BadMagic)?;
    let n_entries = le16(buf, eocd + 10) as usize;
    let cd_size = le32(buf, eocd + 12) as usize;
    let cd_off = le32(buf, eocd + 16) as usize;
    if cd_off.checked_add(cd_size).map_or(true, |end| end > buf.len()) {
        return Err(NpzError::Truncated { what: "central directory extends past end of file" });
    }

    let mut members = Vec::with_capacity(n_entries.min(4096));
    let mut p = cd_off;
    for _ in 0..n_entries {
        if p + 46 > cd_off + cd_size {
            return Err(NpzError::Truncated { what: "central directory entry header" });
        }
        if le32(buf, p) != CDIR_SIG {
            return Err(NpzError::Inconsistent { what: "central directory signature".into() });
        }
        let method = le16(buf, p + 10);
        let crc = le32(buf, p + 16);
        let csize = le32(buf, p + 20) as usize;
        let usize_ = le32(buf, p + 24) as usize;
        let name_len = le16(buf, p + 28) as usize;
        let extra_len = le16(buf, p + 30) as usize;
        let comment_len = le16(buf, p + 32) as usize;
        let local_off = le32(buf, p + 42) as usize;
        if p + 46 + name_len > cd_off + cd_size {
            return Err(NpzError::Truncated { what: "central directory entry name" });
        }
        let name = String::from_utf8_lossy(&buf[p + 46..p + 46 + name_len]).into_owned();
        if method != 0 {
            return Err(NpzError::UnsupportedCompression { name, method });
        }
        if csize != usize_ {
            return Err(NpzError::Inconsistent {
                what: format!("stored member {name:?} has csize {csize} != usize {usize_}"),
            });
        }

        // Walk the local header to find the payload start; trust only
        // ranges that fit inside the buffer.
        if local_off + 30 > buf.len() {
            return Err(NpzError::Truncated { what: "local file header" });
        }
        if le32(buf, local_off) != LOCAL_SIG {
            return Err(NpzError::Inconsistent { what: format!("local header for {name:?}") });
        }
        let l_name = le16(buf, local_off + 26) as usize;
        let l_extra = le16(buf, local_off + 28) as usize;
        let data_start = local_off + 30 + l_name + l_extra;
        let data_end = data_start.checked_add(csize).unwrap_or(usize::MAX);
        if data_end > buf.len() {
            return Err(NpzError::Truncated { what: "member payload" });
        }
        let payload = &buf[data_start..data_end];
        if crc32(payload) != crc {
            return Err(NpzError::CrcMismatch { name });
        }
        members.push(RawMember { name, payload });
        p += 46 + name_len + extra_len + comment_len;
    }
    Ok(members)
}

/// Read every array in an `.npz` file into a name → array map.
pub fn read_npz(path: &Path) -> Result<BTreeMap<String, NpyArray>> {
    Ok(read_npz_with_digests(path)?.0)
}

/// Read an `.npz` file, also returning each member's SHA-256 (hex) —
/// digest of the raw `.npy` member bytes, the quantity registry
/// manifests record per tensor blob.
pub fn read_npz_with_digests(
    path: &Path,
) -> Result<(BTreeMap<String, NpyArray>, BTreeMap<String, String>)> {
    let buf = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    let members = parse_members(&buf).with_context(|| format!("reading zip {}", path.display()))?;
    let mut out = BTreeMap::new();
    let mut digests = BTreeMap::new();
    for m in members {
        let key = m.name.strip_suffix(".npy").unwrap_or(&m.name).to_string();
        let arr = NpyArray::parse(m.payload)
            .with_context(|| format!("parsing member {key} of {}", path.display()))?;
        digests.insert(key.clone(), sha256::hex_digest(m.payload));
        out.insert(key, arr);
    }
    Ok((out, digests))
}

/// Read an `.npz` file, converting every member to an f32 [`Tensor`].
pub fn read_npz_tensors(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    read_npz(path)?
        .into_iter()
        .map(|(k, v)| Ok((k.clone(), v.to_tensor().with_context(|| k)?)))
        .collect()
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

/// Write f32 tensors as an `.npz` file (stored, no compression — these are
/// local interchange files, and stored members round-trip fastest).
pub fn write_npz(path: &Path, arrays: &BTreeMap<String, Tensor>) -> Result<()> {
    write_npz_with_digests(path, arrays).map(|_| ())
}

/// Write an `.npz` file and return each member's SHA-256 (hex) for
/// manifest recording. The file is fsynced before returning so a
/// subsequent atomic rename publishes durable bytes.
pub fn write_npz_with_digests(
    path: &Path,
    arrays: &BTreeMap<String, Tensor>,
) -> Result<BTreeMap<String, String>> {
    let mut body: Vec<u8> = Vec::new();
    let mut central: Vec<u8> = Vec::new();
    let mut digests = BTreeMap::new();
    let mut n_entries = 0u16;
    for (name, t) in arrays {
        let member_name = format!("{name}.npy");
        let payload = NpyArray::encode_f32(t);
        let crc = crc32(&payload);
        digests.insert(name.clone(), sha256::hex_digest(&payload));
        let local_off = body.len() as u32;

        // Local file header (timestamps zeroed: deterministic output).
        body.extend_from_slice(&LOCAL_SIG.to_le_bytes());
        body.extend_from_slice(&20u16.to_le_bytes()); // version needed
        body.extend_from_slice(&0u16.to_le_bytes()); // flags
        body.extend_from_slice(&0u16.to_le_bytes()); // method: stored
        body.extend_from_slice(&0u16.to_le_bytes()); // mod time
        body.extend_from_slice(&0u16.to_le_bytes()); // mod date
        body.extend_from_slice(&crc.to_le_bytes());
        body.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        body.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        body.extend_from_slice(&(member_name.len() as u16).to_le_bytes());
        body.extend_from_slice(&0u16.to_le_bytes()); // extra len
        body.extend_from_slice(member_name.as_bytes());
        body.extend_from_slice(&payload);

        // Central directory entry.
        central.extend_from_slice(&CDIR_SIG.to_le_bytes());
        central.extend_from_slice(&20u16.to_le_bytes()); // version made by
        central.extend_from_slice(&20u16.to_le_bytes()); // version needed
        central.extend_from_slice(&0u16.to_le_bytes()); // flags
        central.extend_from_slice(&0u16.to_le_bytes()); // method
        central.extend_from_slice(&0u16.to_le_bytes()); // time
        central.extend_from_slice(&0u16.to_le_bytes()); // date
        central.extend_from_slice(&crc.to_le_bytes());
        central.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        central.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        central.extend_from_slice(&(member_name.len() as u16).to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes()); // extra len
        central.extend_from_slice(&0u16.to_le_bytes()); // comment len
        central.extend_from_slice(&0u16.to_le_bytes()); // disk start
        central.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
        central.extend_from_slice(&0u32.to_le_bytes()); // external attrs
        central.extend_from_slice(&local_off.to_le_bytes());
        central.extend_from_slice(member_name.as_bytes());
        n_entries += 1;
    }

    let cd_off = body.len() as u32;
    let cd_size = central.len() as u32;
    body.extend_from_slice(&central);
    body.extend_from_slice(&EOCD_SIG.to_le_bytes());
    body.extend_from_slice(&0u16.to_le_bytes()); // disk
    body.extend_from_slice(&0u16.to_le_bytes()); // cd disk
    body.extend_from_slice(&n_entries.to_le_bytes());
    body.extend_from_slice(&n_entries.to_le_bytes());
    body.extend_from_slice(&cd_size.to_le_bytes());
    body.extend_from_slice(&cd_off.to_le_bytes());
    body.extend_from_slice(&0u16.to_le_bytes()); // comment len

    let mut file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    file.write_all(&body)?;
    file.sync_all()
        .with_context(|| format!("fsyncing {}", path.display()))?;
    Ok(digests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mergemoe_npz_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_map(seed: u64) -> BTreeMap<String, Tensor> {
        let mut rng = Rng::new(seed);
        let mut map = BTreeMap::new();
        map.insert("alpha".to_string(), Tensor::randn(&[4, 6], 1.0, &mut rng));
        map.insert("L0.wg".to_string(), Tensor::randn(&[2, 3, 5], 1.0, &mut rng));
        map
    }

    #[test]
    fn roundtrip() {
        let path = tmp("rt.npz");
        let map = sample_map(51);
        write_npz(&path, &map).unwrap();
        let back = read_npz_tensors(&path).unwrap();
        assert_eq!(back.len(), 2);
        for (k, t) in &map {
            assert_eq!(back[k].shape(), t.shape());
            assert_eq!(back[k].data(), t.data());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn digests_stable_and_verified_on_read() {
        let p1 = tmp("dig1.npz");
        let p2 = tmp("dig2.npz");
        let map = sample_map(52);
        let d1 = write_npz_with_digests(&p1, &map).unwrap();
        let d2 = write_npz_with_digests(&p2, &map).unwrap();
        // Deterministic serialization: same tensors, same digests.
        assert_eq!(d1, d2);
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        let (_, rd) = read_npz_with_digests(&p1).unwrap();
        assert_eq!(rd, d1);
        for p in [p1, p2] {
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn missing_file_errors() {
        assert!(read_npz(Path::new("/nonexistent/x.npz")).is_err());
    }

    #[test]
    fn bad_magic_is_typed() {
        let path = tmp("magic.npz");
        std::fs::write(&path, b"this is not a zip archive, just junk bytes").unwrap();
        let err = read_npz(&path).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<NpzError>(), Some(NpzError::BadMagic)),
            "{err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncation_at_every_prefix_is_an_error_never_a_panic() {
        let path = tmp("trunc.npz");
        write_npz(&path, &sample_map(53)).unwrap();
        let full = std::fs::read(&path).unwrap();
        let tpath = tmp("trunc_cut.npz");
        // Every strict prefix must fail with a typed error (BadMagic once
        // the EOCD is gone, Truncated when ranges dangle) — and never
        // panic or allocate from unvalidated sizes.
        for cut in (0..full.len()).step_by(7).chain([full.len() - 1]) {
            std::fs::write(&tpath, &full[..cut]).unwrap();
            let err = read_npz(&tpath).unwrap_err();
            assert!(err.downcast_ref::<NpzError>().is_some(), "cut={cut}: {err:#}");
        }
        for p in [path, tpath] {
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let path = tmp("crc.npz");
        write_npz(&path, &sample_map(54)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit in the middle of the first member's payload (well
        // past the 30-byte local header + name).
        let at = 80;
        bytes[at] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_npz(&path).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<NpzError>(), Some(NpzError::CrcMismatch { .. })),
            "{err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn short_tensor_payload_is_typed_npy_error() {
        // A structurally valid zip whose member lies about being a full
        // NPY array: NpyArray::parse must reject it (payload truncated),
        // not over-read.
        let t = Tensor::zeros(&[8, 8]);
        let mut npy = NpyArray::encode_f32(&t);
        npy.truncate(npy.len() - 64); // keep header, cut data short
        let crc = crc32(&npy);
        let name = b"short.npy";
        let mut buf = Vec::new();
        buf.extend_from_slice(&LOCAL_SIG.to_le_bytes());
        buf.extend_from_slice(&20u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(name);
        buf.extend_from_slice(&npy);
        let cd_off = buf.len() as u32;
        buf.extend_from_slice(&CDIR_SIG.to_le_bytes());
        buf.extend_from_slice(&20u16.to_le_bytes());
        buf.extend_from_slice(&20u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(name);
        let cd_size = buf.len() as u32 - cd_off;
        buf.extend_from_slice(&EOCD_SIG.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.extend_from_slice(&cd_size.to_le_bytes());
        buf.extend_from_slice(&cd_off.to_le_bytes());
        buf.extend_from_slice(&0u16.to_le_bytes());

        let path = tmp("short_member.npz");
        std::fs::write(&path, &buf).unwrap();
        let err = read_npz(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn lying_size_fields_rejected() {
        let path = tmp("lying.npz");
        write_npz(&path, &sample_map(55)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Inflate the first central-directory entry's declared sizes far
        // past the file end: reader must reject, not allocate 4 GB.
        let cd = bytes
            .windows(4)
            .position(|w| w == CDIR_SIG.to_le_bytes())
            .unwrap();
        bytes[cd + 20..cd + 24].copy_from_slice(&0xFFFF_FF00u32.to_le_bytes());
        bytes[cd + 24..cd + 28].copy_from_slice(&0xFFFF_FF00u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = read_npz(&path).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<NpzError>(), Some(NpzError::Truncated { .. })),
            "{err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_vector() {
        // Standard IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }
}
