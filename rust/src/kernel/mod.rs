//! Runtime-dispatched SIMD GEMM microkernels with fused epilogues — the
//! per-core compute substrate under `tensor::ops`.
//!
//! PRs 1–3 bought thread-level parallelism and zero-alloc steady state, but
//! every hot loop still bottomed out in scalar register tiles compiled for
//! the baseline target (SSE2 on x86_64). This module adds the missing
//! per-core axis: explicit 8-wide FMA microkernels (AVX2+FMA) on x86_64 and
//! 4-wide NEON kernels on aarch64, behind cache-blocked, panel-packed GEMM
//! drivers, selected **once per process**.
//!
//! ## Dispatch
//!
//! [`active`] resolves the kernel exactly once (benign-race atomic, same
//! pattern as `par::max_threads`):
//!
//! * `MERGEMOE_KERNEL=auto` (or unset) — detect at startup:
//!   `is_x86_feature_detected!("avx2")` + `fma` on x86_64, NEON on aarch64
//!   (baseline there), scalar everywhere else.
//! * `MERGEMOE_KERNEL=scalar` — the seed repo's register-tiled loops,
//!   preserved **bit for bit** (see `scalar.rs`); the reference every SIMD
//!   path is tested against.
//! * `MERGEMOE_KERNEL=avx2` / `neon` — force a SIMD path; falls back to
//!   scalar with a warning when the host cannot run it.
//!
//! [`set_kernel`] overrides the choice programmatically — for benches and
//! tests only (mirrors `par::set_max_threads`); production code never calls
//! it, so the per-process fixed-choice contract holds.
//!
//! ## Determinism contract
//!
//! The kernel choice is fixed per process, and within a kernel every output
//! element is reduced in an order that depends **only on shapes** (k-block
//! boundaries, column-tile classes), never on the thread count or on which
//! row block a lane claimed. Concretely, every driver computes each output
//! *row* with arithmetic that is independent of the row's position in the
//! matrix, so results are bit-identical across `--threads` 1/2/8
//! (`tests/par_consistency.rs`). For the `A @ Bᵀ` forms (every serving
//! GEMM) the kernel never depends on the row count, so padding-only batch
//! growth is also bit-invariant; `A @ B` alone may switch between the
//! direct and packed driver as `m` crosses the pack threshold.
//! Scalar-vs-SIMD agreement is a tolerance contract, not a bit contract
//! (FMA contracts rounding steps): `tests/kernel_consistency.rs` pins it.
//!
//! ## Packing
//!
//! The `A @ B` driver ([`gemm_nn`]) is cache-blocked over k and, on the
//! AVX2 path at large shapes, packs B k-panels into contiguous
//! 16-column-wide panels so the inner loop streams packed memory instead
//! of striding `n` floats between FMA operands. The pack
//! buffer is **per-thread** (pool workers persist across regions, so after
//! warmup it is as long-lived as a workspace field) and reused at its
//! high-water size — the counting-allocator probes in
//! `benches/bench_forward.rs` stay green because the serving hot path is
//! entirely `A @ Bᵀ`-shaped (never packs) and the pack buffer never churns.
//! Every epilogue below writes the output exactly once, eliminating the
//! write+re-read of a full intermediate:
//!
//! * [`gemm_nt_swiglu`] — `silu(x W_Gᵀ) ⊙ (x W_Uᵀ)` for the expert FFN
//!   (the U panel is never materialized);
//! * [`gemm_nt_scaled_add`] / [`gemm_nt_scatter_add`] — scale-and-accumulate
//!   merged-expert recombination in `moe_forward_ws` (the per-expert output
//!   batch is never materialized);
//! * [`syrk_nt`] — the symmetric rank-k Gram update `P Pᵀ` computes the
//!   lower triangle only and mirrors it (exactly equal to the full product,
//!   column dots are grouping-invariant by construction).

#![warn(missing_docs)]

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "aarch64")]
pub mod neon;

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::par;

/// Which microkernel family the process runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Seed-exact register-tiled loops (the portable fallback).
    Scalar,
    /// 8-wide AVX2 + FMA (x86_64 only).
    Avx2,
    /// 4-wide NEON (aarch64 only).
    Neon,
}

impl Kind {
    /// Lower-case family name (`"scalar"`, `"avx2"`, `"neon"`) — the
    /// spelling `MERGEMOE_KERNEL` accepts and reports stamp.
    pub fn name(self) -> &'static str {
        match self {
            Kind::Scalar => "scalar",
            Kind::Avx2 => "avx2",
            Kind::Neon => "neon",
        }
    }
}

/// 0 = unresolved; resolved lazily on first use (benign race: every racer
/// computes the same value from the same env + CPUID).
static KERNEL: AtomicU8 = AtomicU8::new(0);

fn encode(k: Kind) -> u8 {
    match k {
        Kind::Scalar => 1,
        Kind::Avx2 => 2,
        Kind::Neon => 3,
    }
}

fn decode(v: u8) -> Option<Kind> {
    match v {
        1 => Some(Kind::Scalar),
        2 => Some(Kind::Avx2),
        3 => Some(Kind::Neon),
        _ => None,
    }
}

/// What `auto` resolves to on this host.
fn detect() -> Kind {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Kind::Avx2;
        }
        Kind::Scalar
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the aarch64 baseline.
        Kind::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Kind::Scalar
    }
}

fn resolve() -> Kind {
    let choice = std::env::var("MERGEMOE_KERNEL").unwrap_or_default();
    match choice.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => detect(),
        "scalar" => Kind::Scalar,
        "avx2" if detect() == Kind::Avx2 => Kind::Avx2,
        "neon" if detect() == Kind::Neon => Kind::Neon,
        // arch-neutral alias: whatever SIMD family this host detects
        "simd" if detect() != Kind::Scalar => detect(),
        other => {
            // Same contract as `set_kernel`: an unsupported (or mistyped)
            // choice degrades to the seed-exact scalar family, never to a
            // silently different SIMD one.
            crate::warnlog!("MERGEMOE_KERNEL={other} unsupported on this host; using scalar");
            Kind::Scalar
        }
    }
}

/// The microkernel family every GEMM in this process dispatches to.
/// Resolved once from `MERGEMOE_KERNEL` (auto/scalar/avx2/neon) + CPU
/// detection; fixed for the life of the process unless a bench/test calls
/// [`set_kernel`].
pub fn active() -> Kind {
    if let Some(k) = decode(KERNEL.load(Ordering::Relaxed)) {
        return k;
    }
    let resolved = resolve();
    KERNEL.store(encode(resolved), Ordering::Relaxed);
    resolved
}

/// Name of the active kernel (`"scalar"`, `"avx2"`, `"neon"`) — stamped
/// into every `BENCH_*.json` / `SWEEP_*.json` report and the serve summary.
pub fn name() -> &'static str {
    active().name()
}

/// Override the kernel choice — **benches and tests only** (the production
/// contract is one kernel per process). Forcing a SIMD kind the host cannot
/// run degrades to scalar with a warning instead of executing illegal
/// instructions.
pub fn set_kernel(k: Kind) {
    let k = match k {
        Kind::Scalar => Kind::Scalar,
        other if other == detect() => other,
        other => {
            crate::warnlog!("kernel {} unavailable on this host; using scalar", other.name());
            Kind::Scalar
        }
    };
    KERNEL.store(encode(k), Ordering::Relaxed);
}

/// SiLU (swish) — the expert-FFN epilogue nonlinearity. One definition
/// shared by every kernel family so fused and unfused paths agree bit for
/// bit (`tensor::ops::silu` re-exports it).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

// ---------------------------------------------------------------------------
// Packing.
// ---------------------------------------------------------------------------

/// k-block height of the packed `A @ B` driver: B panels of `KC` rows are
/// packed contiguously so the inner FMA loop streams L2-resident memory.
pub const KC: usize = 256;

// The packing machinery below is only *driven* from the x86_64 packed
// path, but stays arch-neutral (pack_b has unit tests that run
// everywhere); allow dead_code on other arches instead of cfg-gating so
// an aarch64 `cargo clippy -D warnings` run stays clean.

/// Column width of one packed B panel (two 8-lane vectors).
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
const NR: usize = 16;

/// Pack when the B operand clearly exceeds the L2-friendly direct regime
/// and there are enough output rows to amortize the copy.
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
const PACK_MIN_B_ELEMS: usize = 64 * 1024;
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
const PACK_MIN_ROWS: usize = 16;

/// Reusable panel-packing scratch for the blocked `A @ B` driver. Grows to
/// its high-water size and is then allocation-free; private to the driver —
/// one per thread (see the module docs for why per-thread storage preserves
/// the zero-alloc guarantee).
#[derive(Default)]
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
struct PackBuf {
    buf: Vec<f32>,
}

thread_local! {
    /// The calling thread's pack scratch. Taken out of the cell for the
    /// duration of a GEMM (never borrowed across the parallel region), so
    /// nested calls cannot alias it.
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    static TL_PACK: std::cell::RefCell<PackBuf> = std::cell::RefCell::new(PackBuf::default());
}

/// Pack rows `[kb, kb+kc)` of the row-major `b` (k, n) into
/// `ceil(n/NR)` panels of `kc`×`NR` (kk-major, zero-padded tail columns).
/// Panels are independent pure copies, so they fan across the pool (with
/// the caller's parallel decision) instead of leaving workers idle between
/// the driver's compute regions.
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn pack_b(b: &[f32], n: usize, kb: usize, kc: usize, packed: &mut [f32], parallel: bool) {
    let np = (n + NR - 1) / NR;
    let base = packed.as_mut_ptr() as usize;
    par::par_for_range_if(parallel, np, |p| {
        let j0 = p * NR;
        let w = (n - j0).min(NR);
        // SAFETY: panel p owns exactly `packed[p*kc*NR .. (p+1)*kc*NR]` —
        // disjoint per lane; `packed` outlives the region.
        let dst_panel =
            unsafe { std::slice::from_raw_parts_mut((base as *mut f32).add(p * kc * NR), kc * NR) };
        for kk in 0..kc {
            let src = (kb + kk) * n + j0;
            let dst = kk * NR;
            dst_panel[dst..dst + w].copy_from_slice(&b[src..src + w]);
            for c in w..NR {
                dst_panel[dst + c] = 0.0;
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Row-kernel dispatch.
// ---------------------------------------------------------------------------

// Every `match` below carries a trailing `_ => scalar` arm: on x86_64 it
// covers `Kind::Neon` (never produced there by `resolve`/`set_kernel`) and
// vice versa, keeping the enum portable without per-arch variants.

#[inline]
fn nt_row(kind: Kind, arow: &[f32], b: &[f32], orow: &mut [f32]) {
    match kind {
        Kind::Scalar => scalar::nt_row(arow, b, orow),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only yields Avx2 after feature detection.
        Kind::Avx2 => unsafe { avx2::nt_row(arow, b, orow) },
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => neon::nt_row(arow, b, orow),
        _ => scalar::nt_row(arow, b, orow),
    }
}

#[inline]
fn nt_row_scaled_add(kind: Kind, arow: &[f32], b: &[f32], alpha: f32, orow: &mut [f32]) {
    match kind {
        Kind::Scalar => scalar::nt_row_scaled_add(arow, b, alpha, orow),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only yields Avx2 after feature detection.
        Kind::Avx2 => unsafe { avx2::nt_row_scaled_add(arow, b, alpha, orow) },
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => neon::nt_row_scaled_add(arow, b, alpha, orow),
        _ => scalar::nt_row_scaled_add(arow, b, alpha, orow),
    }
}

#[inline]
fn nt_row_swiglu(kind: Kind, arow: &[f32], wg: &[f32], wu: &[f32], orow: &mut [f32]) {
    match kind {
        Kind::Scalar => scalar::nt_row_swiglu(arow, wg, wu, orow),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only yields Avx2 after feature detection.
        Kind::Avx2 => unsafe { avx2::nt_row_swiglu(arow, wg, wu, orow) },
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => neon::nt_row_swiglu(arow, wg, wu, orow),
        _ => scalar::nt_row_swiglu(arow, wg, wu, orow),
    }
}

#[inline]
fn nn_row(kind: Kind, arow: &[f32], b: &[f32], n: usize, orow: &mut [f32]) {
    match kind {
        Kind::Scalar => scalar::nn_row(arow, b, n, orow),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only yields Avx2 after feature detection.
        Kind::Avx2 => unsafe { avx2::nn_row(arow, b, n, orow) },
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => neon::nn_row(arow, b, n, orow),
        _ => scalar::nn_row(arow, b, n, orow),
    }
}

#[inline]
fn tn_row(kind: Kind, ad: &[f32], m: usize, k: usize, i: usize, b: &[f32], orow: &mut [f32]) {
    match kind {
        Kind::Scalar => scalar::tn_row(ad, m, k, i, b, orow),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only yields Avx2 after feature detection.
        Kind::Avx2 => unsafe { avx2::tn_row(ad, m, k, i, b, orow) },
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => neon::tn_row(ad, m, k, i, b, orow),
        _ => scalar::tn_row(ad, m, k, i, b, orow),
    }
}

// ---------------------------------------------------------------------------
// GEMM drivers. Shapes are trusted (validated by the `tensor::ops`
// wrappers); every driver parallelizes over independent output regions with
// the same work threshold the seed kernels used.
// ---------------------------------------------------------------------------

/// `out (m,n) = a (m,k) @ bᵀ` with `b` row-major (n,k). Fully overwrites.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    let kind = active();
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, out, n, |i, orow| {
        nt_row(kind, &a[i * k..(i + 1) * k], b, orow);
    });
}

/// `out (m,n) += alpha · (a (m,k) @ bᵀ)` — the scale-and-accumulate
/// epilogue (merged-expert recombination, shared-expert residual,
/// frequency-weighted Ŷ panels). Fuses what used to be a full GEMM output
/// write plus an `axpy` re-read.
pub fn gemm_nt_scaled_add(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    out: &mut [f32],
) {
    let kind = active();
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, out, n, |i, orow| {
        nt_row_scaled_add(kind, &a[i * k..(i + 1) * k], b, alpha, orow);
    });
}

/// Scatter variant: `out[dst[r]] += scales[r] · (a_r @ bᵀ)` for each input
/// row `r`.
///
/// # Safety
///
/// `dst` must be strictly increasing (distinct destination rows, so
/// parallel row lanes never alias) and every `dst[r] * n + n` must be
/// `<= out.len()`; violating either fabricates overlapping or
/// out-of-bounds `&mut` row slices. The `tensor::ops` wrapper
/// (`matmul_bt_scatter_add_into`) validates both and is the safe entry
/// point.
#[allow(clippy::too_many_arguments)]
pub unsafe fn gemm_nt_scatter_add(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    scales: &[f32],
    dst: &[usize],
    out: &mut [f32],
) {
    debug_assert!(dst.windows(2).all(|w| w[0] < w[1]));
    let kind = active();
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    let base = out.as_mut_ptr() as usize;
    par::par_for_range_if(parallel, m, |r| {
        // SAFETY: dst is strictly increasing and bounds-checked by the
        // caller, so each lane writes a distinct, in-bounds row of `out`.
        let orow =
            unsafe { std::slice::from_raw_parts_mut((base as *mut f32).add(dst[r] * n), n) };
        nt_row_scaled_add(kind, &a[r * k..(r + 1) * k], b, scales[r], orow);
    });
}

/// Fused SwiGLU: `out (m,f) = silu(a @ wgᵀ) ⊙ (a @ wuᵀ)` with `wg`/`wu`
/// row-major (f,k). One pass over `a` feeds both dot products; the U panel
/// is never materialized.
pub fn gemm_nt_swiglu(
    a: &[f32],
    wg: &[f32],
    wu: &[f32],
    m: usize,
    k: usize,
    f: usize,
    out: &mut [f32],
) {
    let kind = active();
    // two matmuls' worth of flops per output element
    let parallel = 4 * m * k * f >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, out, f, |i, orow| {
        nt_row_swiglu(kind, &a[i * k..(i + 1) * k], wg, wu, orow);
    });
}

/// `out (m,n) = a (m,k) @ b (k,n)`, both row-major. Fully overwrites.
/// Cache-blocked over k; the AVX2 path additionally packs B k-panels into
/// `pack` when the shape is past the direct-streaming regime.
pub fn gemm_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    let kind = active();
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    #[cfg(target_arch = "x86_64")]
    if kind == Kind::Avx2 && m >= PACK_MIN_ROWS && k * n >= PACK_MIN_B_ELEMS {
        // Take the pack scratch out of its cell for the whole GEMM so the
        // parallel region never observes a live borrow.
        let mut pack = TL_PACK.with(|p| std::mem::take(&mut *p.borrow_mut()));
        gemm_nn_packed_avx2(a, b, m, k, n, out, &mut pack, parallel);
        TL_PACK.with(|p| *p.borrow_mut() = pack);
        return;
    }
    par::par_chunks_mut_if(parallel, out, n, |i, orow| {
        nn_row(kind, &a[i * k..(i + 1) * k], b, n, orow);
    });
}

/// The packed AVX2 `A @ B` path: serial loop over k-blocks, pack the block
/// of B once, then fan output row-quads across the pool. Reduction order
/// per output element is the plain `kk` order (the FMA chain never
/// reassociates across the k-block boundary — partial sums are carried in
/// the output row), so results depend only on shapes.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn gemm_nn_packed_avx2(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pack: &mut PackBuf,
    parallel: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    let np = (n + NR - 1) / NR;
    pack.buf.resize(np * KC * NR, 0.0);
    let mut kb = 0;
    while kb < k {
        let kc = (k - kb).min(KC);
        pack_b(b, n, kb, kc, &mut pack.buf[..np * kc * NR], parallel);
        let packed: &[f32] = &pack.buf[..np * kc * NR];
        let first = kb == 0;
        // 4 output rows per chunk: the quad kernel shares each packed B
        // load across four row accumulators.
        par::par_chunks_mut_if(parallel, out, 4 * n, |ci, chunk| {
            let rows = chunk.len() / n;
            let r0 = ci * 4;
            let ablock = &a[r0 * k..(r0 + rows) * k];
            // SAFETY: AVX2+FMA verified by `active()` before dispatch.
            unsafe { avx2::nn_packed_chunk(ablock, k, kb, kc, packed, n, chunk, rows, first) };
        });
        kb += kc;
    }
}

/// `out (m,n) = aᵀ @ b` with `a` row-major (k,m), `b` row-major (k,n).
/// Keeps the zero-skip on `a` (Theorem-1 usage masses arrive sparse).
pub fn gemm_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    let kind = active();
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, out, n, |i, orow| {
        tn_row(kind, a, m, k, i, b, orow);
    });
}

/// Symmetric rank-k update `out (f,f) = p (f,s) @ pᵀ`: computes the lower
/// triangle (row i needs only columns `0..=i`) and mirrors it. Because
/// every kernel family computes a column dot with a grouping-invariant
/// instruction sequence, the mirrored upper triangle is bit-identical to
/// what the full `gemm_nt(p, p)` would have produced.
pub fn syrk_nt(p: &[f32], f: usize, s: usize, out: &mut [f32]) {
    let kind = active();
    let parallel = f * f * s >= par::PAR_MIN_FLOPS;
    // Row i of the lower triangle costs O(i+1) dots, so contiguous row
    // blocks would hand the last lane ~2x the mean work. Interleave cheap
    // and expensive rows (index 0,1,2,.. -> row 0, f-1, 1, f-2, ..) so
    // every contiguous index block carries near-equal flops; which lane
    // computes a row never affects its value, so determinism is untouched.
    let base = out.as_mut_ptr() as usize;
    par::par_for_range_if(parallel, f, |idx| {
        let i = if idx % 2 == 0 { idx / 2 } else { f - 1 - idx / 2 };
        // SAFETY: the index map is a bijection on 0..f, so each lane writes
        // a distinct row prefix `out[i*f .. i*f+i+1]`; `out` outlives the
        // region.
        let orow =
            unsafe { std::slice::from_raw_parts_mut((base as *mut f32).add(i * f), i + 1) };
        nt_row(kind, &p[i * s..(i + 1) * s], &p[..(i + 1) * s], orow);
    });
    // Mirror: strictly-upper writes read strictly-lower entries — the two
    // index sets never intersect, so the row fan-out is race-free.
    let base = out.as_mut_ptr() as usize;
    let mirror_parallel = f * f >= par::PAR_MIN_ELEMS;
    par::par_for_range_if(mirror_parallel, f, |i| {
        let p = base as *mut f32;
        for j in i + 1..f {
            // SAFETY: reads out[j][i] (strictly lower), writes out[i][j]
            // (strictly upper); `out` outlives the region.
            unsafe { *p.add(i * f + j) = *p.add(j * f + i) };
        }
    });
}

/// Mixed-precision dot `Σ l[i] as f64 · c[i] as f64` — the inner product of
/// the blocked triangular-solve panels in `linalg`. The scalar path there
/// keeps the seed's interleaved subtract; this is the SIMD half.
pub fn dot_f64(l: &[f32], c: &[f32]) -> f64 {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `active()` only yields Avx2 after feature detection.
        Kind::Avx2 => unsafe { avx2::dot_f64(l, c) },
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => neon::dot_f64(l, c),
        _ => {
            let mut s = 0.0f64;
            for (a, b) in l.iter().zip(c) {
                s += *a as f64 * *b as f64;
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    // Kernel-*switching* coverage lives in `tests/kernel_consistency.rs`,
    // a separate process: flipping the process-wide knob here would race
    // with concurrent lib tests that assert bit-exact kernel outputs. These
    // tests only exercise the kernel that is already active.
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                out[i * n + j] = s as f32;
            }
        }
        out
    }

    fn rel_err(a: &[f32], b: &[f32]) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
        num.sqrt() / (den.sqrt() + 1e-12)
    }

    #[test]
    fn dispatch_resolves_to_a_named_kernel() {
        let k = active();
        assert!(matches!(k, Kind::Scalar | Kind::Avx2 | Kind::Neon));
        assert!(["scalar", "avx2", "neon"].contains(&name()));
        // the choice is sticky: repeated reads agree
        assert_eq!(active(), k);
    }

    #[test]
    fn packed_nn_matches_naive_above_threshold() {
        // m >= PACK_MIN_ROWS and k*n >= PACK_MIN_B_ELEMS force the packed
        // path on AVX2 hosts; elsewhere this still covers the direct path.
        let (m, k, n) = (21, 330, 210);
        assert!(k * n >= PACK_MIN_B_ELEMS && m >= PACK_MIN_ROWS);
        let mut rng = Rng::new(0x9ACC);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let want = naive_nn(&a, &b, m, k, n);
        let mut out = vec![f32::NAN; m * n];
        gemm_nn(&a, &b, m, k, n, &mut out);
        let err = rel_err(&out, &want);
        assert!(err < 1e-4, "{}: rel err {err}", name());
        // a second run through the warm per-thread pack buffer agrees
        let mut out2 = vec![f32::NAN; m * n];
        gemm_nn(&a, &b, m, k, n, &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn syrk_mirror_is_exactly_symmetric_and_matches_nt() {
        let (f, s) = (37, 113);
        let mut rng = Rng::new(0x57);
        let p = randv(f * s, &mut rng);
        let mut full = vec![f32::NAN; f * f];
        gemm_nt(&p, &p, f, s, f, &mut full);
        let mut half = vec![f32::NAN; f * f];
        syrk_nt(&p, f, s, &mut half);
        assert_eq!(half, full, "{}", name());
    }

    #[test]
    fn pack_b_layout_and_padding() {
        // 2 panels over n=20: second panel is 4 wide + 12 zeros per row.
        let k = 3;
        let n = 20;
        let b: Vec<f32> = (0..k * n).map(|i| i as f32).collect();
        let np = (n + NR - 1) / NR;
        let mut packed = vec![f32::NAN; np * k * NR];
        pack_b(&b, n, 0, k, &mut packed, false);
        for kk in 0..k {
            for c in 0..NR {
                assert_eq!(packed[kk * NR + c], b[kk * n + c]);
            }
            for c in 0..4 {
                assert_eq!(packed[k * NR + kk * NR + c], b[kk * n + NR + c]);
            }
            for c in 4..NR {
                assert_eq!(packed[k * NR + kk * NR + c], 0.0);
            }
        }
    }
}
