//! NEON microkernels (aarch64). 4-lane f32 FMA, 2-lane f64 FMA for the
//! triangular-solve dots. NEON is part of the aarch64 baseline, so these
//! are safe functions with `unsafe` only around the intrinsics.
//!
//! Same invariants as the scalar/AVX2 families: row independence and
//! grouping invariance (a column dot is always a 4-wide FMA chain in k
//! order, one horizontal sum, then the scalar tail). Like AVX2, FMA
//! contracts rounding steps, so agreement with scalar is a tolerance
//! contract (`tests/kernel_consistency.rs`); within this family results
//! are bit-identical across thread counts and row positions.

use core::arch::aarch64::*;

use super::silu;

/// One column dot with the canonical sequence: 4-wide FMA chain, horizontal
/// sum (`vaddvq`), scalar tail.
#[inline]
fn dot1(a: &[f32], b: *const f32) -> f32 {
    let k = a.len();
    let ap = a.as_ptr();
    unsafe {
        let mut acc = vdupq_n_f32(0.0);
        let mut kk = 0;
        while kk + 4 <= k {
            acc = vfmaq_f32(acc, vld1q_f32(ap.add(kk)), vld1q_f32(b.add(kk)));
            kk += 4;
        }
        let mut s = vaddvq_f32(acc);
        while kk < k {
            s += *ap.add(kk) * *b.add(kk);
            kk += 1;
        }
        s
    }
}

/// `orow[j] = arow · b_j` for row-major `b` (n, k).
pub(super) fn nt_row(arow: &[f32], bd: &[f32], orow: &mut [f32]) {
    let k = arow.len();
    let bp = bd.as_ptr();
    for (j, o) in orow.iter_mut().enumerate() {
        *o = dot1(arow, unsafe { bp.add(j * k) });
    }
}

/// [`nt_row`] with the scale-and-accumulate epilogue.
pub(super) fn nt_row_scaled_add(arow: &[f32], bd: &[f32], alpha: f32, orow: &mut [f32]) {
    let k = arow.len();
    let bp = bd.as_ptr();
    for (j, o) in orow.iter_mut().enumerate() {
        *o += alpha * dot1(arow, unsafe { bp.add(j * k) });
    }
}

/// Fused SwiGLU row: `orow[j] = silu(arow · wg_j) · (arow · wu_j)`.
pub(super) fn nt_row_swiglu(arow: &[f32], wg: &[f32], wu: &[f32], orow: &mut [f32]) {
    let k = arow.len();
    let gp = wg.as_ptr();
    let up = wu.as_ptr();
    for (j, o) in orow.iter_mut().enumerate() {
        let sg = dot1(arow, unsafe { gp.add(j * k) });
        let su = dot1(arow, unsafe { up.add(j * k) });
        *o = silu(sg) * su;
    }
}

/// One dense output row of `A @ B`: broadcast `a[kk]`, FMA into 16/4/scalar
/// column tiles of the output row.
pub(super) fn nn_row(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    let k = arow.len();
    let ap = arow.as_ptr();
    let bp = bd.as_ptr();
    let op = orow.as_mut_ptr();
    unsafe {
        let mut j = 0;
        while j + 16 <= n {
            let mut c0 = vdupq_n_f32(0.0);
            let mut c1 = vdupq_n_f32(0.0);
            let mut c2 = vdupq_n_f32(0.0);
            let mut c3 = vdupq_n_f32(0.0);
            for kk in 0..k {
                let av = vdupq_n_f32(*ap.add(kk));
                let base = bp.add(kk * n + j);
                c0 = vfmaq_f32(c0, av, vld1q_f32(base));
                c1 = vfmaq_f32(c1, av, vld1q_f32(base.add(4)));
                c2 = vfmaq_f32(c2, av, vld1q_f32(base.add(8)));
                c3 = vfmaq_f32(c3, av, vld1q_f32(base.add(12)));
            }
            vst1q_f32(op.add(j), c0);
            vst1q_f32(op.add(j + 4), c1);
            vst1q_f32(op.add(j + 8), c2);
            vst1q_f32(op.add(j + 12), c3);
            j += 16;
        }
        while j + 4 <= n {
            let mut c = vdupq_n_f32(0.0);
            for kk in 0..k {
                c = vfmaq_f32(c, vdupq_n_f32(*ap.add(kk)), vld1q_f32(bp.add(kk * n + j)));
            }
            vst1q_f32(op.add(j), c);
            j += 4;
        }
        while j < n {
            let mut s = 0.0f32;
            for kk in 0..k {
                s += *ap.add(kk) * *bp.add(kk * n + j);
            }
            *op.add(j) = s;
            j += 1;
        }
    }
}

/// One output row of `aᵀ @ b` (`a` read down column `i` with stride `m`),
/// zero-skip preserved for the sparse Theorem-1 operands.
pub(super) fn tn_row(ad: &[f32], m: usize, k: usize, i: usize, bd: &[f32], orow: &mut [f32]) {
    let n = orow.len();
    orow.fill(0.0);
    let bp = bd.as_ptr();
    let op = orow.as_mut_ptr();
    for kk in 0..k {
        let av = ad[kk * m + i];
        if av == 0.0 {
            continue; // routing masses are top-K sparse
        }
        unsafe {
            let avv = vdupq_n_f32(av);
            let brow = bp.add(kk * n);
            let mut j = 0;
            while j + 4 <= n {
                let o = vld1q_f32(op.add(j));
                vst1q_f32(op.add(j), vfmaq_f32(o, avv, vld1q_f32(brow.add(j))));
                j += 4;
            }
            while j < n {
                *op.add(j) += av * *brow.add(j);
                j += 1;
            }
        }
    }
}

/// Mixed-precision dot `Σ l[i]·c[i]` accumulated in f64 (2-lane FMA chain,
/// horizontal sum, scalar tail).
pub(super) fn dot_f64(l: &[f32], c: &[f32]) -> f64 {
    let k = l.len();
    debug_assert_eq!(k, c.len());
    let lp = l.as_ptr();
    let cp = c.as_ptr();
    unsafe {
        let mut acc = vdupq_n_f64(0.0);
        let mut kk = 0;
        while kk + 2 <= k {
            let lv = vcvt_f64_f32(vld1_f32(lp.add(kk)));
            let cv = vcvt_f64_f32(vld1_f32(cp.add(kk)));
            acc = vfmaq_f64(acc, lv, cv);
            kk += 2;
        }
        let mut s = vaddvq_f64(acc);
        while kk < k {
            s += *lp.add(kk) as f64 * *cp.add(kk) as f64;
            kk += 1;
        }
        s
    }
}
