//! AVX2 + FMA microkernels (x86_64). 8-lane f32 FMA everywhere, 4-lane f64
//! FMA for the triangular-solve dots.
//!
//! Every function is `unsafe` + `#[target_feature(enable = "avx2,fma")]`;
//! the dispatcher in `kernel::mod` only reaches them after
//! `is_x86_feature_detected!` has confirmed both features, so the only
//! remaining obligations are the slice-shape contracts documented per
//! function (all enforced by the `tensor::ops` wrappers).
//!
//! The same two invariants as the scalar family hold:
//!
//! * **Row independence** — each output row's instruction sequence depends
//!   only on its own A row, the B operand and the shape.
//! * **Grouping invariance** — a column dot is always `fma` over 8-wide
//!   k-chunks in order, one horizontal sum, then the scalar k-tail —
//!   identical whether the column sits in a multi-column group, a single
//!   column, or a SYRK-truncated row.
//!
//! FMA contracts the multiply-add rounding step, so these kernels are *not*
//! bit-identical to the scalar family — `tests/kernel_consistency.rs` pins
//! the tolerance. Within this family, results are bit-identical across
//! thread counts and row positions.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;

use super::silu;

/// Fixed-order horizontal sum of 8 lanes: (lo+hi) quad, then pairwise.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hsum8(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps(v, 1);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
    _mm_cvtss_f32(s)
}

/// One column dot with the canonical sequence: 8-wide FMA chain, horizontal
/// sum, scalar tail. Every multi-column group below replays exactly this
/// per-column sequence.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot1(a: *const f32, b: *const f32, k: usize) -> f32 {
    let mut acc = _mm256_setzero_ps();
    let mut kk = 0;
    while kk + 8 <= k {
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(kk)), _mm256_loadu_ps(b.add(kk)), acc);
        kk += 8;
    }
    let mut s = hsum8(acc);
    while kk < k {
        s += *a.add(kk) * *b.add(kk);
        kk += 1;
    }
    s
}

/// Four column dots sharing one stream of `arow` (4 independent 8-lane
/// accumulators, one horizontal sum each, shared scalar k-tail). The single
/// copy of this loop carries the grouping-invariance contract: per column
/// it is exactly [`dot1`]'s sequence, and every `A @ Bᵀ` epilogue below
/// reuses it verbatim.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot4(
    a: *const f32,
    b0: *const f32,
    b1: *const f32,
    b2: *const f32,
    b3: *const f32,
    k: usize,
) -> (f32, f32, f32, f32) {
    let mut c0 = _mm256_setzero_ps();
    let mut c1 = _mm256_setzero_ps();
    let mut c2 = _mm256_setzero_ps();
    let mut c3 = _mm256_setzero_ps();
    let mut kk = 0;
    while kk + 8 <= k {
        let av = _mm256_loadu_ps(a.add(kk));
        c0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.add(kk)), c0);
        c1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.add(kk)), c1);
        c2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.add(kk)), c2);
        c3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.add(kk)), c3);
        kk += 8;
    }
    let mut s0 = hsum8(c0);
    let mut s1 = hsum8(c1);
    let mut s2 = hsum8(c2);
    let mut s3 = hsum8(c3);
    while kk < k {
        let av = *a.add(kk);
        s0 += av * *b0.add(kk);
        s1 += av * *b1.add(kk);
        s2 += av * *b2.add(kk);
        s3 += av * *b3.add(kk);
        kk += 1;
    }
    (s0, s1, s2, s3)
}

/// `orow[j] = arow · b_j` for row-major `b` (n, k): 4 columns per pass
/// share one stream of `arow`.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn nt_row(arow: &[f32], bd: &[f32], orow: &mut [f32]) {
    let k = arow.len();
    let n = orow.len();
    let ap = arow.as_ptr();
    let bp = bd.as_ptr();
    let mut j = 0;
    while j + 4 <= n {
        let (s0, s1, s2, s3) = dot4(
            ap,
            bp.add(j * k),
            bp.add((j + 1) * k),
            bp.add((j + 2) * k),
            bp.add((j + 3) * k),
            k,
        );
        orow[j] = s0;
        orow[j + 1] = s1;
        orow[j + 2] = s2;
        orow[j + 3] = s3;
        j += 4;
    }
    while j < n {
        orow[j] = dot1(ap, bp.add(j * k), k);
        j += 1;
    }
}

/// [`nt_row`] with the scale-and-accumulate epilogue
/// `orow[j] += alpha · dot`.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn nt_row_scaled_add(arow: &[f32], bd: &[f32], alpha: f32, orow: &mut [f32]) {
    let k = arow.len();
    let n = orow.len();
    let ap = arow.as_ptr();
    let bp = bd.as_ptr();
    let mut j = 0;
    while j + 4 <= n {
        let (s0, s1, s2, s3) = dot4(
            ap,
            bp.add(j * k),
            bp.add((j + 1) * k),
            bp.add((j + 2) * k),
            bp.add((j + 3) * k),
            k,
        );
        orow[j] += alpha * s0;
        orow[j + 1] += alpha * s1;
        orow[j + 2] += alpha * s2;
        orow[j + 3] += alpha * s3;
        j += 4;
    }
    while j < n {
        orow[j] += alpha * dot1(ap, bp.add(j * k), k);
        j += 1;
    }
}

/// Fused SwiGLU row: `orow[j] = silu(arow · wg_j) · (arow · wu_j)`, two
/// gate + two up columns per [`dot4`] pass sharing one stream of `arow`.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn nt_row_swiglu(arow: &[f32], wg: &[f32], wu: &[f32], orow: &mut [f32]) {
    let k = arow.len();
    let f = orow.len();
    let ap = arow.as_ptr();
    let gp = wg.as_ptr();
    let up = wu.as_ptr();
    let mut j = 0;
    while j + 2 <= f {
        let (sg0, sg1, su0, su1) = dot4(
            ap,
            gp.add(j * k),
            gp.add((j + 1) * k),
            up.add(j * k),
            up.add((j + 1) * k),
            k,
        );
        orow[j] = silu(sg0) * su0;
        orow[j + 1] = silu(sg1) * su1;
        j += 2;
    }
    while j < f {
        let sg = dot1(ap, gp.add(j * k), k);
        let su = dot1(ap, up.add(j * k), k);
        orow[j] = silu(sg) * su;
        j += 1;
    }
}

/// One dense output row of `A @ B` (direct, unpacked): broadcast `a[kk]`,
/// FMA into 32/8/scalar column tiles of the output row.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn nn_row(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    let k = arow.len();
    let ap = arow.as_ptr();
    let bp = bd.as_ptr();
    let op = orow.as_mut_ptr();
    let mut j = 0;
    while j + 32 <= n {
        let mut c0 = _mm256_setzero_ps();
        let mut c1 = _mm256_setzero_ps();
        let mut c2 = _mm256_setzero_ps();
        let mut c3 = _mm256_setzero_ps();
        for kk in 0..k {
            let av = _mm256_set1_ps(*ap.add(kk));
            let base = bp.add(kk * n + j);
            c0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(base), c0);
            c1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(base.add(8)), c1);
            c2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(base.add(16)), c2);
            c3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(base.add(24)), c3);
        }
        _mm256_storeu_ps(op.add(j), c0);
        _mm256_storeu_ps(op.add(j + 8), c1);
        _mm256_storeu_ps(op.add(j + 16), c2);
        _mm256_storeu_ps(op.add(j + 24), c3);
        j += 32;
    }
    while j + 8 <= n {
        let mut c = _mm256_setzero_ps();
        for kk in 0..k {
            let av = _mm256_set1_ps(*ap.add(kk));
            c = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(kk * n + j)), c);
        }
        _mm256_storeu_ps(op.add(j), c);
        j += 8;
    }
    while j < n {
        let mut s = 0.0f32;
        for kk in 0..k {
            s += *ap.add(kk) * *bp.add(kk * n + j);
        }
        *op.add(j) = s;
        j += 1;
    }
}

/// One output row of `aᵀ @ b` (`a` read down column `i` with stride `m`),
/// zero-skip preserved for the sparse Theorem-1 operands.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn tn_row(
    ad: &[f32],
    m: usize,
    k: usize,
    i: usize,
    bd: &[f32],
    orow: &mut [f32],
) {
    let n = orow.len();
    orow.fill(0.0);
    let bp = bd.as_ptr();
    let op = orow.as_mut_ptr();
    for kk in 0..k {
        let av = ad[kk * m + i];
        if av == 0.0 {
            continue; // routing masses are top-K sparse
        }
        let avv = _mm256_set1_ps(av);
        let brow = bp.add(kk * n);
        let mut j = 0;
        while j + 8 <= n {
            let o = _mm256_loadu_ps(op.add(j));
            _mm256_storeu_ps(op.add(j), _mm256_fmadd_ps(avv, _mm256_loadu_ps(brow.add(j)), o));
            j += 8;
        }
        while j < n {
            *op.add(j) += av * *brow.add(j);
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Packed A @ B path (see `kernel::gemm_nn`): B k-panels are packed into
// 16-column strips so the inner loop streams contiguous memory.
// ---------------------------------------------------------------------------

/// One output row × one *zero-padded tail* panel (width `w` < 16): the
/// accumulators round-trip through a 16-wide stack buffer so partial sums
/// are stored in f32 per k-block exactly like the full-panel path.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn packed_tail_row(
    arow: *const f32,
    kb: usize,
    kc: usize,
    pb: *const f32,
    otail: *mut f32,
    w: usize,
    first: bool,
) {
    let mut tmp = [0.0f32; 16];
    if !first {
        for (c, t) in tmp.iter_mut().enumerate().take(w) {
            *t = *otail.add(c);
        }
    }
    let mut c0 = _mm256_loadu_ps(tmp.as_ptr());
    let mut c1 = _mm256_loadu_ps(tmp.as_ptr().add(8));
    for kk in 0..kc {
        let av = _mm256_set1_ps(*arow.add(kb + kk));
        c0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(pb.add(kk * 16)), c0);
        c1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(pb.add(kk * 16 + 8)), c1);
    }
    _mm256_storeu_ps(tmp.as_mut_ptr(), c0);
    _mm256_storeu_ps(tmp.as_mut_ptr().add(8), c1);
    for (c, t) in tmp.iter().enumerate().take(w) {
        *otail.add(c) = *t;
    }
}

/// One output row over every packed panel (the `rows < 4` fallback; the
/// per-row instruction sequence matches the quad kernel exactly).
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn packed_row(
    arow: *const f32,
    kb: usize,
    kc: usize,
    pp: *const f32,
    np: usize,
    n: usize,
    orow: *mut f32,
    first: bool,
) {
    for p in 0..np {
        let j0 = p * 16;
        let w = (n - j0).min(16);
        let pb = pp.add(p * kc * 16);
        if w == 16 {
            let (mut c0, mut c1) = if first {
                (_mm256_setzero_ps(), _mm256_setzero_ps())
            } else {
                (_mm256_loadu_ps(orow.add(j0)), _mm256_loadu_ps(orow.add(j0 + 8)))
            };
            for kk in 0..kc {
                let av = _mm256_set1_ps(*arow.add(kb + kk));
                c0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(pb.add(kk * 16)), c0);
                c1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(pb.add(kk * 16 + 8)), c1);
            }
            _mm256_storeu_ps(orow.add(j0), c0);
            _mm256_storeu_ps(orow.add(j0 + 8), c1);
        } else {
            packed_tail_row(arow, kb, kc, pb, orow.add(j0), w, first);
        }
    }
}

/// Accumulate `rows` (1..=4) output rows for one k-block from packed B
/// panels. `ablock` holds the rows' full A rows (stride `lda`); the k-block
/// starts at `kb` and spans `kc` of it. `oblock` holds the rows' output
/// (stride `n`). Overwrites when `first`, accumulates the stored f32
/// partials otherwise — so each output element is reduced in plain `kk`
/// order across k-blocks, independent of threading.
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn nn_packed_chunk(
    ablock: &[f32],
    lda: usize,
    kb: usize,
    kc: usize,
    packed: &[f32],
    n: usize,
    oblock: &mut [f32],
    rows: usize,
    first: bool,
) {
    let np = (n + 15) / 16;
    let ap = ablock.as_ptr();
    let op = oblock.as_mut_ptr();
    let pp = packed.as_ptr();
    if rows < 4 {
        for r in 0..rows {
            packed_row(ap.add(r * lda), kb, kc, pp, np, n, op.add(r * n), first);
        }
        return;
    }
    for p in 0..np {
        let j0 = p * 16;
        let w = (n - j0).min(16);
        let pb = pp.add(p * kc * 16);
        if w < 16 {
            for r in 0..4 {
                packed_tail_row(ap.add(r * lda), kb, kc, pb, op.add(r * n + j0), w, first);
            }
            continue;
        }
        // 4 rows × 2 accumulator vectors; one packed-B load pair feeds all
        // four rows.
        let mut acc = [[_mm256_setzero_ps(); 2]; 4];
        if !first {
            for (r, a) in acc.iter_mut().enumerate() {
                a[0] = _mm256_loadu_ps(op.add(r * n + j0));
                a[1] = _mm256_loadu_ps(op.add(r * n + j0 + 8));
            }
        }
        for kk in 0..kc {
            let b0 = _mm256_loadu_ps(pb.add(kk * 16));
            let b1 = _mm256_loadu_ps(pb.add(kk * 16 + 8));
            for (r, a) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*ap.add(r * lda + kb + kk));
                a[0] = _mm256_fmadd_ps(av, b0, a[0]);
                a[1] = _mm256_fmadd_ps(av, b1, a[1]);
            }
        }
        for (r, a) in acc.iter().enumerate() {
            _mm256_storeu_ps(op.add(r * n + j0), a[0]);
            _mm256_storeu_ps(op.add(r * n + j0 + 8), a[1]);
        }
    }
}

/// Mixed-precision dot `Σ l[i]·c[i]` accumulated in f64 (4-lane FMA chain,
/// fixed-order horizontal sum, scalar tail) — the triangular-solve panels.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn dot_f64(l: &[f32], c: &[f32]) -> f64 {
    let k = l.len();
    debug_assert_eq!(k, c.len());
    let lp = l.as_ptr();
    let cp = c.as_ptr();
    let mut acc = _mm256_setzero_pd();
    let mut kk = 0;
    while kk + 4 <= k {
        let lv = _mm256_cvtps_pd(_mm_loadu_ps(lp.add(kk)));
        let cv = _mm256_cvtps_pd(_mm_loadu_ps(cp.add(kk)));
        acc = _mm256_fmadd_pd(lv, cv, acc);
        kk += 4;
    }
    let lo = _mm256_castpd256_pd128(acc);
    let hi = _mm256_extractf128_pd(acc, 1);
    let s2 = _mm_add_pd(lo, hi);
    let s1 = _mm_add_sd(s2, _mm_unpackhi_pd(s2, s2));
    let mut s = _mm_cvtsd_f64(s1);
    while kk < k {
        s += *lp.add(kk) as f64 * *cp.add(kk) as f64;
        kk += 1;
    }
    s
}
