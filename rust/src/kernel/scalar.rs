//! Scalar reference microkernels — the seed repo's register-tiled loops,
//! preserved **bit for bit**. `MERGEMOE_KERNEL=scalar` therefore reproduces
//! the pre-kernel-layer numerics exactly, and every SIMD family is tested
//! against these functions (`tests/kernel_consistency.rs`).
//!
//! Two invariants every function here upholds (the SIMD twins must too):
//!
//! * **Row independence** — an output row's arithmetic depends only on its
//!   own A row, the B operand and the shape, never on the row's position,
//!   so work can be split across threads at any boundary.
//! * **Grouping invariance** — a column's dot product is accumulated with
//!   the same instruction sequence whether the column sits in a 4-wide
//!   group or the tail loop, so restricting the column range (the SYRK
//!   lower triangle) yields exactly the full-product values.
//!
//! The invariance is structural: every `A @ Bᵀ`-shaped kernel below calls
//! the same [`dot4`]/[`dot`] cores and differs only in its store epilogue.

use super::silu;

/// One dense output row of `A @ B`: `orow = arow @ b`, 4 `a` entries per
/// sweep so the inner loop is a branch-free chain of independent
/// multiply-adds (the seed `matmul_row`).
pub(super) fn nn_row(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    orow.fill(0.0);
    let k = arow.len();
    let mut kk = 0;
    while kk + 4 <= k {
        let a0 = arow[kk];
        let a1 = arow[kk + 1];
        let a2 = arow[kk + 2];
        let a3 = arow[kk + 3];
        let b0 = &bd[kk * n..kk * n + n];
        let b1 = &bd[(kk + 1) * n..(kk + 1) * n + n];
        let b2 = &bd[(kk + 2) * n..(kk + 2) * n + n];
        let b3 = &bd[(kk + 3) * n..(kk + 3) * n + n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
        }
        kk += 4;
    }
    while kk < k {
        let av = arow[kk];
        let brow = &bd[kk * n..kk * n + n];
        for (o, &bv) in orow.iter_mut().zip(brow) {
            *o += av * bv;
        }
        kk += 1;
    }
}

/// One column dot `arow · b_j` with the seed accumulation order (the 4-wide
/// group of `matmul_bt` accumulated each column independently, so a single
/// sequential sum reproduces it exactly).
#[inline]
fn dot(arow: &[f32], brow: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in arow.iter().zip(brow) {
        acc += x * y;
    }
    acc
}

/// Four column dots sharing one read of `arow` — the seed `matmul_bt`
/// 4-column group. The single copy of this loop carries the
/// grouping-invariance contract: per column it is exactly [`dot`]'s
/// sequential sum, and every `A @ Bᵀ` epilogue below reuses it verbatim.
#[inline]
fn dot4(arow: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> (f32, f32, f32, f32) {
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    for (kk, &av) in arow.iter().enumerate() {
        s0 += av * b0[kk];
        s1 += av * b1[kk];
        s2 += av * b2[kk];
        s3 += av * b3[kk];
    }
    (s0, s1, s2, s3)
}

/// One output row of `A @ Bᵀ` (`b` row-major (n,k)): `orow[j] = arow · b_j`.
pub(super) fn nt_row(arow: &[f32], bd: &[f32], orow: &mut [f32]) {
    let k = arow.len();
    let n = orow.len();
    let mut j = 0;
    while j + 4 <= n {
        let (s0, s1, s2, s3) = dot4(
            arow,
            &bd[j * k..j * k + k],
            &bd[(j + 1) * k..(j + 1) * k + k],
            &bd[(j + 2) * k..(j + 2) * k + k],
            &bd[(j + 3) * k..(j + 3) * k + k],
        );
        orow[j] = s0;
        orow[j + 1] = s1;
        orow[j + 2] = s2;
        orow[j + 3] = s3;
        j += 4;
    }
    while j < n {
        orow[j] = dot(arow, &bd[j * k..j * k + k]);
        j += 1;
    }
}

/// [`nt_row`] with the scale-and-accumulate epilogue:
/// `orow[j] += alpha · (arow · b_j)`. Identical dot arithmetic; the
/// epilogue matches the old `axpy`/scatter element update (`o += w * y`).
pub(super) fn nt_row_scaled_add(arow: &[f32], bd: &[f32], alpha: f32, orow: &mut [f32]) {
    let k = arow.len();
    let n = orow.len();
    let mut j = 0;
    while j + 4 <= n {
        let (s0, s1, s2, s3) = dot4(
            arow,
            &bd[j * k..j * k + k],
            &bd[(j + 1) * k..(j + 1) * k + k],
            &bd[(j + 2) * k..(j + 2) * k + k],
            &bd[(j + 3) * k..(j + 3) * k + k],
        );
        orow[j] += alpha * s0;
        orow[j + 1] += alpha * s1;
        orow[j + 2] += alpha * s2;
        orow[j + 3] += alpha * s3;
        j += 4;
    }
    while j < n {
        orow[j] += alpha * dot(arow, &bd[j * k..j * k + k]);
        j += 1;
    }
}

/// One output row of the fused SwiGLU panel:
/// `orow[j] = silu(arow · wg_j) · (arow · wu_j)` — both dots accumulated in
/// one [`dot4`] pass (two gate + two up columns), each with the seed
/// per-column order, so the result equals the unfused two-GEMM +
/// elementwise path bit for bit.
pub(super) fn nt_row_swiglu(arow: &[f32], wg: &[f32], wu: &[f32], orow: &mut [f32]) {
    let k = arow.len();
    let f = orow.len();
    let mut j = 0;
    while j + 2 <= f {
        let (sg0, sg1, su0, su1) = dot4(
            arow,
            &wg[j * k..j * k + k],
            &wg[(j + 1) * k..(j + 1) * k + k],
            &wu[j * k..j * k + k],
            &wu[(j + 1) * k..(j + 1) * k + k],
        );
        orow[j] = silu(sg0) * su0;
        orow[j + 1] = silu(sg1) * su1;
        j += 2;
    }
    while j < f {
        let sg = dot(arow, &wg[j * k..j * k + k]);
        let su = dot(arow, &wu[j * k..j * k + k]);
        orow[j] = silu(sg) * su;
        j += 1;
    }
}

/// One output row of `aᵀ @ b` (`a` row-major (k,m), read down column `i`)
/// with the seed zero-skip — Theorem-1 usage/assignment masses arrive
/// sparse on this path.
pub(super) fn tn_row(ad: &[f32], m: usize, k: usize, i: usize, bd: &[f32], orow: &mut [f32]) {
    let n = orow.len();
    orow.fill(0.0);
    for kk in 0..k {
        let av = ad[kk * m + i];
        if av == 0.0 {
            continue; // routing masses are top-K sparse
        }
        let brow = &bd[kk * n..kk * n + n];
        for (o, &bv) in orow.iter_mut().zip(brow) {
            *o += av * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nt_grouping_invariance() {
        // column j's value must not depend on whether it sat in a 4-group
        // or the tail: shrink n from 6 to 5 and compare the shared prefix
        let a: Vec<f32> = (0..7).map(|i| 0.3 * i as f32 - 1.0).collect();
        let b: Vec<f32> = (0..6 * 7).map(|i| (i as f32 * 0.11).sin()).collect();
        let mut full = vec![0.0f32; 6];
        nt_row(&a, &b[..6 * 7], &mut full);
        let mut partial = vec![0.0f32; 5];
        nt_row(&a, &b[..5 * 7], &mut partial);
        assert_eq!(&full[..5], &partial[..]);
    }

    #[test]
    fn scaled_add_accumulates() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0, 5.0, 6.0]; // two rows of k=2
        let mut out = [10.0f32, 20.0];
        nt_row_scaled_add(&a, &b, 0.5, &mut out);
        // dots: 1*3+2*4=11, 1*5+2*6=17
        assert_eq!(out, [10.0 + 0.5 * 11.0, 20.0 + 0.5 * 17.0]);
    }

    #[test]
    fn swiglu_matches_unfused() {
        let a: Vec<f32> = (0..9).map(|i| 0.2 * i as f32 - 0.7).collect();
        let wg: Vec<f32> = (0..5 * 9).map(|i| (i as f32 * 0.07).cos()).collect();
        let wu: Vec<f32> = (0..5 * 9).map(|i| (i as f32 * 0.13).sin()).collect();
        let mut fused = vec![0.0f32; 5];
        nt_row_swiglu(&a, &wg, &wu, &mut fused);
        let mut g = vec![0.0f32; 5];
        let mut u = vec![0.0f32; 5];
        nt_row(&a, &wg, &mut g);
        nt_row(&a, &wu, &mut u);
        for j in 0..5 {
            assert_eq!(fused[j], silu(g[j]) * u[j], "col {j}");
        }
    }
}
