//! Figures 2–5 of the paper's evaluation.

use anyhow::Result;

use super::report::save;
use super::Ctx;
use crate::coordinator::{compress, CompressSpec};
use crate::eval::tasks::Task;
use crate::merge::Algorithm;
use crate::util::json::Json;

/// ASCII bar chart for figure-style outputs.
fn bars(series: &[(String, f64)], unit: &str) {
    let max = series.iter().map(|(_, v)| *v).fold(0.0f64, f64::max).max(1e-9);
    for (label, v) in series {
        let n = ((v / max) * 40.0).round() as usize;
        println!("  {label:<24} {:<40} {v:.2}{unit}", "█".repeat(n));
    }
}

/// Fig. 2a — accuracy vs number of *reduced* experts, fixed merged-layer set
/// (`beta`, layers 2–3; the paper fixes 14 layers on Qwen1.5 and varies the
/// expert count; scored on the WinoGrande analogue `parity`).
pub fn fig2a(ctx: &Ctx) -> Result<()> {
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;
    let sweep = [12usize, 10, 8, 6, 4, 3, 2];
    let mut series = Vec::new();
    for &m in &sweep {
        let acc = if m == model.cfg.n_experts {
            ctx.eval_suite(engine.as_mut(), &model, &[Task::Maj])?["maj"]
        } else {
            let mut cs = CompressSpec::new(vec![0, 1, 2, 3], m, Algorithm::MergeMoe);
            cs.n_calib_seqs = 64;
            cs.seed = ctx.seed ^ 0xF2A;
            let mut gram = ctx.make_gram("beta")?;
            let (merged, _) = compress(&model, &cs, &mut gram.as_backend())?;
            ctx.eval_suite(engine.as_mut(), &merged, &[Task::Maj])?["maj"]
        };
        series.push((format!("experts {} -> {m}", model.cfg.n_experts), acc.percent()));
    }
    println!("\nfig2a: accuracy vs reduced expert count (beta, all layers, maj)");
    bars(&series, "%");
    save(ctx, "fig2a", Json::Obj(
        series.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
    ))
}

/// Fig. 2b — accuracy vs number of *compressed layers*, fixed expert target
/// (`beta`, 12 → 6; layers added back to front as in the paper).
pub fn fig2b(ctx: &Ctx) -> Result<()> {
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;
    let layer_sets: Vec<Vec<usize>> =
        vec![vec![], vec![3], vec![2, 3], vec![1, 2, 3], vec![0, 1, 2, 3]];
    let mut series = Vec::new();
    for layers in &layer_sets {
        let acc = if layers.is_empty() {
            ctx.eval_suite(engine.as_mut(), &model, &[Task::Maj])?["maj"]
        } else {
            let mut cs = CompressSpec::new(layers.clone(), 6, Algorithm::MergeMoe);
            cs.n_calib_seqs = 64;
            cs.seed = ctx.seed ^ 0xF2B;
            let mut gram = ctx.make_gram("beta")?;
            let (merged, _) = compress(&model, &cs, &mut gram.as_backend())?;
            ctx.eval_suite(engine.as_mut(), &merged, &[Task::Maj])?["maj"]
        };
        series.push((format!("{} layers merged", layers.len()), acc.percent()));
    }
    println!("\nfig2b: accuracy vs compressed layer count (beta, 12->6, maj)");
    bars(&series, "%");
    save(ctx, "fig2b", Json::Obj(
        series.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
    ))
}

/// Fig. 3 — merging-time cost: MergeMoE vs M-SMoE on the same layer set
/// (`beta`, 12 → 6, 128 calibration sequences as in the paper's batch-128
/// setting). Also regenerated as `benches/bench_merge.rs`.
pub fn fig3(ctx: &Ctx) -> Result<()> {
    let model = ctx.load_model("beta")?;
    let mut series = Vec::new();
    for alg in [Algorithm::MSmoe, Algorithm::MergeMoe] {
        let mut cs = CompressSpec::new(vec![0, 1, 2, 3], 6, alg);
        cs.n_calib_seqs = 128;
        cs.seed = ctx.seed ^ 0xF30;
        let mut gram = ctx.make_gram("beta")?;
        let t0 = std::time::Instant::now();
        let (_, rep) = compress(&model, &cs, &mut gram.as_backend())?;
        let total = t0.elapsed().as_secs_f64();
        series.push((format!("{} merge", alg.name()), rep.merge_seconds));
        series.push((format!("{} total(+calib)", alg.name()), total));
    }
    println!("\nfig3: merging time cost (beta, all layers, 12->6, 128 calib seqs)");
    bars(&series, "s");
    save(ctx, "fig3", Json::Obj(
        series.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
    ))
}

/// Fig. 4 — accuracy vs calibration sample count, including the
/// below-threshold failure regime (`beta`; the rank threshold sits at
/// d_ff = 64 calibration tokens — the analogue of the paper's 32-sample
/// threshold).
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;
    let token_counts = [8usize, 16, 32, 48, 64, 96, 128, 256, 512, 1024, 4096];
    let mut series = Vec::new();
    for &toks in &token_counts {
        let mut cs = CompressSpec::new(vec![0, 1, 2, 3], 6, Algorithm::MergeMoe);
        cs.n_calib_seqs = toks.div_ceil(64).max(1) * 2; // capture enough, then cap
        cs.max_calib_tokens = Some(toks);
        cs.seed = ctx.seed ^ 0xF40;
        let mut gram = ctx.make_gram("beta")?;
        let (merged, _) = compress(&model, &cs, &mut gram.as_backend())?;
        let acc = ctx.eval_suite(engine.as_mut(), &merged, &[Task::Maj])?["maj"];
        series.push((format!("{toks} tokens"), acc.percent()));
    }
    println!(
        "\nfig4: accuracy vs calibration sample count (beta, 12->6, maj; \
         threshold expected near d_ff=64 tokens)"
    );
    bars(&series, "%");
    save(ctx, "fig4", Json::Obj(
        series.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
    ))
}

/// Fig. 5 — generative/instruction-following analogue with knowledge
/// distillation: the compressed model is evaluated on a held-out mixed-task
/// suite before and after distilling the full model's logits into the
/// merged experts' down-projections (the closed-form refit below is the
/// coordinate-descent analogue of the paper's ShareGPT logit distillation;
/// see exp::figures::distill_wd).
pub fn fig5(ctx: &Ctx) -> Result<()> {
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;
    let tasks = super::paper_task_order();

    let mut cs = CompressSpec::new(vec![0, 1, 2, 3], 6, Algorithm::MergeMoe);
    cs.n_calib_seqs = 8; // deliberately small so distillation has headroom
    cs.seed = ctx.seed ^ 0xF50;
    let mut gram = ctx.make_gram("beta")?;
    let (merged, _) = compress(&model, &cs, &mut gram.as_backend())?;

    let mean = |m: &std::collections::BTreeMap<&'static str, crate::eval::Accuracy>| {
        m.values().map(|a| a.percent()).sum::<f64>() / m.len() as f64
    };
    let acc_before = ctx.eval_suite(engine.as_mut(), &merged, &tasks)?;
    let m_before = mean(&acc_before);

    // distillation: refit every merged W_D against the *teacher layer
    // output* on a fresh, larger corpus (the samples the merge never saw)
    let distilled = distill_wd(ctx, &model, &merged, 192)?;
    let acc_after = ctx.eval_suite(engine.as_mut(), &distilled, &tasks)?;
    let m_after = mean(&acc_after);

    let full_acc = ctx.eval_suite(engine.as_mut(), &model, &tasks)?;
    let series = vec![
        ("Full model".to_string(), mean(&full_acc)),
        ("Compressed (8 calib seqs)".to_string(), m_before),
        ("Compressed + distillation".to_string(), m_after),
    ];
    println!("\nfig5: distillation boost on the compressed model (beta, mean over 7 tasks)");
    bars(&series, "%");
    save(ctx, "fig5", Json::Obj(
        series.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
    ))
}

/// Knowledge distillation of the merged layers: with gate/up projections and
/// routing frozen, the student's MoE-layer output is linear in each merged
/// `W_D`, so matching the teacher's layer output in L2 over a distillation
/// corpus is again a least-squares problem per expert — the closed-form
/// equivalent of gradient distillation on this parameter subset.
pub fn distill_wd(
    ctx: &Ctx,
    teacher: &crate::model::ModelWeights,
    student: &crate::model::ModelWeights,
    n_seqs: usize,
) -> Result<crate::model::ModelWeights> {
    use crate::calib;
    use crate::linalg;
    use crate::model::native;
    use crate::tensor::{ops, Tensor};

    let seq_len = ctx.manifest.seq_len;
    let tokens = calib::sample_sequences(None, n_seqs, seq_len, ctx.seed ^ 0xD157);
    let tcap = calib::capture(teacher, &tokens, n_seqs, seq_len)?;
    let mut out = student.clone();
    for (li, layer) in student.layers.iter().enumerate() {
        if layer.moe.map.is_none() {
            continue; // only merged layers are distilled
        }
        let x = &tcap.layers[li].x;
        // teacher target: full layer output
        let (y_t, _, _) = native::moe_forward(&teacher.layers[li].moe, x)?;
        // The shared expert is frozen and identical in teacher and student:
        // distill the *routed* part only, subtracting its output from BOTH
        // sides (target here, student output below).
        let shared_out = match &layer.moe.shared {
            Some(sh) => Some(native::expert_forward(sh, x)?),
            None => None,
        };
        let mut target = y_t;
        if let Some(ys) = &shared_out {
            target = target.sub(ys)?;
        }
        // student routing (frozen): dense (t, m) weights
        let routing = crate::moe::routing::route_tokens(&layer.moe.router, x, layer.moe.top_k)?;
        let n = layer.moe.router.shape()[0];
        let mut r = Tensor::zeros(&[x.shape()[0], n]);
        for (ti, tok) in routing.iter().enumerate() {
            for &(ei, w) in tok {
                *r.at2_mut(ti, ei) = w;
            }
        }
        let r = ops::matmul_bt(&r, layer.moe.map.as_ref().unwrap())?; // (t, m)
        // per merged expert e: rows where r[:,e] != 0 contribute
        //   r_te * W_D h_e(x_t)  — solve W_D against the residual target,
        // coordinate-descent style (re-evaluating the student between
        // expert refits so each solve sees the latest other-expert output)
        let n_experts = out.layers[li].moe.experts.len();
        for ei in 0..n_experts {
            let rows: Vec<usize> =
                (0..x.shape()[0]).filter(|&t| r.at2(t, ei) != 0.0).collect();
            let ex = out.layers[li].moe.experts[ei].clone();
            if rows.len() < ex.wg.shape()[0] {
                continue; // not enough support to refit
            }
            // gather inputs & weights
            let mut xs = Tensor::zeros(&[rows.len(), x.shape()[1]]);
            let mut ws = Vec::with_capacity(rows.len());
            for (k, &t) in rows.iter().enumerate() {
                xs.row_mut(k).copy_from_slice(x.row(t));
                ws.push(r.at2(t, ei));
            }
            // target residual: remove the other experts' current contribution
            let (y_s_full, _, _) = native::moe_forward(&out.layers[li].moe, x)?;
            let y_s = match &shared_out {
                Some(ys) => y_s_full.sub(ys)?, // routed part of the student
                None => y_s_full,
            };
            let mut resid = Tensor::zeros(&[rows.len(), x.shape()[1]]);
            let own = native::expert_forward(&ex, &xs)?;
            for (k, &t) in rows.iter().enumerate() {
                for c in 0..x.shape()[1] {
                    // target minus (student output minus own contribution)
                    let other = y_s.at2(t, c) - ws[k] * own.at2(k, c);
                    *resid.at2_mut(k, c) = target.at2(t, c) - other;
                }
            }
            // rows scaled by weight: solve  (w ⊗ h) W_Dᵀ = resid
            let mut h = native::expert_inner(&ex, &xs)?; // (rows, f)
            for (k, &w) in ws.iter().enumerate() {
                for v in h.row_mut(k) {
                    *v *= w;
                }
            }
            let p = ops::transpose(&h)?; // (f, rows)
            let y = ops::transpose(&resid)?; // (d, rows)
            out.layers[li].moe.experts[ei].wd = linalg::lstsq_rows(&p, &y, 1e-6)?;
        }
    }
    out.touch();
    Ok(out)
}
