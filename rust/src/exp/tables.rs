//! Tables 1–5 of the paper's evaluation (DESIGN.md §5 maps models/settings).

use anyhow::Result;

use super::report::{acc_json, fmt_params, save, TablePrinter};
use super::{paper_task_order, Ctx};
use crate::coordinator::{compress, CompressSpec};
use crate::eval::tasks::Task;
use crate::merge::{Algorithm, COMPARED};
use crate::util::json::Json;

/// Settings for one comparative table (model + compression config).
pub struct TableSpec {
    pub name: &'static str,
    pub model: &'static str,
    pub layers: Vec<usize>,
    pub m: usize,
    pub dense_baselines: Vec<&'static str>,
    pub n_calib_seqs: usize,
}

/// Table 1 analogue — `alpha` (~Qwen3-30B-A3B: no shared expert), back half
/// of the layers, experts 16 → 8.
pub fn table1(ctx: &Ctx) -> Result<()> {
    comparison_table(ctx, &TableSpec {
        name: "table1",
        model: "alpha",
        layers: vec![0, 1, 2, 3],
        m: 8,
        dense_baselines: vec!["dense_a"],
        n_calib_seqs: 40,
    })
}

/// Table 2 analogue — `beta` (~Qwen1.5-MoE-A2.7B: shared expert), 12 → 6.
pub fn table2(ctx: &Ctx) -> Result<()> {
    comparison_table(ctx, &TableSpec {
        name: "table2",
        model: "beta",
        layers: vec![0, 1, 2, 3],
        m: 6,
        dense_baselines: vec!["dense_b4", "dense_b1"],
        n_calib_seqs: 64,
    })
}

/// Table 3 analogue — `gamma` (~DeepSeekMoE-16B: shared expert, top-4),
/// 16 → 7 over the back three layers.
pub fn table3(ctx: &Ctx) -> Result<()> {
    comparison_table(ctx, &TableSpec {
        name: "table3",
        model: "gamma",
        layers: vec![0, 1, 2, 3, 4],
        m: 7,
        dense_baselines: vec![],
        n_calib_seqs: 64,
    })
}

fn comparison_table(ctx: &Ctx, spec: &TableSpec) -> Result<()> {
    let tasks = paper_task_order();
    let mut headers = vec!["Strategies".to_string(), "Model Size".to_string()];
    headers.extend(tasks.iter().map(|t| format!("{} ({})", t.paper_name(), t.name())));
    let mut printer = TablePrinter::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut engine = ctx.make_engine()?;
    let mut records: Vec<(String, Json)> = Vec::new();

    // Full model
    let full = ctx.load_model(spec.model)?;
    let accs = ctx.eval_suite(engine.as_mut(), &full, &tasks)?;
    let mut row = vec!["Full".to_string(), fmt_params(full.n_params())];
    row.extend(tasks.iter().map(|t| format!("{:.2}", accs[t.name()].percent())));
    printer.row(row);
    records.push(("Full".into(), acc_json(&accs)));

    // Dense baselines
    for dense in &spec.dense_baselines {
        let dm = ctx.load_model(dense)?;
        let accs = ctx.eval_suite(engine.as_mut(), &dm, &tasks)?;
        let mut row = vec![format!("Dense ({dense})"), fmt_params(dm.n_params())];
        row.extend(tasks.iter().map(|t| format!("{:.2}", accs[t.name()].percent())));
        printer.row(row);
        records.push((format!("Dense-{dense}"), acc_json(&accs)));
    }

    // Merge algorithms at identical compression ratio
    for alg in COMPARED {
        let mut cs = CompressSpec::new(spec.layers.clone(), spec.m, alg);
        cs.n_calib_seqs = spec.n_calib_seqs;
        cs.seed = ctx.seed ^ 0x5EED;
        let mut gram = ctx.make_gram(spec.model)?;
        let (merged, rep) = compress(&full, &cs, &mut gram.as_backend())?;
        let accs = ctx.eval_suite(engine.as_mut(), &merged, &tasks)?;
        let mut row = vec![alg.name().to_string(), fmt_params(rep.params_after)];
        row.extend(tasks.iter().map(|t| format!("{:.2}", accs[t.name()].percent())));
        printer.row(row);
        let mut j = acc_json(&accs);
        if let Json::Obj(o) = &mut j {
            o.insert("params_after".into(), Json::Num(rep.params_after as f64));
            o.insert("merge_seconds".into(), Json::Num(rep.merge_seconds));
            o.insert(
                "mean_layer_err".into(),
                Json::Num(
                    rep.layers.iter().map(|l| l.output_rel_err).sum::<f64>()
                        / rep.layers.len().max(1) as f64,
                ),
            );
        }
        records.push((alg.name().into(), j));
    }

    println!(
        "\n{}: model={} layers={:?} experts {}->{} ({} items/task, engine={})",
        spec.name, spec.model, spec.layers, full.cfg.n_experts, spec.m, ctx.items,
        match ctx.engine { super::EngineSel::Native => "native", _ => "pjrt" }
    );
    printer.print();
    save(ctx, spec.name, Json::Obj(records.into_iter().map(|(k, v)| (k, v)).collect()))
}

/// Column headers of a sweep table; `with_calib` inserts the calibration
/// source column the flat (single-table) layout needs.
fn sweep_headers(rep: &crate::eval::sweep::SweepReport, with_calib: bool) -> Vec<String> {
    let mut headers = vec!["Method".to_string()];
    if with_calib {
        headers.push("Calib".to_string());
    }
    headers.extend(["m".to_string(), "Params".to_string(), "Ratio".to_string()]);
    if let Some(first) = rep.variants.first() {
        headers.extend(
            first
                .cells
                .iter()
                .map(|c| format!("{} ({})", c.task.paper_name(), c.task.name())),
        );
    }
    headers.push("Mean".to_string());
    headers
}

fn sweep_row(v: &crate::eval::sweep::VariantResult, with_calib: bool) -> Vec<String> {
    let mut row = vec![v.label.clone()];
    if with_calib {
        row.push(v.source.clone());
    }
    row.extend([
        format!("{}", v.m),
        fmt_params(v.params),
        format!("{:.1}%", 100.0 * v.ratio),
    ]);
    row.extend(v.cells.iter().map(|c| format!("{:.2}", c.acc.percent())));
    row.push(format!("{:.2}", v.mean_percent()));
    row
}

/// The accuracy-vs-ratio table of an evaluation sweep, flat: one row per
/// variant (Full first, then each method at each compression ratio under
/// each calibration source), one column per task plus the mean — the same
/// layout Tables 1–3 print, generalized over ratios and calibration
/// sources (the `Calib` column). Multi-source reports usually read better
/// through [`sweep_markdown`]'s per-source sections.
pub fn sweep_table(rep: &crate::eval::sweep::SweepReport) -> TablePrinter {
    let headers = sweep_headers(rep, true);
    let mut t = TablePrinter::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for v in &rep.variants {
        t.row(sweep_row(v, true));
    }
    t
}

/// Markdown for a whole sweep report — what `exp::report::save_sweep`
/// persists as `SWEEP_<model>.md` and `mergemoe sweep` prints. Single
/// source: the flat [`sweep_table`]. Multiple sources (the Table-4 axis):
/// one `###`-headed section per calibration source, each a paper-style
/// table with the source-independent Full row repeated for side-by-side
/// reading.
pub fn sweep_markdown(rep: &crate::eval::sweep::SweepReport) -> String {
    use crate::eval::sweep::FULL_SOURCE;
    if rep.calib_sources.len() <= 1 {
        return sweep_table(rep).render();
    }
    let headers = sweep_headers(rep, false);
    let mut out = String::new();
    for (si, src) in rep.calib_sources.iter().enumerate() {
        if si > 0 {
            out.push('\n');
        }
        out.push_str(&format!("### calibration source: {src}\n\n"));
        let mut t = TablePrinter::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for v in &rep.variants {
            if v.source == *src || v.source == FULL_SOURCE {
                t.row(sweep_row(v, false));
            }
        }
        out.push_str(&t.render());
    }
    out
}

/// Table 4 — cross-dataset generalization of the calibration source
/// (`beta`): merge with samples from a single task, evaluate on all.
pub fn table4(ctx: &Ctx) -> Result<()> {
    let tasks = paper_task_order();
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;
    let mut headers = vec!["Source of Input Samples".to_string()];
    headers.extend(tasks.iter().map(|t| format!("{} ({})", t.paper_name(), t.name())));
    let mut printer = TablePrinter::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut records: Vec<(String, Json)> = Vec::new();

    // Row 1: self-sourced — per evaluated task, calibrate on that task.
    let mut self_row = vec!["Self-Sourced Samples".to_string()];
    let mut self_rec = std::collections::BTreeMap::new();
    for &t in &tasks {
        let mut cs = CompressSpec::new(vec![0, 1, 2, 3], 6, Algorithm::MergeMoe);
        cs.n_calib_seqs = 64;
        cs.calib_tasks = Some(vec![t]);
        cs.seed = ctx.seed ^ 0x7A5;
        let mut gram = ctx.make_gram("beta")?;
        let (merged, _) = compress(&model, &cs, &mut gram.as_backend())?;
        let accs = ctx.eval_suite(engine.as_mut(), &merged, &[t])?;
        self_row.push(format!("{:.2}", accs[t.name()].percent()));
        self_rec.insert(t.name(), accs[t.name()]);
    }
    printer.row(self_row);
    records.push(("Self-Sourced".into(), acc_json(&self_rec)));

    // Rows 2-4: single-source calibration (paper uses WinoGrande / ARC easy
    // / Hellaswag → our parity / copy / markov), evaluated on all tasks.
    for src in [Task::Maj, Task::Copy, Task::Markov] {
        let mut cs = CompressSpec::new(vec![0, 1, 2, 3], 6, Algorithm::MergeMoe);
        cs.n_calib_seqs = 64;
        cs.calib_tasks = Some(vec![src]);
        cs.seed = ctx.seed ^ 0x7A5;
        let mut gram = ctx.make_gram("beta")?;
        let (merged, _) = compress(&model, &cs, &mut gram.as_backend())?;
        let accs = ctx.eval_suite(engine.as_mut(), &merged, &tasks)?;
        let mut row = vec![format!("{} ({})", src.paper_name(), src.name())];
        row.extend(tasks.iter().map(|t| format!("{:.2}", accs[t.name()].percent())));
        printer.row(row);
        records.push((src.name().into(), acc_json(&accs)));
    }

    println!("\ntable4: cross-dataset calibration generalization (beta, 12->6, all layers)");
    printer.print();
    save(ctx, "table4", Json::Obj(records.into_iter().collect()))
}

/// Table 5 — ablation on the compression errors (`beta`): Full vs
/// w/o merging errors (output-merge oracle) vs w/ merging errors (MergeMoE).
pub fn table5(ctx: &Ctx) -> Result<()> {
    let tasks: Vec<Task> = paper_task_order().into_iter().take(5).collect(); // paper shows 5 tasks
    let model = ctx.load_model("beta")?;
    let mut engine = ctx.make_engine()?;
    let mut headers = vec!["Strategies".to_string()];
    headers.extend(tasks.iter().map(|t| format!("{} ({})", t.paper_name(), t.name())));
    let mut printer = TablePrinter::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut records: Vec<(String, Json)> = Vec::new();

    let accs = ctx.eval_suite(engine.as_mut(), &model, &tasks)?;
    let mut row = vec!["Full".to_string()];
    row.extend(tasks.iter().map(|t| format!("{:.2}", accs[t.name()].percent())));
    printer.row(row);
    records.push(("Full".into(), acc_json(&accs)));

    for (label, alg) in [
        ("w/o merging errors", Algorithm::Oracle),
        ("w/ merging errors", Algorithm::MergeMoe),
    ] {
        let mut cs = CompressSpec::new(vec![0, 1, 2, 3], 6, alg);
        cs.n_calib_seqs = 64;
        cs.seed = ctx.seed ^ 0xAB1;
        let mut gram = ctx.make_gram("beta")?;
        let (merged, _) = compress(&model, &cs, &mut gram.as_backend())?;
        let accs = ctx.eval_suite(engine.as_mut(), &merged, &tasks)?;
        let mut row = vec![label.to_string()];
        row.extend(tasks.iter().map(|t| format!("{:.2}", accs[t.name()].percent())));
        printer.row(row);
        records.push((label.into(), acc_json(&accs)));
    }

    println!("\ntable5: ablation on compression errors (beta, 12->6, all layers)");
    printer.print();
    save(ctx, "table5", Json::Obj(records.into_iter().collect()))
}
