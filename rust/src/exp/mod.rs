//! Experiment drivers: one function per table/figure of the paper's
//! evaluation (DESIGN.md §5 maps each to the paper artifact it regenerates).
//!
//! All drivers print a human-readable table to stdout and append a JSON
//! record to `artifacts/reports/<exp>.json`; EXPERIMENTS.md quotes these
//! outputs.

pub mod figures;
pub mod report;
pub mod tables;

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::Manifest;
use crate::eval::tasks::{gen_items, Task};
use crate::eval::{score_items, Accuracy};
use crate::merge::{GramBackend, NativeGram};
use crate::model::ModelWeights;
use crate::runtime::{Engine, NativeEngine, PjrtEngine};

/// Which forward backend experiments run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineSel {
    Native,
    Pjrt,
}

impl EngineSel {
    pub fn parse(s: &str) -> Result<EngineSel> {
        match s {
            "native" => Ok(EngineSel::Native),
            "pjrt" => Ok(EngineSel::Pjrt),
            _ => bail!("unknown engine {s:?} (native|pjrt)"),
        }
    }
}

/// Shared experiment context: manifest, engine selection, sizes.
pub struct Ctx {
    pub artifacts: PathBuf,
    pub manifest: Manifest,
    pub engine: EngineSel,
    /// Items per task in accuracy evaluations.
    pub items: usize,
    /// Eval batch (sequences per forward call).
    pub batch: usize,
    pub seed: u64,
    /// Use the PJRT gram artifact (pallas kernel) in the MergeMoE solve.
    pub pjrt_gram: bool,
}

impl Ctx {
    pub fn new(artifacts: PathBuf, engine: EngineSel) -> Result<Ctx> {
        let manifest = Manifest::load(&artifacts)
            .with_context(|| format!("loading manifest from {}", artifacts.display()))?;
        Ok(Ctx {
            artifacts,
            manifest,
            engine,
            items: 150,
            batch: 32,
            seed: 2026,
            pjrt_gram: false,
        })
    }

    pub fn load_model(&self, name: &str) -> Result<ModelWeights> {
        let cfg = self.manifest.model(name)?;
        ModelWeights::load(&self.artifacts, cfg)
    }

    pub fn make_engine(&self) -> Result<Box<dyn Engine>> {
        match self.engine {
            EngineSel::Native => Ok(Box::new(NativeEngine)),
            EngineSel::Pjrt => {
                let manifest = Manifest::load(&self.artifacts)?;
                Ok(Box::new(PjrtEngine::new(manifest)?))
            }
        }
    }

    /// Gram backend for the compression pipeline. PJRT-gram routes the
    /// least-squares accumulation through the pallas `gram_*` artifact.
    pub fn make_gram(&self, model: &str) -> Result<GramBox> {
        if self.pjrt_gram && self.engine == EngineSel::Pjrt {
            let manifest = Manifest::load(&self.artifacts)?;
            Ok(GramBox::Pjrt(PjrtEngine::new(manifest)?, model.to_string()))
        } else {
            Ok(GramBox::Native(NativeGram))
        }
    }

    /// Evaluate one model on all (or selected) tasks.
    pub fn eval_suite(
        &self,
        engine: &mut dyn Engine,
        model: &ModelWeights,
        tasks: &[Task],
    ) -> Result<BTreeMap<&'static str, Accuracy>> {
        let mut out = BTreeMap::new();
        for &t in tasks {
            let items = gen_items(t, self.items, self.seed);
            let acc = score_items(engine, model, &items, self.manifest.seq_len, self.batch)?;
            out.insert(t.name(), acc);
        }
        Ok(out)
    }
}

/// Owned gram backend (PJRT engines are not `Send`/boxable trait objects
/// with lifetimes, so a small enum keeps call sites simple).
pub enum GramBox {
    Native(NativeGram),
    Pjrt(PjrtEngine, String),
}

impl GramBox {
    pub fn as_backend(&mut self) -> GramRef<'_> {
        GramRef(self)
    }
}

/// Borrowing adapter implementing [`GramBackend`].
pub struct GramRef<'a>(&'a mut GramBox);

impl GramBackend for GramRef<'_> {
    fn gram(
        &mut self,
        p: &crate::tensor::Tensor,
        y: &crate::tensor::Tensor,
    ) -> Result<(crate::tensor::Tensor, crate::tensor::Tensor)> {
        match self.0 {
            GramBox::Native(g) => g.gram(p, y),
            GramBox::Pjrt(engine, model) => crate::runtime::pjrt::PjrtGram {
                engine,
                model: model.clone(),
            }
            .gram(p, y),
        }
    }

    fn fork(&self) -> Option<Box<dyn GramBackend + Send>> {
        match self.0 {
            // The native backend is stateless: forked instances unlock
            // per-cluster parallelism in the MergeMoE solve.
            GramBox::Native(_) => Some(Box::new(NativeGram)),
            // PJRT device state is single-threaded — stay serial.
            GramBox::Pjrt(..) => None,
        }
    }
}

/// The default task order used in report tables (paper column order:
/// WinoGrande, ARC easy, ARC challenge, Hellaswag, PIQA, SQuAD, MRPC).
pub fn paper_task_order() -> Vec<Task> {
    vec![
        Task::Maj, Task::Copy, Task::Sort, Task::Markov,
        Task::Parity, Task::Rev, Task::Arith,
    ]
}

/// Dispatch an experiment by id.
pub fn run(ctx: &Ctx, exp: &str) -> Result<()> {
    match exp {
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx),
        "table3" => tables::table3(ctx),
        "table4" => tables::table4(ctx),
        "table5" => tables::table5(ctx),
        "fig2a" => figures::fig2a(ctx),
        "fig2b" => figures::fig2b(ctx),
        "fig3" => figures::fig3(ctx),
        "fig4" => figures::fig4(ctx),
        "fig5" => figures::fig5(ctx),
        "loss" => report::loss_curves(ctx),
        "all" => {
            for e in ["table1", "table2", "table3", "table4", "table5",
                      "fig2a", "fig2b", "fig3", "fig4", "fig5", "loss"] {
                println!("\n================ {e} ================");
                run(ctx, e)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment {exp:?}"),
    }
}
