//! Report formatting + persistence for the experiment drivers.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::Ctx;
use crate::eval::Accuracy;
use crate::util::json::Json;

/// Markdown-ish table printer (the same rows the paper's tables report).
pub struct TablePrinter {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(headers: &[&str]) -> TablePrinter {
        TablePrinter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// The table as a markdown string (one trailing newline) — what
    /// [`TablePrinter::print`] writes to stdout and what `save_sweep`
    /// persists as `SWEEP_<model>.md`.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(4)));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep));
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Persist an experiment record to `artifacts/reports/<name>.json`.
pub fn save(ctx: &Ctx, name: &str, payload: Json) -> Result<()> {
    let dir = ctx.artifacts.join("reports");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, payload.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("[report saved to {}]", path.display());
    Ok(())
}

/// Persist a sweep report: `SWEEP_<model>.json` (the machine-readable
/// record a `tools/bench_diff`-style comparison consumes) plus
/// `SWEEP_<model>.md` (the accuracy-vs-ratio tables — one section per
/// calibration source on multi-source sweeps). Takes a directory rather
/// than a [`Ctx`] so sweeps run on bare checkouts without a manifest.
/// Returns the JSON path.
pub fn save_sweep(
    dir: &std::path::Path,
    rep: &crate::eval::sweep::SweepReport,
) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let json_path = dir.join(format!("SWEEP_{}.json", rep.model));
    std::fs::write(&json_path, rep.to_json().to_string())
        .with_context(|| format!("writing {}", json_path.display()))?;
    let md_path = dir.join(format!("SWEEP_{}.md", rep.model));
    std::fs::write(&md_path, super::tables::sweep_markdown(rep))
        .with_context(|| format!("writing {}", md_path.display()))?;
    Ok(json_path)
}

/// Convert an accuracy map to a JSON object.
pub fn acc_json(map: &BTreeMap<&'static str, Accuracy>) -> Json {
    Json::Obj(
        map.iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v.percent())))
            .collect(),
    )
}

/// Format a parameter count the way the paper's "Model Size" column does.
pub fn fmt_params(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else {
        format!("{:.0}K", n as f64 / 1e3)
    }
}

/// Reprint the build-time training loss curves (EXPERIMENTS.md §Training).
pub fn loss_curves(ctx: &Ctx) -> Result<()> {
    for name in ctx.manifest.models.keys() {
        let path = ctx.artifacts.join(format!("train_log_{name}.json"));
        if !path.exists() {
            continue;
        }
        let j = Json::parse_file(&path)?;
        let steps = j.get("steps")?.as_arr()?;
        let nll = j.get("nll")?.as_arr()?;
        let wall = j.get("wall_seconds")?.as_f64()?;
        let first = nll.first().map(|x| x.as_f64().unwrap_or(0.0)).unwrap_or(0.0);
        let last = nll.last().map(|x| x.as_f64().unwrap_or(0.0)).unwrap_or(0.0);
        println!(
            "model {name:<9} steps {:>4}  nll {first:.3} -> {last:.3}  ({wall:.0}s)",
            steps.last().map(|x| x.as_f64().unwrap_or(0.0)).unwrap_or(0.0)
        );
        // sparkline of the curve
        let vals: Vec<f64> = nll.iter().filter_map(|x| x.as_f64().ok()).collect();
        let (lo, hi) = vals.iter().fold((f64::MAX, f64::MIN), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        let ticks = "▁▂▃▄▅▆▇█";
        let spark: String = vals
            .iter()
            .map(|&v| {
                let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
                ticks.chars().nth(((t * 7.0) as usize).min(7)).unwrap()
            })
            .collect();
        println!("  {spark}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_printer_aligns() {
        let mut t = TablePrinter::new(&["a", "bb"]);
        t.row(vec!["xxx".into(), "1".into()]);
        t.print(); // no panic; visual check in CI logs
    }

    #[test]
    fn fmt_params_units() {
        assert_eq!(fmt_params(4_300_000), "4.30M");
        assert_eq!(fmt_params(32_000), "32K");
    }

    fn unit_sweep_report() -> crate::eval::sweep::SweepReport {
        use crate::eval::sweep::{SweepReport, TaskCell, VariantResult, FULL_SOURCE};
        use crate::eval::tasks::Task;
        let cell = |pct_correct: usize| TaskCell {
            task: Task::Copy,
            acc: crate::eval::Accuracy { correct: pct_correct, total: 4 },
            mean_correct_lp: -1.0,
        };
        SweepReport {
            model: "unit".into(),
            items: 4,
            seq_len: 64,
            seed: 1,
            threads: 1,
            kernel: "scalar".into(),
            calib_sources: vec!["mixture".into()],
            n_calib_tokens: 0,
            wall_seconds: 0.0,
            variants: vec![
                VariantResult {
                    source: FULL_SOURCE.into(),
                    label: "Full".into(),
                    m: 4,
                    params: 100,
                    ratio: 1.0,
                    merge_seconds: 0.0,
                    mean_layer_err: 0.0,
                    cells: vec![cell(2)],
                },
                VariantResult {
                    source: "mixture".into(),
                    label: "MergeMoE".into(),
                    m: 2,
                    params: 60,
                    ratio: 0.6,
                    merge_seconds: 0.1,
                    mean_layer_err: 0.05,
                    cells: vec![cell(1)],
                },
            ],
        }
    }

    #[test]
    fn render_and_save_sweep_roundtrip() {
        let rep = unit_sweep_report();
        let md = crate::exp::tables::sweep_markdown(&rep);
        assert!(md.contains("Full"), "{md}");
        assert!(md.contains("50.00"), "{md}");
        assert!(md.contains("mixture"), "{md}");
        // single source: flat table — header + separator + two variant rows
        assert_eq!(md.lines().count(), 4, "{md}");
        // per-process dir: concurrent test runs must not race on the files
        let dir = std::env::temp_dir()
            .join(format!("mergemoe_sweep_report_test_{}", std::process::id()));
        let path = save_sweep(&dir, &rep).unwrap();
        let back = Json::parse_file(&path).unwrap();
        assert_eq!(back.get("model").unwrap().as_str().unwrap(), "unit");
        assert_eq!(
            back.get("calib_sources").unwrap().as_arr().unwrap()[0]
                .as_str()
                .unwrap(),
            "mixture"
        );
        assert!(dir.join("SWEEP_unit.md").exists());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(dir.join("SWEEP_unit.md")).ok();
    }

    #[test]
    fn multi_source_markdown_sections_repeat_the_full_row() {
        use crate::eval::sweep::VariantResult;
        let mut rep = unit_sweep_report();
        rep.calib_sources = vec!["mixture".into(), "copy".into()];
        let compressed = rep.variants[1].clone();
        rep.variants.push(VariantResult { source: "copy".into(), ..compressed });
        let md = crate::exp::tables::sweep_markdown(&rep);
        // one section header per source
        assert_eq!(md.matches("### calibration source:").count(), 2, "{md}");
        assert!(md.contains("### calibration source: mixture"), "{md}");
        assert!(md.contains("### calibration source: copy"), "{md}");
        // the Full row appears in both sections; each section has exactly
        // one compressed row
        assert_eq!(md.matches("| Full").count(), 2, "{md}");
        assert_eq!(md.matches("| MergeMoE").count(), 2, "{md}");
        // sectioned tables omit the Calib column (the header names it)
        assert!(!md.contains("Calib"), "{md}");
    }
}
