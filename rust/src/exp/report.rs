//! Report formatting + persistence for the experiment drivers.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::Ctx;
use crate::eval::Accuracy;
use crate::util::json::Json;

/// Markdown-ish table printer (the same rows the paper's tables report).
pub struct TablePrinter {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(headers: &[&str]) -> TablePrinter {
        TablePrinter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(4)));
            }
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Persist an experiment record to `artifacts/reports/<name>.json`.
pub fn save(ctx: &Ctx, name: &str, payload: Json) -> Result<()> {
    let dir = ctx.artifacts.join("reports");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, payload.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("[report saved to {}]", path.display());
    Ok(())
}

/// Convert an accuracy map to a JSON object.
pub fn acc_json(map: &BTreeMap<&'static str, Accuracy>) -> Json {
    Json::Obj(
        map.iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v.percent())))
            .collect(),
    )
}

/// Format a parameter count the way the paper's "Model Size" column does.
pub fn fmt_params(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else {
        format!("{:.0}K", n as f64 / 1e3)
    }
}

/// Reprint the build-time training loss curves (EXPERIMENTS.md §Training).
pub fn loss_curves(ctx: &Ctx) -> Result<()> {
    for name in ctx.manifest.models.keys() {
        let path = ctx.artifacts.join(format!("train_log_{name}.json"));
        if !path.exists() {
            continue;
        }
        let j = Json::parse_file(&path)?;
        let steps = j.get("steps")?.as_arr()?;
        let nll = j.get("nll")?.as_arr()?;
        let wall = j.get("wall_seconds")?.as_f64()?;
        let first = nll.first().map(|x| x.as_f64().unwrap_or(0.0)).unwrap_or(0.0);
        let last = nll.last().map(|x| x.as_f64().unwrap_or(0.0)).unwrap_or(0.0);
        println!(
            "model {name:<9} steps {:>4}  nll {first:.3} -> {last:.3}  ({wall:.0}s)",
            steps.last().map(|x| x.as_f64().unwrap_or(0.0)).unwrap_or(0.0)
        );
        // sparkline of the curve
        let vals: Vec<f64> = nll.iter().filter_map(|x| x.as_f64().ok()).collect();
        let (lo, hi) = vals.iter().fold((f64::MAX, f64::MIN), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        let ticks = "▁▂▃▄▅▆▇█";
        let spark: String = vals
            .iter()
            .map(|&v| {
                let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
                ticks.chars().nth(((t * 7.0) as usize).min(7)).unwrap()
            })
            .collect();
        println!("  {spark}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_printer_aligns() {
        let mut t = TablePrinter::new(&["a", "bb"]);
        t.row(vec!["xxx".into(), "1".into()]);
        t.print(); // no panic; visual check in CI logs
    }

    #[test]
    fn fmt_params_units() {
        assert_eq!(fmt_params(4_300_000), "4.30M");
        assert_eq!(fmt_params(32_000), "32K");
    }
}
