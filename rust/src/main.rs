//! `mergemoe` — CLI entrypoint of the L3 coordinator.
//!
//! Subcommands:
//!   repro     regenerate a paper table/figure      (mergemoe repro --exp table2)
//!   compress  run the compression pipeline         (mergemoe compress --model beta --m 6)
//!   eval      evaluate a model on the task suite   (mergemoe eval --model beta)
//!   sweep     evaluate the whole method × ratio ×  (mergemoe sweep --model beta
//!             task comparison grid in one run          --methods average,msmoe,mergemoe --ms 6,8)
//!   generate  seeded autoregressive sampling       (mergemoe generate --prompt "c:abcd|"
//!             through the KV-cache decode path         --max-new 32 --temp 0.8 --seed 7)
//!   serve     run the batched scoring server demo  (mergemoe serve --model beta)
//!   registry  manage the crash-safe variant store  (mergemoe registry ls --registry DIR)
//!   stats     dump expert usage frequencies        (mergemoe stats --model beta)
//!   selfcheck cross-check native vs pjrt engines   (mergemoe selfcheck --model beta)
//!
//! Global flags: --artifacts DIR (default ./artifacts), --engine native|pjrt
//! (default pjrt), --items N, --seed N.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use mergemoe::calib;
use mergemoe::coordinator::{
    compress, AdminState, CalibSource, CompressSpec, HttpServer, Registry, RouteFallback,
    ScoringServer, ServerConfig, VariantSpec,
};
use mergemoe::eval::tasks::{self, Task, ALL_TASKS};
use mergemoe::eval::{generate, run_sweep, Sampler, SweepSpec};
use mergemoe::exp::{self, Ctx, EngineSel};
use mergemoe::merge::{Algorithm, NativeGram};
use mergemoe::model::ModelWeights;
use mergemoe::runtime::{Engine, NativeEngine, PjrtEngine};
use mergemoe::util::cli::Args;
use mergemoe::util::fault::FaultPlan;
use mergemoe::util::rng::Rng;
use mergemoe::{config, info};

fn main() {
    mergemoe::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: mergemoe <repro|compress|eval|sweep|generate|serve|registry|stats|selfcheck> [flags]\n\
     common flags: --artifacts DIR --engine native|pjrt --items N --seed N\n\
                   --threads N (worker threads; default: MERGEMOE_THREADS env\n\
                   or all cores; 1 = fully serial)\n\
                   MERGEMOE_KERNEL=auto|scalar|avx2|neon (compute kernel,\n\
                   fixed per process; default auto-detects, scalar is the\n\
                   seed-exact reference)\n\
     repro:     --exp table1..table5|fig2a|fig2b|fig3|fig4|fig5|loss|all\n\
     compress:  --model NAME --layers 2,3 --m M --alg mergemoe|msmoe|average|zipit|oracle\n\
                [--calib-seqs N] [--calib-tasks t1,t2] [--out FILE.npz]\n\
     eval:      --model NAME [--compressed FILE.npz] [--tasks t1,t2]\n\
     sweep:     [--model NAME] [--methods m1,m2,..] [--ms M1,M2,..] [--tasks t1,t2]\n\
                [--layers l1,l2] [--items N] [--batch N] [--calib-seqs N]\n\
                [--calib-sources s1,s2] [--calib-tasks t1,t2] [--no-full]\n\
                evaluates every {calib source x method x ratio x task} cell\n\
                in one run and writes SWEEP_<model>.json + .md under\n\
                <artifacts>/reports (synthetic-model fallback on bare\n\
                checkouts). each calibration source is a task name, an\n\
                a+b task combination, or \"mixture\" (Table 4's rows);\n\
                omitted = one source from --calib-tasks (default mixture)\n\
     generate:  [--model NAME] [--prompt STR] [--max-new N] [--temp T]\n\
                [--top-k K] [--top-p P] [--seed N]\n\
                seeded autoregressive sampling through the KV-cache decode\n\
                path (native engine; pjrt decodes via re-prefill). --temp 0\n\
                (default) is greedy; --top-k/--top-p truncate the candidate\n\
                set. prints a deterministic \"tokens:\" id line — the same\n\
                --seed reproduces the same sequence across runs and\n\
                --threads settings (synthetic-model fallback on bare\n\
                checkouts); generation stops cleanly at the trained context\n\
                window\n\
     serve:     --model NAME [--requests N] [--clients N] [--max-batch N] [--max-wait-ms N]\n\
                [--queue-cap N] [--deadline-ms N] [--retries N] [--restart-budget N]\n\
                [--drain-ms N] [--workers N] [--listen ADDR[:PORT]] [--duration-s N]\n\
                [--registry DIR [--variant NAME[@vN]]] [--config-file FILE.json]\n\
                [--cache-budget-mb N] [--route-fallback base|reject]\n\
                default: in-process demo load-gen; with --listen, serves the\n\
                HTTP/1.1 API (POST /score, GET /healthz, GET /metrics, plus\n\
                POST /admin/swap and /admin/reload when --registry or\n\
                --config-file is given) for --duration-s seconds (0 = forever).\n\
                POST /score takes optional method/ratio/calib_source fields to\n\
                score on a compressed variant, built on demand (registry\n\
                first, else compressed from the boot model) into an in-process\n\
                cache bounded by --cache-budget-mb (default 256, also via\n\
                MERGEMOE_CACHE_BUDGET_MB); --route-fallback base serves\n\
                quarantined-variant traffic on the boot weights with\n\
                fallback=true (default reject = typed 503).\n\
                --variant boots from the registry (latest good version unless\n\
                @vN pins one); --config-file applies validated tuning at boot\n\
                and on each /admin/reload. --workers N runs N compute lanes\n\
                behind one continuous batch collector (default 1 = in-order;\n\
                also via MERGEMOE_WORKERS). overload knobs also via\n\
                MERGEMOE_QUEUE_CAP; fault injection via MERGEMOE_FAULT\n\
                (seed:N[,transient:P][,fatal:P][,panic:P][,slow:P][,slow-ms:N]\n\
                [,io-fail:N][,build-fail:N])\n\
     registry:  <add|ls|verify> --registry DIR\n\
                add: --model NAME [--name VARIANT] [--m M --alg ALG\n\
                [--layers l1,l2] [--calib-seqs N] [--calib-tasks t1,t2]]\n\
                stores the (optionally compressed) model as a new immutable\n\
                version via write-to-temp + fsync + atomic rename\n\
                ls: list variants; verify: re-hash every stored tensor\n\
                against its manifest (exit 1 on any corruption)\n\
     stats:     --model NAME [--calib-seqs N]\n\
     selfcheck: --model NAME"
}

fn run() -> Result<()> {
    let args = Args::from_env(&["monolith", "pjrt-gram", "no-full", "help"])?;
    if args.has("help") || args.subcommand.is_none() {
        println!("{}", usage());
        return Ok(());
    }
    let artifacts = PathBuf::from(args.get_or(
        "artifacts",
        config::artifacts_dir().to_str().unwrap_or("artifacts"),
    ));
    let threads = args.apply_threads()?;
    if threads > 1 {
        info!("compute: {threads} worker threads");
    }
    info!("compute: {} kernel", mergemoe::kernel::name());
    let engine = EngineSel::parse(args.get_or("engine", "pjrt"))?;
    if args.subcommand.as_deref() == Some("sweep") {
        // sweeps run even on a bare checkout (synthetic-model fallback), so
        // they must not require the manifest that Ctx::new loads
        return cmd_sweep(&artifacts, engine, &args);
    }
    if args.subcommand.as_deref() == Some("registry") {
        // registry ls/verify need no model at all, and add falls back to a
        // synthetic model — none of them require the artifacts manifest
        return cmd_registry(&artifacts, engine, &args);
    }
    if args.subcommand.as_deref() == Some("serve") {
        // serve also runs on a bare checkout (synthetic-model fallback on
        // the native engine) so CI can smoke-test the server end to end
        return cmd_serve(&artifacts, engine, &args);
    }
    if args.subcommand.as_deref() == Some("generate") {
        // generate also runs on a bare checkout (synthetic-model fallback),
        // which is what lets CI pin an exact token sequence
        return cmd_generate(&artifacts, engine, &args);
    }
    let mut ctx = Ctx::new(artifacts.clone(), engine)?;
    ctx.items = args.usize("items", ctx.items)?;
    ctx.batch = args.usize("batch", ctx.batch)?;
    ctx.seed = args.usize("seed", ctx.seed as usize)? as u64;
    ctx.pjrt_gram = args.has("pjrt-gram");

    match args.subcommand.as_deref().unwrap() {
        "repro" => {
            let exp = args.require("exp")?;
            exp::run(&ctx, exp)
        }
        "compress" => cmd_compress(&ctx, &args),
        "eval" => cmd_eval(&mut ctx, &args),
        "stats" => cmd_stats(&ctx, &args),
        "selfcheck" => cmd_selfcheck(&ctx, &args),
        other => bail!("unknown subcommand {other:?}\n{}", usage()),
    }
}

fn parse_layers(args: &Args, default: &[usize]) -> Result<Vec<usize>> {
    match args.get("layers") {
        None => Ok(default.to_vec()),
        Some(v) => v
            .split(',')
            .map(|s| s.trim().parse::<usize>().context("bad --layers"))
            .collect(),
    }
}

fn parse_tasks(args: &Args, key: &str) -> Result<Option<Vec<Task>>> {
    match args.get(key) {
        None => Ok(None),
        Some(v) => {
            let mut out = Vec::new();
            for name in v.split(',') {
                out.push(
                    Task::from_name(name.trim())
                        .with_context(|| format!("unknown task {name:?}"))?,
                );
            }
            Ok(Some(out))
        }
    }
}

fn cmd_compress(ctx: &Ctx, args: &Args) -> Result<()> {
    let model_name = args.require("model")?;
    let model = ctx.load_model(model_name)?;
    let last = model.cfg.n_layers - 1;
    let layers = parse_layers(args, &[last.saturating_sub(1), last])?;
    let m = args.usize("m", model.cfg.merge_targets.first().copied().unwrap_or(1))?;
    let alg = Algorithm::from_name(args.get_or("alg", "mergemoe"))
        .context("bad --alg")?;
    let mut spec = CompressSpec::new(layers, m, alg);
    spec.n_calib_seqs = args.usize("calib-seqs", 64)?;
    spec.calib_tasks = parse_tasks(args, "calib-tasks")?;
    spec.seed = ctx.seed;
    let mut gram = ctx.make_gram(model_name)?;
    info!("compressing {model_name} layers {:?} -> {m} experts via {}", spec.layers, alg.name());
    let (merged, rep) = compress(&model, &spec, &mut gram.as_backend())?;
    println!(
        "compressed {model_name}: {} -> {} params ({:.1}% of original), merge {:.2}s (+calib {:.2}s)",
        rep.params_before, rep.params_after, 100.0 * rep.compression_ratio(),
        rep.merge_seconds, rep.calib_seconds,
    );
    for l in &rep.layers {
        println!(
            "  layer {:>2}: {} -> {} experts, output rel-err {:.4} ({:.3}s)",
            l.layer, l.n_before, l.n_after, l.output_rel_err, l.merge_seconds
        );
    }
    if let Some(out) = args.get("out") {
        merged.save(&PathBuf::from(out))?;
        println!("saved compressed weights to {out} (note: routing maps are \
                  structural — rerun compression or keep the plan to redeploy)");
    }
    Ok(())
}

fn cmd_eval(ctx: &mut Ctx, args: &Args) -> Result<()> {
    let model_name = args.require("model")?;
    let model = ctx.load_model(model_name)?;
    let tasks = parse_tasks(args, "tasks")?
        .unwrap_or_else(|| ALL_TASKS.to_vec());
    let mut engine = ctx.make_engine()?;
    let t0 = std::time::Instant::now();
    let accs = ctx.eval_suite(engine.as_mut(), &model, &tasks)?;
    for (name, acc) in &accs {
        println!("{name:<8} {:>6.2}%  ({}/{})", acc.percent(), acc.correct, acc.total);
    }
    let mean: f64 = accs.values().map(|a| a.percent()).sum::<f64>() / accs.len() as f64;
    println!("mean     {mean:>6.2}%   [{} items/task, engine={}, {:.1}s]",
             ctx.items, engine.name(), t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_sweep(artifacts: &std::path::Path, engine_sel: EngineSel, args: &Args) -> Result<()> {
    let model_name = args.get_or("model", "beta").to_string();
    // Artifacts are optional here: a bare checkout falls back to a synthetic
    // model of the published shape (the benches' fallback), so the
    // comparison grid always runs.
    let ctx = match Ctx::new(artifacts.to_path_buf(), engine_sel) {
        Ok(mut c) => {
            c.pjrt_gram = args.has("pjrt-gram");
            Some(c)
        }
        Err(e) => {
            info!(
                "no artifacts ({e:#}); sweeping a synthetic {model_name}-shaped \
                 model on the native engine"
            );
            None
        }
    };
    let (model, seq_len, mut engine): (ModelWeights, usize, Box<dyn Engine>) = match &ctx {
        Some(c) => (c.load_model(&model_name)?, c.manifest.seq_len, c.make_engine()?),
        None => {
            let bm = mergemoe::bench::load_or_synth(&model_name);
            (bm.model, bm.seq_len, Box::new(NativeEngine))
        }
    };
    let n = model.cfg.n_experts;
    let mut default_targets = vec![(n / 2).max(1), (2 * n / 3).max(1)];
    default_targets.dedup();
    let targets = args.usize_list("ms", &default_targets)?;
    let mut methods = Vec::new();
    for name in args.list("methods", &["average", "zipit", "msmoe", "mergemoe"]) {
        methods.push(
            Algorithm::from_name(&name).with_context(|| format!("unknown method {name:?}"))?,
        );
    }
    let tasks = parse_tasks(args, "tasks")?.unwrap_or_else(|| ALL_TASKS.to_vec());
    let all_layers: Vec<usize> = (0..model.cfg.n_layers).collect();
    let layers = parse_layers(args, &all_layers)?;
    let mut spec = SweepSpec::new(methods, targets, tasks, layers);
    spec.items = args.usize("items", 50)?;
    spec.batch = args.usize("batch", 32)?;
    spec.seq_len = seq_len;
    spec.n_calib_seqs = args.usize("calib-seqs", 48)?;
    spec.calib_tasks = parse_tasks(args, "calib-tasks")?;
    if let Some(v) = args.get("calib-sources") {
        let mut sources = Vec::new();
        for entry in v.split(',') {
            sources.push(
                CalibSource::parse(entry)
                    .with_context(|| format!("bad --calib-sources entry {entry:?}"))?,
            );
        }
        spec.calib_sources = sources;
    }
    spec.seed = args.usize("seed", 2026)? as u64;
    spec.include_full = !args.has("no-full");
    info!(
        "sweep: {} calib sources x {} methods x {} ratios x {} tasks on {model_name} \
         ({} items/task)",
        spec.sources().len(),
        spec.methods.len(),
        spec.targets.len(),
        spec.tasks.len(),
        spec.items
    );
    // Gram backend: honor --pjrt-gram exactly like `compress` does (routes
    // the MergeMoE solves through the pallas artifact when artifacts exist).
    let mut gram = match &ctx {
        Some(c) => c.make_gram(&model_name)?,
        None => exp::GramBox::Native(NativeGram),
    };
    let rep = run_sweep(&model, &spec, &mut gram.as_backend(), engine.as_mut())?;
    println!(
        "\nsweep: model={model_name} layers={:?} targets={:?} sources={:?} ({} items/task, \
         engine={}, {} threads, {:.1}s)",
        spec.layers, spec.targets, rep.calib_sources, spec.items, engine.name(), rep.threads,
        rep.wall_seconds
    );
    print!("{}", exp::tables::sweep_markdown(&rep));
    let path = exp::report::save_sweep(&artifacts.join("reports"), &rep)?;
    println!("[sweep report saved to {} (+ .md)]", path.display());
    Ok(())
}

/// `mergemoe generate`: seeded autoregressive sampling through the KV-cache
/// decode path (ROADMAP direction 5). Deterministic by construction — equal
/// seeds reproduce equal token sequences across runs and `--threads`
/// settings (`tests/decode_consistency.rs` pins this; ci.sh smokes it by
/// diffing two runs).
fn cmd_generate(artifacts: &std::path::Path, engine_sel: EngineSel, args: &Args) -> Result<()> {
    let model_name = args.get_or("model", "beta").to_string();
    // same ctx-optional pattern as `sweep`: a bare checkout generates from a
    // synthetic model of the published shape on the native engine
    let ctx = Ctx::new(artifacts.to_path_buf(), engine_sel).ok();
    let model = match &ctx {
        Some(c) => c.load_model(&model_name)?,
        None => {
            info!("no artifacts; generating from a synthetic {model_name}-shaped model");
            mergemoe::bench::load_or_synth(&model_name).model
        }
    };
    let mut engine: Box<dyn Engine> = match (&ctx, engine_sel) {
        (Some(c), EngineSel::Pjrt) => c.make_engine()?,
        _ => Box::new(NativeEngine),
    };
    let prompt_text = args.get_or("prompt", "c:abcd|").to_string();
    for c in prompt_text.chars() {
        if !tasks::CHARSET.contains(c) {
            bail!(
                "--prompt char {c:?} is outside the model alphabet {:?}",
                tasks::CHARSET
            );
        }
    }
    let prompt = tasks::encode(&prompt_text);
    let max_new = args.usize("max-new", 32)?;
    let temp = args.f64("temp", 0.0)? as f32;
    let top_k = args.usize("top-k", 0)?;
    let top_p = args.f64("top-p", 1.0)? as f32;
    let seed = args.usize("seed", 2026)? as u64;
    let mut sampler = Sampler::new(temp, top_k, top_p);
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let (tokens, stats) =
        generate(engine.as_mut(), &model, &prompt, max_new, &mut sampler, &mut rng)?;
    let dt = t0.elapsed().as_secs_f64();
    // the ids line is the CI smoke's determinism anchor — keep it greppable
    let ids: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    println!("tokens: {}", ids.join(" "));
    let text: String = tokens
        .iter()
        .map(|&t| tasks::CHARSET.as_bytes().get(t as usize).map_or('?', |&b| b as char))
        .collect();
    println!("text: {text:?}");
    println!(
        "produced {} token(s) in {dt:.3}s ({:.0} tok/s, engine={}{})",
        stats.produced,
        stats.produced as f64 / dt.max(1e-9),
        engine.name(),
        if stats.hit_context_limit { ", stopped at the trained context window" } else { "" }
    );
    Ok(())
}

/// `mergemoe registry <add|ls|verify> --registry DIR`: manage the crash-safe
/// on-disk variant store that `serve` hot-swaps from.
fn cmd_registry(artifacts: &std::path::Path, engine_sel: EngineSel, args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .context("registry expects an action: add | ls | verify")?;
    let root = PathBuf::from(args.require("registry")?);
    // honor MERGEMOE_FAULT io-fail:N so crash-safety drills can kill the
    // writer at a chosen fsync/rename crossing
    if let Some(plan) = FaultPlan::from_env()? {
        plan.arm_io();
    }
    let reg = Registry::open(&root)?;
    match action {
        "add" => {
            let model_name = args.get_or("model", "beta").to_string();
            let name = args.get_or("name", &model_name).to_string();
            // same ctx-optional pattern as `sweep`: bare checkouts get a
            // synthetic model of the published shape
            let ctx = Ctx::new(artifacts.to_path_buf(), engine_sel).ok();
            let model = match &ctx {
                Some(c) => c.load_model(&model_name)?,
                None => mergemoe::bench::load_or_synth(&model_name).model,
            };
            let (model, spec) = if let Some(mflag) = args.get("m") {
                let m: usize = mflag.parse().context("--m expects an integer")?;
                let last = model.cfg.n_layers - 1;
                let layers = parse_layers(args, &[last.saturating_sub(1), last])?;
                let alg = Algorithm::from_name(args.get_or("alg", "mergemoe"))
                    .context("bad --alg")?;
                let mut cspec = CompressSpec::new(layers, m, alg);
                cspec.n_calib_seqs = args.usize("calib-seqs", 48)?;
                cspec.calib_tasks = parse_tasks(args, "calib-tasks")?;
                cspec.seed = args.usize("seed", 2026)? as u64;
                let mut gram = match &ctx {
                    Some(c) => c.make_gram(&model_name)?,
                    None => exp::GramBox::Native(NativeGram),
                };
                info!("compressing {model_name} -> {m} experts via {}", alg.name());
                let (merged, rep) = compress(&model, &cspec, &mut gram.as_backend())?;
                let spec = VariantSpec {
                    method: alg.name().to_string(),
                    ratio: rep.compression_ratio(),
                    calib_source: args.get_or("calib-tasks", "mixture").to_string(),
                };
                (merged, spec)
            } else {
                let spec = VariantSpec {
                    method: "full".to_string(),
                    ratio: 1.0,
                    calib_source: "none".to_string(),
                };
                (model, spec)
            };
            let meta = reg.add(&name, &model, &spec)?;
            println!(
                "registered {} ({}, {:.1}% of full params) in {}",
                meta.label(),
                meta.method,
                100.0 * meta.ratio,
                root.display()
            );
            Ok(())
        }
        "ls" => {
            let variants = reg.list()?;
            if variants.is_empty() {
                println!("(empty registry at {})", root.display());
                return Ok(());
            }
            println!("{:<24} {:<10} {:>8}  {}", "variant", "method", "ratio", "calib");
            for m in variants {
                println!(
                    "{:<24} {:<10} {:>7.1}%  {}",
                    m.label(),
                    m.method,
                    100.0 * m.ratio,
                    m.calib_source
                );
            }
            Ok(())
        }
        "verify" => {
            let entries = reg.verify()?;
            let mut bad = 0usize;
            for e in &entries {
                match &e.problem {
                    None => println!("{:<24} ok", e.label),
                    Some(p) => {
                        bad += 1;
                        println!("{:<24} CORRUPT: {p}", e.label);
                    }
                }
            }
            println!("verified {} variant(s), {bad} corrupt", entries.len());
            if bad > 0 {
                bail!("{bad} corrupt variant(s) in {}", root.display());
            }
            Ok(())
        }
        other => bail!("unknown registry action {other:?} (expected add | ls | verify)"),
    }
}

fn cmd_serve(artifacts: &std::path::Path, engine_sel: EngineSel, args: &Args) -> Result<()> {
    // Artifacts are optional (the `sweep`/`registry add` pattern): a bare
    // checkout serves a synthetic model of the published shape on the
    // native engine, which is what lets CI smoke-test the server.
    let ctx = Ctx::new(artifacts.to_path_buf(), engine_sel).ok();
    let registry = match args.get("registry") {
        Some(dir) => Some(std::sync::Arc::new(Registry::open(std::path::Path::new(dir))?)),
        None => None,
    };
    // boot weights: a pinned/latest-good registry variant, or --model
    let (model, variant) = if let Some(vspec) = args.get("variant") {
        let reg = registry
            .as_ref()
            .context("--variant requires --registry DIR")?;
        let (name, version) = match vspec.split_once('@') {
            Some((n, v)) => {
                let ver: u64 = v
                    .trim_start_matches('v')
                    .parse()
                    .with_context(|| format!("bad --variant version in {vspec:?}"))?;
                (n, Some(ver))
            }
            None => (vspec, None),
        };
        let (model, meta) = match version {
            Some(v) => reg.load(name, v)?,
            None => reg.load_latest_good(name)?,
        };
        info!("booting registry variant {}", meta.label());
        (model, Some(meta))
    } else {
        let model_name = args.require("model")?;
        let model = match &ctx {
            Some(c) => c.load_model(model_name)?,
            None => {
                info!("no artifacts; serving a synthetic {model_name}-shaped model");
                mergemoe::bench::load_or_synth(model_name).model
            }
        };
        (model, None)
    };
    let n_requests = args.usize("requests", 200)?;
    let n_clients = args.usize("clients", 4)?;
    let default_cfg = ServerConfig::default();
    // the CacheConfig default already honors MERGEMOE_CACHE_BUDGET_MB; the
    // flag overrides it
    let mut cache = default_cfg.cache.clone();
    cache.budget_bytes = args
        .usize("cache-budget-mb", cache.budget_bytes / (1024 * 1024))?
        .saturating_mul(1024 * 1024);
    let route_fallback = RouteFallback::parse(args.get_or("route-fallback", "reject"))?;
    let cfg = ServerConfig {
        max_batch: args.usize("max-batch", 32)?,
        max_wait: Duration::from_millis(args.usize("max-wait-ms", 3)? as u64),
        seq_len: ctx.as_ref().map_or(default_cfg.seq_len, |c| c.manifest.seq_len),
        queue_cap: args.usize("queue-cap", default_cfg.queue_cap)?,
        deadline: args.opt_ms("deadline-ms")?,
        max_retries: args.usize("retries", default_cfg.max_retries as usize)? as u32,
        restart_budget: args.usize("restart-budget", default_cfg.restart_budget as usize)? as u32,
        drain_timeout: args.ms("drain-ms", default_cfg.drain_timeout)?,
        workers: args.usize("workers", default_cfg.workers)?,
        cache,
        route_fallback,
        ..default_cfg
    };
    // a bare checkout has no pallas artifact, so the lanes fall back to the
    // native engine rather than booting degraded
    let sel = if ctx.is_some() { engine_sel } else { EngineSel::Native };
    let artifacts = artifacts.to_path_buf();
    // keep a copy of registry-booted weights: the post-start swap below
    // re-labels the slot with the registry version (name@vN, not name@local)
    let boot_copy = variant.as_ref().map(|_| model.clone());
    // the registry doubles as the cache's variant source: a routed request
    // whose variant is registered loads it instead of re-compressing
    let server = ScoringServer::start_with_registry(
        model,
        cfg,
        registry.clone(),
        move || -> Result<Box<dyn Engine>> {
            match sel {
                EngineSel::Native => Ok(Box::new(NativeEngine)),
                EngineSel::Pjrt => {
                    let manifest = config::Manifest::load(&artifacts)?;
                    Ok(Box::new(PjrtEngine::new(manifest)?))
                }
            }
        },
    )?;
    if let (Some(meta), Some(m)) = (&variant, boot_copy) {
        server
            .admin()
            .swap_in(m, &meta.label())
            .context("activating registry variant")?;
    }
    // --config-file applies the same validate-then-commit path as
    // POST /admin/reload, so a bad file is rejected loudly at boot
    let config_file = args.get("config-file").map(PathBuf::from);
    if let Some(p) = &config_file {
        server
            .admin()
            .reload_from(p)
            .with_context(|| format!("applying --config-file {}", p.display()))?;
        info!("applied tuning from {}", p.display());
    }
    // `--listen ADDR` runs the HTTP front end instead of the demo load-gen
    if let Some(addr) = args.get("listen") {
        let admin_state = AdminState {
            admin: server.admin(),
            registry: registry.clone(),
            config_file: config_file.clone(),
        };
        let mut http =
            HttpServer::bind_with_admin(addr, server.handle(), server.status(), admin_state)?;
        let duration = args.usize("duration-s", 0)?;
        println!(
            "listening on http://{} (POST /score, GET /healthz, GET /metrics, \
             POST /admin/swap, POST /admin/reload)",
            http.addr()
        );
        if duration > 0 {
            std::thread::sleep(Duration::from_secs(duration as u64));
        } else {
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        http.stop();
        let m = server.shutdown();
        println!("served: {}", m.report());
        return Ok(());
    }
    info!("serving {n_requests} requests from {n_clients} clients");
    let handle = server.handle();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let h = handle.clone();
        let per = n_requests / n_clients;
        joins.push(std::thread::spawn(move || -> Result<usize> {
            let mut rng = Rng::new(7000 + c as u64);
            let mut correct = 0;
            for _ in 0..per {
                let t = *rng.pick(&ALL_TASKS);
                let item = mergemoe::eval::tasks::gen_items(t, 1, rng.next_u64())
                    .pop()
                    .unwrap();
                let s0 = h.score(&item.prompt, &item.options[0])?;
                let s1 = h.score(&item.prompt, &item.options[1])?;
                let pick = if s0 >= s1 { 0 } else { 1 };
                if pick == item.correct {
                    correct += 1;
                }
            }
            Ok(correct)
        }));
    }
    let mut correct = 0;
    for j in joins {
        correct += j.join().unwrap()?;
    }
    drop(handle);
    let m = server.shutdown();
    println!("served: {}", m.report());
    println!(
        "online accuracy {:.1}% over {} items",
        100.0 * correct as f64 / (n_requests / n_clients * n_clients) as f64,
        n_requests / n_clients * n_clients
    );
    Ok(())
}

fn cmd_stats(ctx: &Ctx, args: &Args) -> Result<()> {
    let model_name = args.require("model")?;
    let model = ctx.load_model(model_name)?;
    let n_seqs = args.usize("calib-seqs", 32)?;
    let tokens = calib::sample_sequences(None, n_seqs, ctx.manifest.seq_len, ctx.seed);
    let data = calib::capture(&model, &tokens, n_seqs, ctx.manifest.seq_len)?;
    for (li, l) in data.layers.iter().enumerate() {
        let freq = l.stats.frequencies();
        let order = l.stats.by_usage_desc();
        let top: Vec<String> = order
            .iter()
            .take(6)
            .map(|&e| format!("E{e}:{:.1}%", 100.0 * freq[e]))
            .collect();
        println!("layer {li}: top experts {}", top.join("  "));
    }
    Ok(())
}

fn cmd_selfcheck(ctx: &Ctx, args: &Args) -> Result<()> {
    let model_name = args.require("model")?;
    let model = ctx.load_model(model_name)?;
    let s = ctx.manifest.seq_len;
    let b = 4;
    let tokens = calib::sample_sequences(None, b, s, 42);
    let native = NativeEngine.logits(&model, &tokens, b, s)?;
    let manifest = config::Manifest::load(&ctx.artifacts)?;
    let mut pjrt = PjrtEngine::new(manifest)?;
    let layered = pjrt.logits(&model, &tokens, b, s)?;
    let rel = layered.rel_err(&native);
    println!("native vs pjrt(per-layer): rel err {rel:.2e}");
    let mono = pjrt.logits_bucketed(&model, &tokens, b, s, true);
    match mono {
        Ok(m) => println!("native vs pjrt(monolith):  rel err {:.2e}", m.rel_err(&native)),
        Err(e) => println!("monolith unavailable for {model_name}: {e:#}"),
    }
    if rel > 1e-3 {
        bail!("selfcheck FAILED: engines disagree (rel err {rel})");
    }
    println!("selfcheck OK");
    Ok(())
}
