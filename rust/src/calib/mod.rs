//! Calibration: sampling input batches and capturing per-layer activations
//! plus routing statistics from the uncompressed model.
//!
//! The paper (Appendix B) merges layers back to front precisely so that one
//! activation capture of the *original* model serves every layer: merging
//! layer ℓ only changes activations downstream of ℓ, and layers are merged
//! in decreasing ℓ. [`capture`] therefore runs the uncompressed model once
//! over the calibration batch and records, per MoE layer, the post-LN inputs
//! X̂ and the usage statistics that Theorem 1's weights need.

use anyhow::{bail, Context, Result};

use crate::eval::tasks::{self, Task};
use crate::model::native;
use crate::model::ModelWeights;
use crate::moe::UsageStats;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-layer calibration data.
#[derive(Debug, Clone)]
pub struct LayerCalib {
    /// Post-LN MoE inputs, one row per calibration token: (T, d).
    pub x: Tensor,
    pub stats: UsageStats,
}

/// Calibration data for a whole model (index = layer).
#[derive(Debug, Clone)]
pub struct CalibData {
    pub layers: Vec<LayerCalib>,
    pub n_sequences: usize,
    pub seq_len: usize,
}

impl CalibData {
    pub fn n_tokens(&self) -> usize {
        self.n_sequences * self.seq_len
    }
}

/// A named calibration source — *where* the calibration batch is sampled
/// from. This is the paper's Table-4 experimental axis (cross-dataset
/// generalization of the calibration data): the evaluation sweep treats it
/// as a fourth grid dimension (`SweepSpec::calib_sources`), capturing
/// activations once per source and compressing every (method, ratio)
/// variant against each.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CalibSource {
    /// Row label in reports (`"mixture"`, `"copy"`, `"copy+parity"`, …).
    pub label: String,
    /// Tasks [`sample_sequences`] may draw corpus lines from; `None` is
    /// the uniform mixture over all seven tasks.
    pub tasks: Option<Vec<Task>>,
}

impl CalibSource {
    /// The uniform mixture over all tasks — the default calibration data.
    pub fn mixture() -> CalibSource {
        CalibSource { label: "mixture".into(), tasks: None }
    }

    /// Calibration restricted to one task (Table 4's single-source rows).
    pub fn single(task: Task) -> CalibSource {
        CalibSource { label: task.name().into(), tasks: Some(vec![task]) }
    }

    /// A source drawing from an explicit task set; the empty set is the
    /// mixture. The label joins task names with `+` (`"copy+parity"`).
    pub fn from_tasks(tasks: &[Task]) -> CalibSource {
        if tasks.is_empty() {
            return CalibSource::mixture();
        }
        let label = tasks.iter().map(|t| t.name()).collect::<Vec<_>>().join("+");
        CalibSource { label, tasks: Some(tasks.to_vec()) }
    }

    /// Parse one `--calib-sources` entry: `"mixture"` (or `"all"`), a task
    /// name, or a `+`-joined task list (`"copy+parity"`).
    pub fn parse(s: &str) -> Result<CalibSource> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty calibration source");
        }
        if s.eq_ignore_ascii_case("mixture") || s.eq_ignore_ascii_case("all") {
            return Ok(CalibSource::mixture());
        }
        let mut parsed = Vec::new();
        for name in s.split('+') {
            let name = name.trim();
            parsed.push(Task::from_name(name).with_context(|| {
                format!(
                    "unknown calibration source task {name:?} \
                     (task names, a+b combinations, or \"mixture\")"
                )
            })?);
        }
        Ok(CalibSource::from_tasks(&parsed))
    }
}

/// Pack task-corpus lines into `n_seqs` sequences of `seq_len` tokens —
/// the same packing the trainer uses, so calibration inputs are
/// in-distribution. `tasks` selects the source datasets (Table 4 varies
/// this; `None` ⇒ uniform mixture over all seven).
pub fn sample_sequences(
    task_filter: Option<&[Task]>,
    n_seqs: usize,
    seq_len: usize,
    seed: u64,
) -> Vec<i32> {
    let all: Vec<Task> = match task_filter {
        Some(ts) => ts.to_vec(),
        None => tasks::ALL_TASKS.to_vec(),
    };
    let mut rng = Rng::new(seed);
    let newline = tasks::encode("\n")[0];
    let mut buf: Vec<i32> = Vec::new();
    let mut out = Vec::with_capacity(n_seqs * seq_len);
    for _ in 0..n_seqs {
        while buf.len() < seq_len {
            let t = *rng.pick(&all);
            let line = tasks::gen_corpus_line(t, &mut rng);
            buf.extend(tasks::encode(&line));
            buf.push(newline);
        }
        out.extend(buf.drain(..seq_len));
    }
    out
}

/// Run the uncompressed model over calibration sequences and capture all
/// per-layer data in one pass (native engine — the capture path needs
/// activations *between* layers, which the rust coordinator owns anyway).
pub fn capture(
    model: &ModelWeights,
    tokens: &[i32],
    n_seqs: usize,
    seq_len: usize,
) -> Result<CalibData> {
    let mut caps = Vec::new();
    // One workspace (plus one logits buffer) reused across every chunk:
    // the per-chunk forward passes only allocate their capture clones.
    let mut ws = crate::model::workspace::Workspace::new();
    let mut logits = Tensor::default();
    // chunk to bound peak memory on large calibration sets
    let chunk = 32usize.min(n_seqs.max(1));
    let total_rows = n_seqs * seq_len;
    let mut merged: Vec<LayerCalib> = Vec::new();
    let mut done = 0;
    let mut row_off = 0; // calibration rows already copied per layer
    while done < n_seqs {
        let take = chunk.min(n_seqs - done);
        let slice = &tokens[done * seq_len..(done + take) * seq_len];
        caps.clear();
        native::forward_ws(model, slice, take, seq_len, Some(&mut caps), &mut ws, &mut logits)?;
        if merged.is_empty() {
            // First chunk reveals the layer count and width: preallocate the
            // full (total_rows, d) capture per layer once, instead of
            // reallocating and copying the whole prefix on every chunk.
            for c in &caps {
                let d = c.x.shape()[1];
                let mut x = Tensor::zeros(&[total_rows, d]);
                x.data_mut()[..c.x.len()].copy_from_slice(c.x.data());
                let mut stats = UsageStats::new(c.counts.len());
                stats.add(&c.counts, &c.weight_mass, (take * seq_len) as u64);
                merged.push(LayerCalib { x, stats });
            }
        } else {
            for (dst, c) in merged.iter_mut().zip(&caps) {
                let d = c.x.shape()[1];
                let lo = row_off * d;
                dst.x.data_mut()[lo..lo + c.x.len()].copy_from_slice(c.x.data());
                dst.stats.add(&c.counts, &c.weight_mass, (take * seq_len) as u64);
            }
        }
        row_off += take * seq_len;
        done += take;
    }
    Ok(CalibData { layers: merged, n_sequences: n_seqs, seq_len })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn sequences_are_packed_and_in_alphabet() {
        let toks = sample_sequences(None, 4, 64, 9);
        assert_eq!(toks.len(), 256);
        assert!(toks.iter().all(|&t| (0..47).contains(&t)));
        // deterministic
        assert_eq!(toks, sample_sequences(None, 4, 64, 9));
        // different seed differs
        assert_ne!(toks, sample_sequences(None, 4, 64, 10));
    }

    #[test]
    fn task_filter_restricts_content() {
        let toks = sample_sequences(Some(&[Task::Parity]), 2, 64, 3);
        // parity lines contain only p : 0 1 # e o . \n — check no lowercase
        // letters other than e/o/p appear
        let allowed: Vec<i32> = tasks::encode("p:01#eo.\n");
        assert!(toks.iter().all(|t| allowed.contains(t)), "{toks:?}");
    }

    #[test]
    fn calib_source_parsing_round_trips() {
        assert_eq!(CalibSource::parse("mixture").unwrap(), CalibSource::mixture());
        assert_eq!(CalibSource::parse(" ALL ").unwrap(), CalibSource::mixture());
        assert_eq!(CalibSource::parse("copy").unwrap(), CalibSource::single(Task::Copy));
        let combo = CalibSource::parse("copy+parity").unwrap();
        assert_eq!(combo.label, "copy+parity");
        assert_eq!(combo.tasks, Some(vec![Task::Copy, Task::Parity]));
        assert_eq!(combo, CalibSource::from_tasks(&[Task::Copy, Task::Parity]));
        assert!(CalibSource::parse("").is_err());
        assert!(CalibSource::parse("winogrande").is_err());
        // empty task set degenerates to the mixture
        assert_eq!(CalibSource::from_tasks(&[]), CalibSource::mixture());
    }

    #[test]
    fn calib_source_selects_sampling_tasks() {
        let mix = CalibSource::mixture();
        let one = CalibSource::single(Task::Parity);
        assert_eq!(
            sample_sequences(mix.tasks.as_deref(), 2, 64, 5),
            sample_sequences(None, 2, 64, 5)
        );
        assert_eq!(
            sample_sequences(one.tasks.as_deref(), 2, 64, 5),
            sample_sequences(Some(&[Task::Parity]), 2, 64, 5)
        );
    }

    #[test]
    fn capture_accumulates_across_chunks() {
        let model = tiny_model(4, 2, false, 60);
        let n_seqs = 40; // forces two chunks of 32 + 8
        let toks = sample_sequences(None, n_seqs, 64, 11);
        let data = capture(&model, &toks, n_seqs, 64).unwrap();
        assert_eq!(data.layers.len(), 2);
        for l in &data.layers {
            assert_eq!(l.x.shape(), &[n_seqs * 64, 16]);
            assert_eq!(l.stats.tokens_seen, (n_seqs * 64) as u64);
            let total: f64 = l.stats.counts.iter().sum();
            assert_eq!(total, (n_seqs * 64 * 2) as f64); // top-2
        }
    }
}
