//! Clustering (paper §4 step 1): the M most-used experts are the cluster
//! centers; every remaining expert joins the center with the highest cosine
//! similarity of its concatenated `[W_U; W_G]` matrix. Intra-cluster weights
//! are the relative usage frequencies (Theorem 1).

use anyhow::{bail, Result};

use super::plan::MergePlan;
use crate::model::MoeLayer;
use crate::moe::UsageStats;

/// Cosine similarity of two experts' `[W_U; W_G]` concatenations (flattened;
/// the metric the paper uses so that "weighted average is performed among
/// experts with similar W_U and W_G").
pub fn expert_similarity(moe: &MoeLayer, a: usize, b: usize) -> f64 {
    let ea = &moe.experts[a];
    let eb = &moe.experts[b];
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in ea
        .wu
        .data()
        .iter()
        .chain(ea.wg.data())
        .zip(eb.wu.data().iter().chain(eb.wg.data()))
    {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-30)
}

/// Build the merge plan for reducing `moe` to `m` experts.
pub fn build_plan(moe: &MoeLayer, stats: &UsageStats, m: usize) -> Result<MergePlan> {
    let n = moe.n_experts();
    if m == 0 || m > n {
        bail!("cannot merge {n} experts into {m}");
    }
    if stats.n_experts() != n {
        bail!("usage stats cover {} experts, layer has {n}", stats.n_experts());
    }
    // centers: top-M usage
    let order = stats.by_usage_desc();
    let centers: Vec<usize> = order[..m].to_vec();
    let mut assign = vec![usize::MAX; n];
    let mut clusters: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (ci, &c) in centers.iter().enumerate() {
        assign[c] = ci;
        clusters[ci].push(c);
    }
    // assign the rest by similarity to centers
    for &j in &order[m..] {
        let mut best = 0usize;
        let mut best_sim = f64::NEG_INFINITY;
        for (ci, &c) in centers.iter().enumerate() {
            let sim = expert_similarity(moe, j, c);
            if sim > best_sim {
                best_sim = sim;
                best = ci;
            }
        }
        assign[j] = best;
        clusters[best].push(j);
    }
    for members in &mut clusters {
        members.sort();
    }
    // Theorem-1 weights: relative usage frequency inside each cluster
    let freq = stats.frequencies();
    let mut weights = vec![0.0f64; n];
    for members in &clusters {
        let total: f64 = members.iter().map(|&j| freq[j]).sum();
        for &j in members {
            weights[j] = freq[j] / total;
        }
    }
    let plan = MergePlan { n, m, clusters, assign, weights };
    plan.validate(n)?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    fn stats_with_counts(counts: &[f64]) -> UsageStats {
        let mut s = UsageStats::new(counts.len());
        let mass: Vec<f64> = counts.iter().map(|c| c * 0.5).collect();
        s.add(counts, &mass, counts.iter().sum::<f64>() as u64);
        s
    }

    #[test]
    fn centers_are_top_usage() {
        let model = tiny_model(6, 2, false, 10);
        let moe = &model.layers[0].moe;
        let stats = stats_with_counts(&[5.0, 50.0, 1.0, 40.0, 2.0, 30.0]);
        let plan = build_plan(moe, &stats, 3).unwrap();
        // experts 1, 3, 5 are the centers — each must be in its own cluster
        let c1 = plan.assign[1];
        let c3 = plan.assign[3];
        let c5 = plan.assign[5];
        assert_ne!(c1, c3);
        assert_ne!(c3, c5);
        assert_ne!(c1, c5);
    }

    #[test]
    fn self_similarity_is_max() {
        let model = tiny_model(5, 2, false, 11);
        let moe = &model.layers[0].moe;
        for i in 0..5 {
            assert!((expert_similarity(moe, i, i) - 1.0).abs() < 1e-6);
            for j in 0..5 {
                if i != j {
                    assert!(expert_similarity(moe, i, j) < 0.999);
                }
            }
        }
    }

    #[test]
    fn identical_experts_cluster_together() {
        let model = tiny_model(6, 2, false, 12);
        let mut moe = model.layers[0].moe.clone();
        // make expert 4 a copy of expert 0 (a center)
        moe.experts[4] = moe.experts[0].clone();
        let stats = stats_with_counts(&[50.0, 40.0, 30.0, 2.0, 1.0, 2.0]);
        let plan = build_plan(&moe, &stats, 3).unwrap();
        assert_eq!(plan.assign[4], plan.assign[0], "copy must join its twin");
    }

    #[test]
    fn weights_are_relative_frequencies() {
        let model = tiny_model(4, 2, false, 13);
        let moe = &model.layers[0].moe;
        let stats = stats_with_counts(&[30.0, 10.0, 5.0, 5.0]);
        let plan = build_plan(moe, &stats, 2).unwrap();
        for members in &plan.clusters {
            let total: f64 = members.iter().map(|&j| stats.counts[j]).sum();
            for &j in members {
                let expect = stats.counts[j] / total;
                assert!((plan.weights[j] - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn rejects_bad_m() {
        let model = tiny_model(4, 2, false, 14);
        let stats = stats_with_counts(&[1.0; 4]);
        assert!(build_plan(&model.layers[0].moe, &stats, 0).is_err());
        assert!(build_plan(&model.layers[0].moe, &stats, 5).is_err());
    }
}
