//! **MergeMoE** — the paper's method (§4).
//!
//! For each cluster C with frequency weights `w_j` (Theorem 1):
//!
//! 1. `T2 W'_G = Σ_j w_j W_Gj` and `T3 W'_U = Σ_j w_j W_Uj` — the merged
//!    gate/up projections are the frequency-weighted averages (the paper
//!    fixes T2, T3 to Eq. 4 because the non-linearity precludes a closed
//!    form for them).
//! 2. `T1` is solved by least squares on calibration activations X̂ (Eq. 5):
//!    `T1 P = Q` with `P = σ(T2 W'_G X̂) ⊙ (T3 W'_U X̂)` (f × S) and
//!    `Q = σ(W'_G X̂) ⊙ (W'_U X̂)` (N_c·f × S), giving `T1 = Q P†` (Eq. 6).
//! 3. The merged down-projection is `W'_D T1` where
//!    `W'_D = [B_1i W_D1, …]`. We use the identity
//!    `W'_D Q = Σ_j w_j W_Dj Q_j = Σ_j w_j E_j(X̂) = Ŷ` — the target merged
//!    *output* — so the final weight is obtained directly as
//!    `W_D' = Ŷ P† = (Ŷ Pᵀ)(P Pᵀ + λI)⁻¹` without ever materializing the
//!    (N_c·f × f) matrix `T1`. The Gram blocks stream through a
//!    [`GramBackend`] in fixed-size column chunks (the L1 pallas kernel on
//!    the PJRT path).
//!
//! All P/Ŷ panels are drawn from a [`Workspace`] arena
//! ([`crate::model::workspace::PanelScratch`] — one slot per concurrent
//! chunk), so a multi-layer compression run re-streams every chunk through
//! the same buffers instead of churning the allocator. Workspaces are
//! per-thread: the serial cluster loop reuses the caller's, the forked
//! parallel path gives each cluster lane its own.

use anyhow::Result;

use super::plan::MergePlan;
use super::GramBackend;
use crate::linalg;
use crate::model::native::expert_swiglu_into;
use crate::model::workspace::{PanelScratch, Workspace};
use crate::model::{Expert, MoeLayer};
use crate::tensor::{ops, Tensor};
use crate::util::par;

/// Column-chunk size for streaming the Gram accumulation (matches the
/// `gram_*` artifact buckets; the backend may further split internally).
pub const GRAM_CHUNK: usize = 1024;

/// Compute one chunk's panels into `sc`: P (f, chunk) from the averaged
/// expert's inner activations and Ŷ (d, chunk) from the frequency-weighted
/// member outputs.
#[allow(clippy::too_many_arguments)]
fn panel_compute(
    moe: &MoeLayer,
    members: &[usize],
    weights: &[f64],
    avg: &Expert,
    x: &Tensor,
    clo: usize,
    chi: usize,
    sc: &mut PanelScratch,
) -> Result<()> {
    let d = x.shape()[1];
    let rows = chi - clo;
    sc.xs.reuse2(rows, d);
    sc.xs.data_mut().copy_from_slice(&x.data()[clo * d..chi * d]);
    // Ŷ chunk: frequency-weighted member outputs, transposed. Each member's
    // contribution `w_j · E_j(X̂)` accumulates through the fused
    // scale-and-add GEMM epilogue — the member output batch is never
    // materialized.
    sc.yhat.reuse2(rows, d);
    sc.yhat.data_mut().fill(0.0);
    for &j in members {
        let ex = &moe.experts[j];
        expert_swiglu_into(ex, &sc.xs, &mut sc.g)?;
        ops::matmul_bt_scaled_add_into(&sc.g, &ex.wd, weights[j] as f32, &mut sc.yhat)?;
    }
    sc.y.reuse2(d, rows);
    ops::transpose_into(&sc.yhat, &mut sc.y)?;
    // P chunk: fused SwiGLU activations of the averaged gate/up, transposed
    expert_swiglu_into(avg, &sc.xs, &mut sc.g)?;
    let f = avg.wg.shape()[0];
    sc.p.reuse2(f, rows);
    ops::transpose_into(&sc.g, &mut sc.p)
}

/// Merge one cluster: returns the merged expert. Panel scratch comes from
/// `ws` (never shared across threads — each parallel cluster lane owns one).
#[allow(clippy::too_many_arguments)]
fn merge_cluster(
    moe: &MoeLayer,
    members: &[usize],
    weights: &[f64],
    x: &Tensor, // calibration inputs (T, d)
    gram: &mut dyn GramBackend,
    ridge: f64,
    ws: &mut Workspace,
) -> Result<Expert> {
    // (1) frequency-weighted gate/up projections
    let proto = &moe.experts[members[0]];
    let mut wg = Tensor::zeros(proto.wg.shape());
    let mut wu = Tensor::zeros(proto.wu.shape());
    for &j in members {
        wg.axpy(weights[j] as f32, &moe.experts[j].wg)?;
        wu.axpy(weights[j] as f32, &moe.experts[j].wu)?;
    }
    if members.len() == 1 {
        // singleton cluster: exact, no solve needed
        return Ok(Expert { wg, wu, wd: moe.experts[members[0]].wd.clone() });
    }
    let avg = Expert { wg, wu, wd: proto.wd.clone() }; // wd unused below

    // (2)+(3): stream P (f,S) and Ŷ (d,S) in chunks, accumulate Gram blocks.
    // Chunks are independent until the Gram reduction, so they are computed
    // in waves of up to `max_threads` chunks in parallel (bounding peak
    // memory to one wave of panel slots) and reduced serially in chunk order
    // — the accumulation order is identical at every thread count.
    let t = x.shape()[0];
    let f = avg.wg.shape()[0];
    let d = x.shape()[1];
    let mut ppt = Tensor::zeros(&[f, f]);
    let mut ypt = Tensor::zeros(&[d, f]);
    let mut ranges: Vec<(usize, usize)> = Vec::new();
    let mut lo = 0;
    while lo < t {
        let hi = (lo + GRAM_CHUNK).min(t);
        ranges.push((lo, hi));
        lo = hi;
    }
    let avg_ref = &avg;
    for wave in ranges.chunks(par::max_threads().max(1)) {
        let nw = wave.len();
        if ws.panels.len() < nw {
            ws.panels.resize_with(nw, PanelScratch::new);
        }
        let slots = &mut ws.panels[..nw];
        // chunk panels are coarse by construction — always fan out
        par::par_chunks_mut_if(true, slots, 1, |wi, slot| {
            let sc = &mut slot[0];
            let (clo, chi) = wave[wi];
            let result = panel_compute(moe, members, weights, avg_ref, x, clo, chi, sc);
            sc.err = result.err();
        });
        for sc in ws.panels[..nw].iter_mut() {
            if let Some(err) = sc.err.take() {
                return Err(err);
            }
            let (pp, yp) = gram.gram(&sc.p, &sc.y)?;
            ppt.axpy(1.0, &pp)?;
            ypt.axpy(1.0, &yp)?;
        }
    }
    // ridge-regularized normal-equation solve: W_D' (f columns)
    let wd = linalg::lstsq_from_gram(&ppt, &ypt, ridge)?; // (d, f)
    Ok(Expert { wg: avg.wg, wu: avg.wu, wd })
}

/// Merge a whole layer according to `plan`, drawing panel scratch from `ws`:
/// the serial path uses it directly, the forked parallel path hands each
/// cluster lane its own sub-workspace from `ws.cluster_ws` (workspaces are
/// never shared across threads; the slots are reused across layers when the
/// pipeline merges several).
pub fn merge(
    moe: &MoeLayer,
    plan: &MergePlan,
    x: &Tensor,
    gram: &mut dyn GramBackend,
    ridge: f64,
    ws: &mut Workspace,
) -> Result<MoeLayer> {
    // Clusters are independent solves. If the backend can fork (native
    // path), each cluster gets its own backend instance and the solves run
    // in parallel; otherwise (PJRT device state) the loop stays serial on
    // the caller's backend.
    let n_clusters = plan.clusters.len();
    let forks: Option<Vec<Box<dyn GramBackend + Send>>> = if n_clusters > 1 {
        (0..n_clusters).map(|_| gram.fork()).collect()
    } else {
        None
    };
    let experts = match forks {
        Some(mut forked) => {
            let mut slots: Vec<Option<Result<Expert>>> = Vec::new();
            slots.resize_with(n_clusters, || None);
            // One sub-workspace per cluster lane, drawn from (and returned
            // to) the caller's arena so repeated merges — the pipeline's
            // back-to-front layer loop — reuse warm panels.
            if ws.cluster_ws.len() < n_clusters {
                ws.cluster_ws.resize_with(n_clusters, Workspace::new);
            }
            {
                type Lane<'a> = (
                    &'a mut Box<dyn GramBackend + Send>,
                    &'a mut Option<Result<Expert>>,
                    &'a mut Workspace,
                );
                let mut items: Vec<Lane<'_>> = forked
                    .iter_mut()
                    .zip(slots.iter_mut())
                    .zip(ws.cluster_ws.iter_mut())
                    .map(|((g, s), w)| (g, s, w))
                    .collect();
                // cluster solves are coarse by construction — always fan out
                par::par_chunks_mut_if(true, &mut items, 1, |ci, slot| {
                    let (g, out, cluster_ws) = &mut slot[0];
                    **out = Some(merge_cluster(
                        moe,
                        &plan.clusters[ci],
                        &plan.weights,
                        x,
                        g.as_mut(),
                        ridge,
                        cluster_ws,
                    ));
                });
            }
            slots
                .into_iter()
                .map(|s| s.expect("cluster solve missing"))
                .collect::<Result<Vec<_>>>()?
        }
        None => plan
            .clusters
            .iter()
            .map(|members| merge_cluster(moe, members, &plan.weights, x, gram, ridge, ws))
            .collect::<Result<Vec<_>>>()?,
    };
    Ok(MoeLayer {
        router: moe.router.clone(),
        experts,
        shared: moe.shared.clone(),
        top_k: moe.top_k,
        map: Some(plan.matrix_a()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::NativeGram;
    use crate::model::native::expert_forward;
    use crate::model::testutil::tiny_model;
    use crate::util::rng::Rng;

    fn two_cluster_plan() -> MergePlan {
        MergePlan {
            n: 4,
            m: 2,
            clusters: vec![vec![0, 1], vec![2, 3]],
            assign: vec![0, 0, 1, 1],
            weights: vec![0.6, 0.4, 0.3, 0.7],
        }
    }

    #[test]
    fn merged_expert_approximates_weighted_output() {
        let model = tiny_model(4, 2, false, 30);
        let moe = &model.layers[0].moe;
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[512, 16], 1.0, &mut rng);
        let plan = two_cluster_plan();
        let merged =
            merge(moe, &plan, &x, &mut NativeGram, 1e-8, &mut Workspace::new()).unwrap();

        // held-out batch: merged expert vs the exact weighted output target
        let xt = Tensor::randn(&[128, 16], 1.0, &mut Rng::new(32));
        for (ci, members) in plan.clusters.iter().enumerate() {
            let got = expert_forward(&merged.experts[ci], &xt).unwrap();
            let mut want = Tensor::zeros(&[128, 16]);
            for &j in members {
                let yj = expert_forward(&moe.experts[j], &xt).unwrap();
                want.axpy(plan.weights[j] as f32, &yj).unwrap();
            }
            let rel = got.sub(&want).unwrap().frob_norm() / (want.frob_norm() + 1e-12);
            // approximation, not exact — but must capture most of the signal
            assert!(rel < 0.9, "cluster {ci}: rel err {rel}");
        }
    }

    #[test]
    fn wd_solve_beats_msmoe_wd_on_calibration() {
        // Optimality of the lstsq W_D against the fixed-T1 (M-SMoE) W_D,
        // measured on the merged expert's own output error.
        let model = tiny_model(4, 2, false, 33);
        let moe = &model.layers[0].moe;
        let mut rng = Rng::new(34);
        let x = Tensor::randn(&[512, 16], 1.0, &mut rng);
        let plan = two_cluster_plan();
        let mm =
            merge(moe, &plan, &x, &mut NativeGram, 1e-10, &mut Workspace::new()).unwrap();
        let ms = crate::merge::msmoe::merge(moe, &plan).unwrap();
        for (ci, members) in plan.clusters.iter().enumerate() {
            let mut want = Tensor::zeros(&[512, 16]);
            for &j in members {
                let yj = expert_forward(&moe.experts[j], &x).unwrap();
                want.axpy(plan.weights[j] as f32, &yj).unwrap();
            }
            let e_mm = expert_forward(&mm.experts[ci], &x)
                .unwrap()
                .sub(&want)
                .unwrap()
                .frob_norm();
            let e_ms = expert_forward(&ms.experts[ci], &x)
                .unwrap()
                .sub(&want)
                .unwrap()
                .frob_norm();
            assert!(
                e_mm <= e_ms + 1e-6,
                "cluster {ci}: mergemoe {e_mm} vs msmoe {e_ms}"
            );
        }
    }

    #[test]
    fn singleton_cluster_is_exact_copy() {
        let model = tiny_model(3, 1, false, 35);
        let moe = &model.layers[0].moe;
        let plan = MergePlan {
            n: 3,
            m: 3,
            clusters: vec![vec![0], vec![1], vec![2]],
            assign: vec![0, 1, 2],
            weights: vec![1.0; 3],
        };
        let x = Tensor::randn(&[64, 16], 1.0, &mut Rng::new(36));
        let merged =
            merge(moe, &plan, &x, &mut NativeGram, 1e-8, &mut Workspace::new()).unwrap();
        for i in 0..3 {
            assert_eq!(merged.experts[i].wd.data(), moe.experts[i].wd.data());
        }
    }

    #[test]
    fn tiny_sample_count_still_finite() {
        // Below-threshold regime of Fig. 4: with fewer samples than d_ff the
        // Gram matrix is singular; ridge must keep the solve finite.
        let model = tiny_model(4, 2, false, 37);
        let moe = &model.layers[0].moe;
        let x = Tensor::randn(&[4, 16], 1.0, &mut Rng::new(38));
        let merged =
            merge(moe, &two_cluster_plan(), &x, &mut NativeGram, 1e-6, &mut Workspace::new())
                .unwrap();
        for e in &merged.experts {
            assert!(e.wd.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn workspace_reuse_across_merges_is_bit_identical() {
        // Re-running the same merge through one warm workspace must produce
        // byte-identical weights to a fresh workspace.
        let model = tiny_model(4, 2, false, 39);
        let moe = &model.layers[0].moe;
        let x = Tensor::randn(&[300, 16], 1.0, &mut Rng::new(40));
        let plan = two_cluster_plan();
        let mut ws = Workspace::new();
        let first = merge(moe, &plan, &x, &mut NativeGram, 1e-8, &mut ws).unwrap();
        let second = merge(moe, &plan, &x, &mut NativeGram, 1e-8, &mut ws).unwrap();
        for (a, b) in first.experts.iter().zip(&second.experts) {
            assert_eq!(a.wd.data(), b.wd.data());
            assert_eq!(a.wg.data(), b.wg.data());
        }
    }
}
