//! ZipIt baseline (Stoica et al., 2023), adapted to the expert-merging
//! scenario as in the M-SMoE paper's comparison.
//!
//! ZipIt merges networks by *matching features* rather than averaging
//! position-wise: hidden units that compute similar functions are "zipped"
//! together. Adapted to a SwiGLU expert cluster: starting from the cluster
//! center, each other member's hidden units are greedily matched one-to-one
//! to the center's units by cosine similarity of their `[w_u; w_g]` rows,
//! then the matched rows (and the corresponding `W_D` columns) are averaged
//! with the cluster frequency weights.

use anyhow::Result;

use super::plan::MergePlan;
use crate::model::{Expert, MoeLayer};

/// Cosine similarity between hidden unit `a` of expert `ea` and unit `b` of
/// `eb` (concatenated gate+up rows).
fn unit_sim(ea: &Expert, eb: &Expert, a: usize, b: usize) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in ea
        .wg
        .row(a)
        .iter()
        .chain(ea.wu.row(a))
        .zip(eb.wg.row(b).iter().chain(eb.wu.row(b)))
    {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-30)
}

/// Greedy one-to-one matching of `other`'s units onto the center's units:
/// highest-similarity pairs first (the ZipIt "zip" step).
fn match_units(center: &Expert, other: &Expert) -> Vec<usize> {
    let f = center.wg.shape()[0];
    let mut pairs: Vec<(f64, usize, usize)> = Vec::with_capacity(f * f);
    for a in 0..f {
        for b in 0..f {
            pairs.push((unit_sim(center, other, a, b), a, b));
        }
    }
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let mut center_used = vec![false; f];
    let mut other_used = vec![false; f];
    let mut map = vec![usize::MAX; f]; // center unit -> other unit
    let mut matched = 0;
    for (_, a, b) in pairs {
        if !center_used[a] && !other_used[b] {
            center_used[a] = true;
            other_used[b] = true;
            map[a] = b;
            matched += 1;
            if matched == f {
                break;
            }
        }
    }
    map
}

pub fn merge(moe: &MoeLayer, plan: &MergePlan) -> Result<MoeLayer> {
    let experts = plan
        .clusters
        .iter()
        .map(|members| {
            // center = highest-frequency member (plan weights are relative
            // frequencies, so argmax weight)
            let center = *members
                .iter()
                .max_by(|&&a, &&b| plan.weights[a].partial_cmp(&plan.weights[b]).unwrap())
                .unwrap();
            let ce = &moe.experts[center];
            let f = ce.wg.shape()[0];
            let d = ce.wg.shape()[1];
            let mut wg = ce.wg.clone().scale(plan.weights[center] as f32);
            let mut wu = ce.wu.clone().scale(plan.weights[center] as f32);
            let mut wd = ce.wd.clone().scale(plan.weights[center] as f32);
            for &j in members {
                if j == center {
                    continue;
                }
                let oe = &moe.experts[j];
                let m = match_units(ce, oe);
                let w = plan.weights[j] as f32;
                for a in 0..f {
                    let b = m[a];
                    for c in 0..d {
                        *wg.at2_mut(a, c) += w * oe.wg.at2(b, c);
                        *wu.at2_mut(a, c) += w * oe.wu.at2(b, c);
                    }
                    // W_D columns follow the hidden-unit permutation
                    for r in 0..d {
                        *wd.at2_mut(r, a) += w * oe.wd.at2(r, b);
                    }
                }
            }
            Ok(Expert { wg, wu, wd })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(MoeLayer {
        router: moe.router.clone(),
        experts,
        shared: moe.shared.clone(),
        top_k: moe.top_k,
        map: Some(plan.matrix_a()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::util::rng::Rng;

    #[test]
    fn matching_is_a_permutation() {
        let model = tiny_model(2, 1, false, 40);
        let a = &model.layers[0].moe.experts[0];
        let b = &model.layers[0].moe.experts[1];
        let m = match_units(a, b);
        let mut seen = vec![false; m.len()];
        for &x in &m {
            assert!(!seen[x]);
            seen[x] = true;
        }
    }

    #[test]
    fn permuted_twin_merges_losslessly() {
        // If the other expert is the center with its hidden units permuted,
        // ZipIt must undo the permutation (this is ZipIt's defining property
        // vs plain averaging).
        let model = tiny_model(2, 1, false, 41);
        let mut moe = model.layers[0].moe.clone();
        let f = moe.experts[0].wg.shape()[0];
        let d = moe.experts[0].wg.shape()[1];
        let mut perm: Vec<usize> = (0..f).collect();
        Rng::new(42).shuffle(&mut perm);
        let src = moe.experts[0].clone();
        let mut twin = src.clone();
        for a in 0..f {
            let b = perm[a];
            for c in 0..d {
                *twin.wg.at2_mut(b, c) = src.wg.at2(a, c);
                *twin.wu.at2_mut(b, c) = src.wu.at2(a, c);
            }
            for r in 0..d {
                *twin.wd.at2_mut(r, b) = src.wd.at2(r, a);
            }
        }
        moe.experts[1] = twin;
        let plan = MergePlan {
            n: 2,
            m: 1,
            clusters: vec![vec![0, 1]],
            assign: vec![0, 0],
            weights: vec![0.6, 0.4], // expert 0 (src) is the center
        };
        let merged = merge(&moe, &plan).unwrap();
        // matching undoes the permutation, so the weighted combination
        // 0.6·src + 0.4·matched(twin) must equal src exactly
        assert!(merged.experts[0].wg.rel_err(&src.wg) < 1e-5);
        assert!(merged.experts[0].wd.rel_err(&src.wd) < 1e-5);
    }
}
