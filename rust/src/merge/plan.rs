//! The merge plan: clustering assignment (matrix `A`, Eq. 2) plus the
//! intra-cluster weights (matrix `B`, Theorem 1).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Cluster assignment + merge weights for one MoE layer.
#[derive(Debug, Clone)]
pub struct MergePlan {
    /// Original expert count N.
    pub n: usize,
    /// Merged expert count M.
    pub m: usize,
    /// `clusters[i]` lists the original experts merged into expert i
    /// (ascending; `clusters[i][0..]` always non-empty).
    pub clusters: Vec<Vec<usize>>,
    /// `assign[j]` = cluster of original expert j (column structure of A).
    pub assign: Vec<usize>,
    /// `weights[j]` = B_{j,assign[j]} — the relative usage frequency within
    /// its cluster. Within every cluster they sum to 1.
    pub weights: Vec<f64>,
}

impl MergePlan {
    /// Identity plan (M = N, every cluster a singleton).
    pub fn identity(n: usize) -> MergePlan {
        MergePlan {
            n,
            m: n,
            clusters: (0..n).map(|i| vec![i]).collect(),
            assign: (0..n).collect(),
            weights: vec![1.0; n],
        }
    }

    /// Structural invariants (checked before every merge; the property tests
    /// fuzz these).
    pub fn validate(&self, n_experts: usize) -> Result<()> {
        if self.n != n_experts {
            bail!("plan built for {} experts, layer has {}", self.n, n_experts);
        }
        if self.clusters.len() != self.m || self.assign.len() != self.n
            || self.weights.len() != self.n {
            bail!("plan size mismatch");
        }
        let mut seen = vec![false; self.n];
        for (ci, members) in self.clusters.iter().enumerate() {
            if members.is_empty() {
                bail!("cluster {ci} is empty");
            }
            let mut wsum = 0.0;
            for &j in members {
                if j >= self.n || seen[j] {
                    bail!("expert {j} missing or assigned twice");
                }
                seen[j] = true;
                if self.assign[j] != ci {
                    bail!("assign[{j}] != {ci}");
                }
                if self.weights[j] < 0.0 {
                    bail!("negative weight for expert {j}");
                }
                wsum += self.weights[j];
            }
            if (wsum - 1.0).abs() > 1e-6 {
                bail!("cluster {ci} weights sum to {wsum}, expected 1");
            }
        }
        if !seen.iter().all(|&s| s) {
            bail!("some experts unassigned");
        }
        Ok(())
    }

    /// Summation matrix `A` (M × N): `A[i][j] = 1` iff expert j ∈ cluster i.
    pub fn matrix_a(&self) -> Tensor {
        let mut a = Tensor::zeros(&[self.m, self.n]);
        for (j, &ci) in self.assign.iter().enumerate() {
            *a.at2_mut(ci, j) = 1.0;
        }
        a
    }

    /// Weighting matrix `B` (N × M): `B[j][i] = w_j` iff expert j ∈ cluster i.
    pub fn matrix_b(&self) -> Tensor {
        let mut b = Tensor::zeros(&[self.n, self.m]);
        for (j, &ci) in self.assign.iter().enumerate() {
            *b.at2_mut(j, ci) = self.weights[j] as f32;
        }
        b
    }

    /// `B·A` (N × N) — the Table-5 oracle routing transform.
    pub fn matrix_ba(&self) -> Tensor {
        let mut ba = Tensor::zeros(&[self.n, self.n]);
        for (j, &cj) in self.assign.iter().enumerate() {
            for (k, &ck) in self.assign.iter().enumerate() {
                if cj == ck {
                    *ba.at2_mut(j, k) = self.weights[j] as f32;
                }
            }
        }
        ba
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::rng::Rng;

    fn sample_plan(n: usize, m: usize, rng: &mut Rng) -> MergePlan {
        // random assignment with every cluster non-empty
        let mut assign: Vec<usize> = (0..m).collect();
        assign.extend((m..n).map(|_| rng.below(m as u64) as usize));
        rng.shuffle(&mut assign);
        let mut clusters = vec![Vec::new(); m];
        for (j, &c) in assign.iter().enumerate() {
            clusters[c].push(j);
        }
        let mut weights = vec![0.0; n];
        for members in &clusters {
            let raw: Vec<f64> = members.iter().map(|_| rng.f64() + 0.1).collect();
            let s: f64 = raw.iter().sum();
            for (&j, w) in members.iter().zip(raw) {
                weights[j] = w / s;
            }
        }
        MergePlan { n, m, clusters, assign, weights }
    }

    #[test]
    fn identity_plan_valid() {
        let p = MergePlan::identity(5);
        p.validate(5).unwrap();
        assert_eq!(p.matrix_a(), Tensor::eye(5));
        assert_eq!(p.matrix_b(), Tensor::eye(5));
        assert_eq!(p.matrix_ba(), Tensor::eye(5));
    }

    #[test]
    fn random_plans_satisfy_matrix_structure() {
        // property test: A columns one-hot, B columns cluster-supported,
        // BA = B @ A for 50 random plans
        let mut rng = Rng::new(91);
        for _ in 0..50 {
            let n = rng.range(2, 16) as usize;
            let m = rng.range(1, n as i64) as usize;
            let p = sample_plan(n, m, &mut rng);
            p.validate(n).unwrap();
            let a = p.matrix_a();
            for j in 0..n {
                let col_sum: f32 = (0..m).map(|i| a.at2(i, j)).sum();
                assert_eq!(col_sum, 1.0, "A column {j} not one-hot");
            }
            let b = p.matrix_b();
            let ba = ops::matmul(&b, &a).unwrap();
            assert!(ba.rel_err(&p.matrix_ba()) < 1e-6);
            // row sums of A·Bᵀ... and B column sums = 1 per cluster
            for (ci, members) in p.clusters.iter().enumerate() {
                let s: f32 = members.iter().map(|&j| b.at2(j, ci)).sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn validate_rejects_broken_plans() {
        let mut p = MergePlan::identity(4);
        p.weights[2] = 0.5;
        assert!(p.validate(4).is_err());
        let mut p2 = MergePlan::identity(4);
        p2.assign[1] = 0;
        assert!(p2.validate(4).is_err());
        assert!(MergePlan::identity(4).validate(5).is_err());
    }
}
