//! Expert merging — the paper's contribution and all of its baselines.
//!
//! The pipeline for one MoE layer (paper §4 "Summary of the algorithm
//! design"):
//!
//! 1. [`clustering::build_plan`] fixes the summation matrix `A` (Eq. 2) and
//!    the Theorem-1 frequency weights (matrix `B`): the top-M most-used
//!    experts become cluster centers; every other expert joins the center
//!    with the most similar `concat(W_U, W_G)` (cosine).
//! 2. An [`Algorithm`] constructs the M merged experts:
//!    * [`average`]  — uniform parameter averaging (Choshen et al. baseline),
//!    * [`zipit`]    — feature-matching merge (Stoica et al., adapted),
//!    * [`msmoe`]    — frequency-weighted parameter averaging (Li et al.;
//!      equivalently Eq. 4's fixed `T1,T2,T3`),
//!    * [`mergemoe`] — the paper's method: `T2,T3` = frequency-weighted
//!      average, `T1` solved by least squares on calibration activations
//!      (Eq. 5–6),
//!    * [`oracle`]   — Table-5's "w/o merging errors": original experts
//!      kept, outputs merged exactly through the routing map `B·A`.
//! 3. The result is a new [`MoeLayer`] whose router is untouched (Appendix
//!    B: N expert references pointing at M real experts — the routing map
//!    `A`) and whose shared expert, if any, is byte-identical.

pub mod average;
pub mod clustering;
pub mod mergemoe;
pub mod msmoe;
pub mod oracle;
pub mod plan;
pub mod zipit;

use anyhow::{bail, Result};

pub use plan::MergePlan;

use crate::model::native::moe_forward;
use crate::model::workspace::Workspace;
use crate::model::MoeLayer;
use crate::tensor::{ops, Tensor};

/// Backend for the Gram accumulations `(P Pᵀ, Y Pᵀ)` that dominate the
/// MergeMoE solve. [`NativeGram`] computes them with the tensor substrate;
/// the PJRT runtime provides an implementation backed by the `gram_*` HLO
/// artifact (the L1 pallas kernel), which the pipeline injects here.
pub trait GramBackend {
    /// `p` (f, s), `y` (d, s) -> (`P Pᵀ` (f,f), `Y Pᵀ` (d,f)).
    fn gram(&mut self, p: &Tensor, y: &Tensor) -> Result<(Tensor, Tensor)>;

    /// An independent backend instance usable from a worker thread, if the
    /// backend supports concurrent use. `Some` unlocks per-cluster
    /// parallelism in [`mergemoe::merge`]; the default `None` keeps the
    /// cluster loop serial (the PJRT engine owns non-shareable device
    /// state, so its backend stays on the calling thread).
    fn fork(&self) -> Option<Box<dyn GramBackend + Send>> {
        None
    }
}

/// Pure-rust Gram backend (stateless — forks freely).
pub struct NativeGram;

impl GramBackend for NativeGram {
    fn gram(&mut self, p: &Tensor, y: &Tensor) -> Result<(Tensor, Tensor)> {
        // P Pᵀ through the symmetric rank-k kernel: lower triangle only,
        // mirrored — exactly equal to the full product at half the flops.
        Ok((ops::syrk_bt(p)?, ops::matmul_bt(y, p)?))
    }

    fn fork(&self) -> Option<Box<dyn GramBackend + Send>> {
        Some(Box::new(NativeGram))
    }
}

/// The merge algorithms compared in Tables 1–3 (plus the Table-5 oracle).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Average,
    ZipIt,
    MSmoe,
    MergeMoe,
    /// Table-5 "w/o merging errors" — not a compression scheme (keeps all
    /// N experts) but isolates the clustering error.
    Oracle,
}

pub const COMPARED: [Algorithm; 4] =
    [Algorithm::Average, Algorithm::ZipIt, Algorithm::MSmoe, Algorithm::MergeMoe];

impl Algorithm {
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Average => "Average",
            Algorithm::ZipIt => "ZipIt",
            Algorithm::MSmoe => "M-SMoE",
            Algorithm::MergeMoe => "MergeMoE",
            Algorithm::Oracle => "Oracle",
        }
    }

    pub fn from_name(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "average" | "avg" => Some(Algorithm::Average),
            "zipit" => Some(Algorithm::ZipIt),
            "m-smoe" | "msmoe" => Some(Algorithm::MSmoe),
            "mergemoe" => Some(Algorithm::MergeMoe),
            "oracle" => Some(Algorithm::Oracle),
            _ => None,
        }
    }

    /// Whether the algorithm consumes calibration activations.
    pub fn needs_calibration(self) -> bool {
        matches!(self, Algorithm::MergeMoe)
    }
}

/// Merge one MoE layer according to `plan`.
///
/// `calib_x`: post-LN layer inputs X̂ (T, d); required by MergeMoE,
/// ignored by the parameter-space baselines. `ridge` is the relative
/// regularization of the normal-equation solve. `ws` supplies the MergeMoE
/// Gram-panel scratch — callers merging several layers (the compression
/// pipeline) pass one workspace so the panels are reused throughout;
/// one-shot callers pass `&mut Workspace::new()`.
pub fn merge_layer(
    alg: Algorithm,
    moe: &MoeLayer,
    plan: &MergePlan,
    calib_x: Option<&Tensor>,
    gram: &mut dyn GramBackend,
    ridge: f64,
    ws: &mut Workspace,
) -> Result<MoeLayer> {
    plan.validate(moe.n_experts())?;
    match alg {
        Algorithm::Average => average::merge(moe, plan),
        Algorithm::ZipIt => zipit::merge(moe, plan),
        Algorithm::MSmoe => msmoe::merge(moe, plan),
        Algorithm::MergeMoe => {
            let Some(x) = calib_x else {
                bail!("MergeMoE requires calibration activations")
            };
            mergemoe::merge(moe, plan, x, gram, ridge, ws)
        }
        Algorithm::Oracle => oracle::merge(moe, plan),
    }
}

/// Output-space error of a merged layer against the original on a batch of
/// inputs — ‖MoE'(X) − MoE(X)‖_F / ‖MoE(X)‖_F. This is the quantity the
/// paper's optimization minimizes; tests assert the algorithm ordering on
/// it, and the pipeline logs it per layer.
pub fn layer_output_error(original: &MoeLayer, merged: &MoeLayer, x: &Tensor) -> Result<f64> {
    let (y0, _, _) = moe_forward(original, x)?;
    let (y1, _, _) = moe_forward(merged, x)?;
    Ok(y1.sub(&y0)?.frob_norm() / (y0.frob_norm() + 1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::moe::UsageStats;
    use crate::util::rng::Rng;

    fn setup(e: usize, m: usize) -> (MoeLayer, MergePlan, Tensor) {
        let model = tiny_model(e, 2, false, 77);
        let moe = model.layers[0].moe.clone();
        let mut rng = Rng::new(1234);
        let x = Tensor::randn(&[256, 16], 1.0, &mut rng);
        let mut stats = UsageStats::new(e);
        let (_, counts, mass) = moe_forward(&moe, &x).unwrap();
        stats.add(&counts, &mass, 256);
        let plan = clustering::build_plan(&moe, &stats, m).unwrap();
        (moe, plan, x)
    }

    #[test]
    fn all_algorithms_produce_valid_layers() {
        let (moe, plan, x) = setup(8, 4);
        for alg in [Algorithm::Average, Algorithm::ZipIt, Algorithm::MSmoe,
                    Algorithm::MergeMoe, Algorithm::Oracle] {
            let merged =
                merge_layer(alg, &moe, &plan, Some(&x), &mut NativeGram, 1e-6, &mut Workspace::new())
                    .unwrap();
            let expected_experts =
                if alg == Algorithm::Oracle { 8 } else { 4 };
            assert_eq!(merged.n_experts(), expected_experts, "{alg:?}");
            assert_eq!(merged.router.shape(), moe.router.shape(), "{alg:?}");
            assert!(merged.map.is_some(), "{alg:?} must carry a routing map");
            // merged layer must run
            let (y, _, _) = moe_forward(&merged, &x).unwrap();
            assert!(y.data().iter().all(|v| v.is_finite()), "{alg:?}");
        }
    }

    #[test]
    fn mergemoe_beats_msmoe_on_calibration_batch() {
        // Least-squares optimality: on the *same* batch it was fitted to,
        // MergeMoE's output error can only be <= M-SMoE's (M-SMoE is the
        // T1-fixed special case of the same parametrization).
        let (moe, plan, x) = setup(8, 4);
        let msmoe =
            merge_layer(Algorithm::MSmoe, &moe, &plan, Some(&x), &mut NativeGram, 1e-9,
                        &mut Workspace::new())
                .unwrap();
        let mm =
            merge_layer(Algorithm::MergeMoe, &moe, &plan, Some(&x), &mut NativeGram, 1e-9,
                        &mut Workspace::new())
                .unwrap();
        let e_msmoe = layer_output_error(&moe, &msmoe, &x).unwrap();
        let e_mm = layer_output_error(&moe, &mm, &x).unwrap();
        assert!(
            e_mm <= e_msmoe + 1e-6,
            "MergeMoE {e_mm} must not exceed M-SMoE {e_msmoe}"
        );
    }

    #[test]
    fn oracle_error_below_mergemoe() {
        // Table 5: removing the T1/T2/T3 approximation (keeping clustering)
        // must not increase the output error.
        let (moe, plan, x) = setup(8, 4);
        let mm =
            merge_layer(Algorithm::MergeMoe, &moe, &plan, Some(&x), &mut NativeGram, 1e-9,
                        &mut Workspace::new())
                .unwrap();
        let or = merge_layer(Algorithm::Oracle, &moe, &plan, None, &mut NativeGram, 0.0,
                &mut Workspace::new())
            .unwrap();
        let e_mm = layer_output_error(&moe, &mm, &x).unwrap();
        let e_or = layer_output_error(&moe, &or, &x).unwrap();
        assert!(e_or <= e_mm + 1e-6, "oracle {e_or} vs mergemoe {e_mm}");
    }

    #[test]
    fn singleton_clusters_are_lossless_for_all_param_algorithms() {
        // M = N ⇒ every cluster is a singleton ⇒ merging must be exact.
        let (moe, plan, x) = setup(4, 4);
        for alg in [Algorithm::Average, Algorithm::MSmoe, Algorithm::MergeMoe,
                    Algorithm::ZipIt] {
            let merged =
                merge_layer(alg, &moe, &plan, Some(&x), &mut NativeGram, 1e-12,
                            &mut Workspace::new())
                .unwrap();
            let err = layer_output_error(&moe, &merged, &x).unwrap();
            assert!(err < 2e-3, "{alg:?}: singleton merge err {err}");
        }
    }

    #[test]
    fn mergemoe_requires_calibration() {
        let (moe, plan, _) = setup(8, 4);
        assert!(merge_layer(Algorithm::MergeMoe, &moe, &plan, None, &mut NativeGram, 1e-6,
            &mut Workspace::new())
            .is_err());
    }

    #[test]
    fn native_gram_matches_definition() {
        let mut rng = Rng::new(9);
        let p = Tensor::randn(&[6, 50], 1.0, &mut rng);
        let y = Tensor::randn(&[4, 50], 1.0, &mut rng);
        let (pp, yp) = NativeGram.gram(&p, &y).unwrap();
        assert_eq!(pp.shape(), &[6, 6]);
        assert_eq!(yp.shape(), &[4, 6]);
        // symmetry of PPᵀ
        for i in 0..6 {
            for j in 0..6 {
                assert!((pp.at2(i, j) - pp.at2(j, i)).abs() < 1e-4);
            }
        }
    }
}
