//! M-SMoE baseline (Li et al., 2023): frequency-weighted *parameter*
//! averaging. Under the paper's output-merging view this is Eq. 4 —
//! `T1 = [I;I;…]`, `T2 = T3 = [B_1i I, …, B_Ni I]` — i.e. the same
//! parametrization as MergeMoE with `T1` fixed instead of optimized.

use anyhow::Result;

use super::average::weighted_param_merge;
use super::plan::MergePlan;
use crate::model::MoeLayer;

pub fn merge(moe: &MoeLayer, plan: &MergePlan) -> Result<MoeLayer> {
    Ok(MoeLayer {
        router: moe.router.clone(),
        experts: weighted_param_merge(moe, plan, &plan.weights),
        shared: moe.shared.clone(),
        top_k: moe.top_k,
        map: Some(plan.matrix_a()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn uses_frequency_weights() {
        let model = tiny_model(2, 1, false, 22);
        let moe = &model.layers[0].moe;
        let plan = MergePlan {
            n: 2,
            m: 1,
            clusters: vec![vec![0, 1]],
            assign: vec![0, 0],
            weights: vec![0.75, 0.25],
        };
        let merged = merge(moe, &plan).unwrap();
        let want = {
            let mut t = moe.experts[0].wu.clone().scale(0.75);
            t.axpy(0.25, &moe.experts[1].wu).unwrap();
            t
        };
        assert!(merged.experts[0].wu.rel_err(&want) < 1e-6);
    }

    #[test]
    fn shared_expert_untouched() {
        let model = tiny_model(4, 2, true, 23);
        let moe = &model.layers[0].moe;
        let plan = MergePlan {
            n: 4,
            m: 2,
            clusters: vec![vec![0, 1], vec![2, 3]],
            assign: vec![0, 0, 1, 1],
            weights: vec![0.5; 4],
        };
        let merged = merge(moe, &plan).unwrap();
        let orig = moe.shared.as_ref().unwrap();
        let kept = merged.shared.as_ref().unwrap();
        assert_eq!(orig.wg.data(), kept.wg.data());
        assert_eq!(orig.wd.data(), kept.wd.data());
    }
}
