//! Table-5 oracle: "clustering is retained but expert outputs are directly
//! merged, thereby removing merging errors". All N original experts are
//! kept; the routing vector is transformed `r' = (B·A) r`, which realizes
//! `Y B A mask_top_K(·)` exactly — the only remaining error is the
//! clustering error. Not a compression scheme (no memory saved); used to
//! isolate the two error sources in the ablation.

use anyhow::Result;

use super::plan::MergePlan;
use crate::model::MoeLayer;

pub fn merge(moe: &MoeLayer, plan: &MergePlan) -> Result<MoeLayer> {
    Ok(MoeLayer {
        router: moe.router.clone(),
        experts: moe.experts.clone(),
        shared: moe.shared.clone(),
        top_k: moe.top_k,
        map: Some(plan.matrix_ba()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::moe_forward;
    use crate::model::testutil::tiny_model;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn identity_plan_oracle_is_exact() {
        let model = tiny_model(4, 2, true, 50);
        let moe = &model.layers[0].moe;
        let plan = MergePlan::identity(4);
        let o = merge(moe, &plan).unwrap();
        let x = Tensor::randn(&[32, 16], 1.0, &mut Rng::new(51));
        let (y0, _, _) = moe_forward(moe, &x).unwrap();
        let (y1, _, _) = moe_forward(&o, &x).unwrap();
        assert!(y0.rel_err(&y1) < 1e-6);
    }

    #[test]
    fn oracle_keeps_all_experts() {
        let model = tiny_model(6, 2, false, 52);
        let moe = &model.layers[0].moe;
        let plan = MergePlan {
            n: 6,
            m: 2,
            clusters: vec![vec![0, 1, 2], vec![3, 4, 5]],
            assign: vec![0, 0, 0, 1, 1, 1],
            weights: vec![1.0 / 3.0; 6],
        };
        let o = merge(moe, &plan).unwrap();
        assert_eq!(o.n_experts(), 6);
        assert_eq!(o.map.as_ref().unwrap().shape(), &[6, 6]);
    }
}
