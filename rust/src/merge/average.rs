//! Average baseline (Choshen et al., 2022, adapted to expert merging as in
//! the M-SMoE paper's comparison): merged expert = *uniform* mean of the
//! member experts' weight matrices.

use anyhow::Result;

use super::plan::MergePlan;
use crate::model::{Expert, MoeLayer};
use crate::tensor::Tensor;

/// Weighted parameter average of the cluster members (shared by the Average
/// and M-SMoE baselines — they differ only in the weights).
pub fn weighted_param_merge(moe: &MoeLayer, plan: &MergePlan, weights: &[f64]) -> Vec<Expert> {
    plan.clusters
        .iter()
        .map(|members| {
            let proto = &moe.experts[members[0]];
            let mut wg = Tensor::zeros(proto.wg.shape());
            let mut wu = Tensor::zeros(proto.wu.shape());
            let mut wd = Tensor::zeros(proto.wd.shape());
            for &j in members {
                let w = weights[j] as f32;
                wg.axpy(w, &moe.experts[j].wg).unwrap();
                wu.axpy(w, &moe.experts[j].wu).unwrap();
                wd.axpy(w, &moe.experts[j].wd).unwrap();
            }
            Expert { wg, wu, wd }
        })
        .collect()
}

pub fn merge(moe: &MoeLayer, plan: &MergePlan) -> Result<MoeLayer> {
    // uniform weights within each cluster
    let mut w = vec![0.0f64; plan.n];
    for members in &plan.clusters {
        for &j in members {
            w[j] = 1.0 / members.len() as f64;
        }
    }
    Ok(MoeLayer {
        router: moe.router.clone(),
        experts: weighted_param_merge(moe, plan, &w),
        shared: moe.shared.clone(),
        top_k: moe.top_k,
        map: Some(plan.matrix_a()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn uniform_average_of_identical_experts_is_identity() {
        let model = tiny_model(4, 2, false, 20);
        let mut moe = model.layers[0].moe.clone();
        moe.experts[1] = moe.experts[0].clone();
        moe.experts[3] = moe.experts[2].clone();
        let plan = MergePlan {
            n: 4,
            m: 2,
            clusters: vec![vec![0, 1], vec![2, 3]],
            assign: vec![0, 0, 1, 1],
            weights: vec![0.5; 4],
        };
        let merged = merge(&moe, &plan).unwrap();
        assert_eq!(merged.n_experts(), 2);
        assert!(merged.experts[0].wg.rel_err(&moe.experts[0].wg) < 1e-6);
        assert!(merged.experts[1].wd.rel_err(&moe.experts[2].wd) < 1e-6);
    }

    #[test]
    fn average_midpoint() {
        let model = tiny_model(2, 1, false, 21);
        let moe = &model.layers[0].moe;
        let plan = MergePlan {
            n: 2,
            m: 1,
            clusters: vec![vec![0, 1]],
            assign: vec![0, 0],
            // plan weights are frequencies (ignored by Average)
            weights: vec![0.9, 0.1],
        };
        let merged = merge(moe, &plan).unwrap();
        let mid = moe.experts[0].wg.add(&moe.experts[1].wg).unwrap().scale(0.5);
        assert!(merged.experts[0].wg.rel_err(&mid) < 1e-6);
    }
}
