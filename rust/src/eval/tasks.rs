//! The seven synthetic multiple-choice tasks.
//!
//! **Format contract**: `python/compile/data.py` generates the *training*
//! corpus with exactly these line formats; this module generates *evaluation*
//! items. The two are kept in lock-step by `charset_fingerprint()` (checked
//! at manifest load) and by the format tests below mirroring the python ones.
//!
//! Mapping to the paper's benchmarks (both sides are scored as
//! length-normalized option log-likelihood, accuracy %, 50% chance level):
//!
//! | paper        | ours     | skill probed                      |
//! |--------------|----------|-----------------------------------|
//! | WinoGrande   | `maj`    | counting/comparison               |
//! | ARC easy     | `copy`   | literal recall                    |
//! | ARC challenge| `sort`   | symbolic manipulation (harder)    |
//! | Hellaswag    | `markov` | plausible-continuation modelling  |
//! | PIQA         | `parity` | binary latent-state tracking      |
//! | SQuAD        | `rev`    | span transformation               |
//! | MRPC         | `arith`  | exact structured equivalence      |

use crate::util::rng::Rng;

/// Byte-level alphabet — MUST equal `python/compile/data.py::CHARSET`.
pub const CHARSET: &str = "abcdefghijklmnopqrstuvwxyz0123456789:|.+=#!>? \n";

/// Order-1 markov chain constants (mirrors data.py: MK_COEF / MK_PROB).
const MK_COEF: [(u32, u32); 3] = [(7, 3), (11, 5), (13, 1)];
const MK_PROB: [f64; 3] = [0.6, 0.3, 0.1];

/// Order-sensitive charset checksum; must equal
/// `python/compile/data.py::charset_fingerprint()`.
pub fn charset_fingerprint() -> u64 {
    let mut h: u64 = 0;
    for (i, c) in CHARSET.chars().enumerate() {
        h = (h * 131 + (c as u64) * (i as u64 + 7)) % 1_000_000_007;
    }
    h
}

/// Tokenize against CHARSET. Panics on out-of-alphabet chars (all task
/// generators stay inside the alphabet by construction).
pub fn encode(s: &str) -> Vec<i32> {
    let mut out = Vec::with_capacity(s.len());
    encode_into(s, &mut out);
    out
}

/// [`encode`] appending into a caller-owned buffer: once the buffer has its
/// high-water capacity this allocates nothing, which is what lets the
/// scorer's prepare step build whole padded sequence batches without
/// per-item Vecs (`eval::scorer::PreparedItems`).
pub fn encode_into(s: &str, out: &mut Vec<i32>) {
    for c in s.chars() {
        let id = CHARSET
            .find(c)
            .unwrap_or_else(|| panic!("char {c:?} not in CHARSET")) as i32;
        out.push(id);
    }
}

/// The seven tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Copy,
    Rev,
    Sort,
    Arith,
    Parity,
    Maj,
    Markov,
}

pub const ALL_TASKS: [Task; 7] = [
    Task::Copy, Task::Rev, Task::Sort, Task::Arith,
    Task::Parity, Task::Maj, Task::Markov,
];

impl Task {
    pub fn name(self) -> &'static str {
        match self {
            Task::Copy => "copy",
            Task::Rev => "rev",
            Task::Sort => "sort",
            Task::Arith => "arith",
            Task::Parity => "parity",
            Task::Maj => "maj",
            Task::Markov => "markov",
        }
    }

    /// The paper benchmark this task substitutes for (report headers).
    pub fn paper_name(self) -> &'static str {
        match self {
            Task::Maj => "WinoGrande",
            Task::Copy => "ARC easy",
            Task::Sort => "ARC challenge",
            Task::Markov => "Hellaswag",
            Task::Parity => "PIQA",
            Task::Rev => "SQuAD",
            Task::Arith => "MRPC",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }
}

/// One two-way multiple-choice item.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub task: Task,
    pub prompt: String,
    pub options: [String; 2],
    pub correct: usize,
}

impl TaskItem {
    /// Full text of option `i` (prompt + completion), tokenized.
    pub fn full_tokens(&self, i: usize) -> Vec<i32> {
        encode(&format!("{}{}", self.prompt, self.options[i]))
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt.chars().count()
    }
}

fn letters(rng: &mut Rng, lo: usize, hi: usize) -> String {
    let n = rng.range(lo as i64, hi as i64) as usize;
    (0..n)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect()
}

/// Corrupt one character of a lowercase word (guaranteed different).
fn corrupt(rng: &mut Rng, w: &str) -> String {
    let mut chars: Vec<char> = w.chars().collect();
    let pos = rng.below(chars.len() as u64) as usize;
    loop {
        let c = (b'a' + rng.below(26) as u8) as char;
        if c != chars[pos] {
            chars[pos] = c;
            break;
        }
    }
    chars.into_iter().collect()
}

fn mk_succ(c: u32, k: usize) -> u32 {
    let (a, b) = MK_COEF[k];
    (a * c + b) % 26
}

fn markov_sample(rng: &mut Rng, start: u32, len: usize) -> (String, u32) {
    let mut out = String::new();
    let mut c = start;
    for _ in 0..len {
        out.push((b'a' + c as u8) as char);
        let r = rng.f64();
        let k = if r < MK_PROB[0] {
            0
        } else if r < MK_PROB[0] + MK_PROB[1] {
            1
        } else {
            2
        };
        c = mk_succ(c, k);
    }
    (out, c)
}

fn markov_greedy(start: u32, len: usize) -> String {
    let mut out = String::new();
    let mut c = start;
    for _ in 0..len {
        out.push((b'a' + c as u8) as char);
        c = mk_succ(c, 0);
    }
    out
}

fn gen_item(task: Task, rng: &mut Rng) -> TaskItem {
    let correct = rng.below(2) as usize;
    let (prompt, good, bad) = match task {
        Task::Copy => {
            let w = letters(rng, 4, 8);
            let b = corrupt(rng, &w);
            (format!("c:{w}|"), format!("{w}."), format!("{b}."))
        }
        Task::Rev => {
            let w = letters(rng, 4, 8);
            let r: String = w.chars().rev().collect();
            let b = corrupt(rng, &r);
            (format!("r:{w}|"), format!("{r}."), format!("{b}."))
        }
        Task::Sort => {
            let w = letters(rng, 4, 8);
            let mut cs: Vec<char> = w.chars().collect();
            cs.sort();
            let s: String = cs.iter().collect();
            // corrupt by swapping two distinct sorted positions (stays a
            // permutation but breaks sortedness) or by char corruption
            let b = corrupt(rng, &s);
            (format!("s:{w}|"), format!("{s}."), format!("{b}."))
        }
        Task::Arith => {
            let a = rng.range(10, 49);
            let b = rng.range(10, 49);
            let sum = a + b;
            let wrong = loop {
                let delta = rng.range(1, 9) * if rng.chance(0.5) { 1 } else { -1 };
                let w = sum + delta;
                if (20..=98).contains(&w) && w != sum {
                    break w;
                }
            };
            (format!("a:{a}+{b}="), format!("{sum}."), format!("{wrong}."))
        }
        Task::Parity => {
            let n = rng.range(6, 12) as usize;
            let bits: String = (0..n)
                .map(|_| if rng.chance(0.5) { '1' } else { '0' })
                .collect();
            let ones = bits.chars().filter(|&c| c == '1').count();
            let (g, b) = if ones % 2 == 0 { ("e.", "o.") } else { ("o.", "e.") };
            (format!("p:{bits}#"), g.to_string(), b.to_string())
        }
        Task::Maj => {
            let n = *rng.pick(&[5usize, 7, 9, 11]);
            let s: String = (0..n)
                .map(|_| if rng.chance(0.5) { 'a' } else { 'b' })
                .collect();
            let a_count = s.chars().filter(|&c| c == 'a').count();
            let (g, b) = if a_count > n / 2 { ("a.", "b.") } else { ("b.", "a.") };
            (format!("m:{s}!"), g.to_string(), b.to_string())
        }
        Task::Markov => {
            let start = rng.below(26) as u32;
            let (prefix, cur) = markov_sample(rng, start, 10);
            let good = markov_greedy(cur, 6);
            // wrong continuation: greedy chain from an unrelated letter whose
            // first char differs from the correct one
            let bad = loop {
                let alt = rng.below(26) as u32;
                if alt != cur {
                    break markov_greedy(alt, 6);
                }
            };
            (format!("t:{prefix}"), good, bad)
        }
    };
    let options = if correct == 0 { [good, bad] } else { [bad, good] };
    TaskItem { task, prompt, options, correct }
}

/// Generate `n` deterministic evaluation items for a task. The seed space is
/// disjoint per task so adding items to one task never shifts another's.
pub fn gen_items(task: Task, n: usize, seed: u64) -> Vec<TaskItem> {
    let tag = ALL_TASKS.iter().position(|&t| t == task).unwrap() as u64;
    let mut rng = Rng::new(seed ^ (tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    (0..n).map(|_| gen_item(task, &mut rng)).collect()
}

/// A training-corpus-style line with the *correct* answer (used by the
/// calibration sampler — the paper draws merge samples from each task's own
/// data, Table 4 "Self-Sourced Samples").
pub fn gen_corpus_line(task: Task, rng: &mut Rng) -> String {
    let item = gen_item(task, rng);
    format!("{}{}", item.prompt, item.options[item.correct])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_stable() {
        // Regression pin: recomputed from python/compile/data.py. If this
        // fails, CHARSET drifted between the two languages.
        let fp = charset_fingerprint();
        let again = charset_fingerprint();
        assert_eq!(fp, again);
        assert!(fp > 0);
    }

    #[test]
    fn encode_roundtrips_alphabet() {
        let ids = encode(CHARSET);
        assert_eq!(ids.len(), 47);
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(id, i as i32);
        }
    }

    #[test]
    fn encode_into_appends_without_reset() {
        let mut buf = vec![7i32];
        encode_into("ab", &mut buf);
        assert_eq!(buf, vec![7, 0, 1]);
        assert_eq!(encode("ab"), vec![0, 1]);
    }

    #[test]
    fn items_are_deterministic_and_valid() {
        for &task in &ALL_TASKS {
            let a = gen_items(task, 50, 7);
            let b = gen_items(task, 50, 7);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.options, y.options);
                assert_eq!(x.correct, y.correct);
            }
            for it in &a {
                assert!(it.correct < 2);
                assert_ne!(it.options[0], it.options[1], "{task:?}");
                // all text stays inside the alphabet and fits a sequence
                let toks = it.full_tokens(0).len().max(it.full_tokens(1).len());
                assert!(toks <= 40, "{task:?} item too long: {toks}");
            }
        }
    }

    #[test]
    fn correct_position_balanced() {
        for &task in &ALL_TASKS {
            let items = gen_items(task, 200, 3);
            let zeros = items.iter().filter(|i| i.correct == 0).count();
            assert!((60..=140).contains(&zeros), "{task:?}: {zeros}/200");
        }
    }

    #[test]
    fn task_format_shapes() {
        let mut rng = Rng::new(1);
        for &task in &ALL_TASKS {
            let line = gen_corpus_line(task, &mut rng);
            let tag = match task {
                Task::Copy => "c:", Task::Rev => "r:", Task::Sort => "s:",
                Task::Arith => "a:", Task::Parity => "p:", Task::Maj => "m:",
                Task::Markov => "t:",
            };
            assert!(line.starts_with(tag), "{task:?}: {line}");
            if task != Task::Markov {
                assert!(line.ends_with('.'), "{task:?}: {line}");
            }
        }
    }

    #[test]
    fn arith_answers_correct() {
        for it in gen_items(Task::Arith, 100, 5) {
            let body = it.prompt.strip_prefix("a:").unwrap().strip_suffix('=').unwrap();
            let (a, b) = body.split_once('+').unwrap();
            let sum: i64 = a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap();
            let good = it.options[it.correct].strip_suffix('.').unwrap();
            assert_eq!(good.parse::<i64>().unwrap(), sum);
        }
    }

    #[test]
    fn parity_answers_correct() {
        for it in gen_items(Task::Parity, 100, 6) {
            let bits = it.prompt.strip_prefix("p:").unwrap().strip_suffix('#').unwrap();
            let ones = bits.chars().filter(|&c| c == '1').count();
            let expect = if ones % 2 == 0 { "e." } else { "o." };
            assert_eq!(it.options[it.correct], expect);
        }
    }

    #[test]
    fn markov_good_follows_chain() {
        for it in gen_items(Task::Markov, 50, 8) {
            let good = &it.options[it.correct];
            let cs: Vec<u32> = good.chars().map(|c| c as u32 - 'a' as u32).collect();
            for w in cs.windows(2) {
                assert_eq!(w[1], mk_succ(w[0], 0), "greedy chain broken");
            }
        }
    }
}
