//! Multiple-choice scorer: length-normalized option log-likelihood, exactly
//! the protocol the paper's harness (DCLM/lm-eval style) applies to
//! WinoGrande/ARC/PIQA/….
//!
//! For each item, both `prompt+option` strings are tokenized, padded to the
//! model's sequence length and scored in one batched forward pass per chunk;
//! the option with the higher mean per-token log-probability wins. Padding
//! sits *after* the completion and is never scored, so bucket padding cannot
//! change results (asserted by `padding_does_not_change_scores` below and by
//! the 64-vs-96 case in `tests/eval_consistency.rs`).
//!
//! The hot path is workspace-backed: [`PreparedItems`] tokenizes and pads
//! every sequence once into one flat reusable buffer, and
//! [`score_prepared_ws`] streams chunks of it through
//! [`Engine::logits_ws`] + [`target_logprobs_into`] with all scratch drawn
//! from a caller-owned [`EvalScratch`] — zero heap allocations per chunk
//! once the lane is warm (`benches/bench_forward.rs` proves it with the
//! counting allocator). The historical entry point [`score_items`] is a
//! thin allocating wrapper and is bit-identical to the pre-workspace path
//! (`tests/eval_consistency.rs`).

use anyhow::{bail, Result};

use super::tasks::{self, TaskItem};
use crate::model::native::target_logprobs_into;
use crate::model::workspace::EvalScratch;
use crate::model::ModelWeights;
use crate::runtime::Engine;

/// Accuracy over a set of items.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Accuracy {
    pub correct: usize,
    pub total: usize,
}

impl Accuracy {
    pub fn percent(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        100.0 * self.correct as f64 / self.total as f64
    }
}

/// Tokenized, padded, flattened two-option items, ready to stream through
/// an engine chunk by chunk. The buffers are reusable: [`PreparedItems::prepare`]
/// clears and refills them, allocating only while growing past the
/// high-water mark — a sweep that prepares task after task into one
/// instance settles at the largest task's footprint, and the prepared
/// buffer is shared read-only by every (model, task) cell that scores it.
#[derive(Default)]
pub struct PreparedItems {
    seq_len: usize,
    /// Flat (2·n_items, seq_len) padded token rows, option-interleaved.
    tokens: Vec<i32>,
    /// Per sequence: (prompt_len, option_len) in tokens.
    spans: Vec<(usize, usize)>,
    /// Per item: index of the correct option.
    correct: Vec<usize>,
}

impl PreparedItems {
    pub fn new() -> PreparedItems {
        PreparedItems::default()
    }

    /// Tokenize and pad `items` (two sequences per item, interleaved).
    /// Errors if any full sequence exceeds `seq_len`.
    pub fn prepare(&mut self, items: &[TaskItem], seq_len: usize) -> Result<()> {
        let pad = tasks::CHARSET.find('\n').expect("charset newline") as i32;
        self.seq_len = seq_len;
        self.tokens.clear();
        self.spans.clear();
        self.correct.clear();
        for item in items {
            self.correct.push(item.correct);
            for opt in 0..2 {
                let start = self.tokens.len();
                tasks::encode_into(&item.prompt, &mut self.tokens);
                let plen = self.tokens.len() - start;
                tasks::encode_into(&item.options[opt], &mut self.tokens);
                let full = self.tokens.len() - start;
                if full > seq_len {
                    bail!("item longer than seq_len: {full} > {seq_len}");
                }
                self.tokens.resize(start + seq_len, pad);
                self.spans.push((plen, full - plen));
            }
        }
        Ok(())
    }

    pub fn n_items(&self) -> usize {
        self.correct.len()
    }

    pub fn n_seqs(&self) -> usize {
        self.spans.len()
    }

    /// Correct-option index per item.
    pub fn correct(&self) -> &[usize] {
        &self.correct
    }
}

/// Sequences per forward pass: `batch` rounded **up** to the next even
/// count, so an item's two options always travel together and an odd
/// `batch` never silently halves the chunk (the seed rounded down:
/// `batch.max(2) / 2 * 2` turned `--batch 33` into chunks of 32... and
/// `--batch 3` into chunks of 2).
fn even_chunk(batch: usize) -> usize {
    (batch.max(1) + 1) / 2 * 2
}

/// Score every prepared sequence through one scratch lane. Fills
/// `es.scores` with the mean option log-probability of each sequence (two
/// per item, option-interleaved) and returns the accuracy. After the first
/// call has warmed `es`, subsequent calls allocate nothing per chunk.
pub fn score_prepared_ws(
    engine: &mut dyn Engine,
    model: &ModelWeights,
    prep: &PreparedItems,
    batch: usize,
    es: &mut EvalScratch,
) -> Result<Accuracy> {
    let s = prep.seq_len;
    let chunk = even_chunk(batch);
    es.scores.clear();
    let mut lo = 0;
    while lo < prep.n_seqs() {
        let hi = (lo + chunk).min(prep.n_seqs());
        let b = hi - lo;
        let toks = &prep.tokens[lo * s..hi * s];
        engine.logits_ws(model, toks, b, s, &mut es.ws, &mut es.logits)?;
        target_logprobs_into(&es.logits, toks, b, s, &mut es.ws.lps);
        for bi in 0..b {
            let (plen, olen) = prep.spans[lo + bi];
            // positions plen-1 .. plen+olen-2 predict the option tokens
            let mut sum = 0.0f64;
            for si in (plen - 1)..(plen + olen - 1) {
                sum += es.ws.lps[bi * s + si] as f64;
            }
            es.scores.push(sum / olen as f64);
        }
        lo = hi;
    }
    let mut acc = Accuracy::default();
    for (i, &c) in prep.correct.iter().enumerate() {
        let pick = if es.scores[2 * i] >= es.scores[2 * i + 1] { 0 } else { 1 };
        if pick == c {
            acc.correct += 1;
        }
        acc.total += 1;
    }
    Ok(acc)
}

/// Mean log-probability of the *correct* options over per-option `scores`
/// (as filled by [`score_prepared_ws`]) — the sweep's fidelity metric on
/// the calibration distribution, banded by the method-ordering regression
/// test.
pub fn mean_correct_lp(prep: &PreparedItems, scores: &[f64]) -> f64 {
    if prep.n_items() == 0 {
        return 0.0;
    }
    let sum: f64 = prep
        .correct
        .iter()
        .enumerate()
        .map(|(i, &c)| scores[2 * i + c])
        .sum();
    sum / prep.n_items() as f64
}

/// Evaluate items; returns the accuracy. `batch` sets the sequences per
/// forward pass (two per item; odd values round **up** to the next even
/// count so option pairs travel together). Thin allocating wrapper around
/// [`score_prepared_ws`] — callers scoring in a loop (the sweep) hold
/// their own [`PreparedItems`] + [`EvalScratch`] instead.
pub fn score_items(
    engine: &mut dyn Engine,
    model: &ModelWeights,
    items: &[TaskItem],
    seq_len: usize,
    batch: usize,
) -> Result<Accuracy> {
    Ok(score_items_scored(engine, model, items, seq_len, batch)?.0)
}

/// [`score_items`] that also returns the per-option mean log-probabilities
/// (two per item, option-interleaved) — the padding-invariance and
/// method-ordering tests compare these directly.
pub fn score_items_scored(
    engine: &mut dyn Engine,
    model: &ModelWeights,
    items: &[TaskItem],
    seq_len: usize,
    batch: usize,
) -> Result<(Accuracy, Vec<f64>)> {
    let mut prep = PreparedItems::new();
    prep.prepare(items, seq_len)?;
    let mut es = EvalScratch::new();
    let acc = score_prepared_ws(engine, model, &prep, batch, &mut es)?;
    Ok((acc, std::mem::take(&mut es.scores)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::tasks::{gen_items, Task};
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    #[test]
    fn random_model_scores_near_chance() {
        let model = tiny_model(4, 2, false, 80);
        let items = gen_items(Task::Parity, 60, 1);
        let acc = score_items(&mut NativeEngine, &model, &items, 64, 16).unwrap();
        assert_eq!(acc.total, 60);
        // untrained model: accuracy must be within a wide band around 50%
        assert!(
            (20.0..=80.0).contains(&acc.percent()),
            "untrained accuracy {}",
            acc.percent()
        );
    }

    #[test]
    fn batch_size_does_not_change_results() {
        // odd sizes included: the chunking used to round odd batches *down*
        // (silently halving --batch 3 to 2); all sizes must agree exactly,
        // per-option scores included.
        let model = tiny_model(4, 2, true, 81);
        let items = gen_items(Task::Copy, 30, 2);
        let (ref_acc, ref_scores) =
            score_items_scored(&mut NativeEngine, &model, &items, 64, 60).unwrap();
        for batch in [1usize, 3, 4, 5, 7, 16, 59] {
            let (acc, scores) =
                score_items_scored(&mut NativeEngine, &model, &items, 64, batch).unwrap();
            assert_eq!(acc, ref_acc, "batch {batch}");
            assert_eq!(scores, ref_scores, "batch {batch}");
        }
    }

    #[test]
    fn even_chunk_rounds_up() {
        assert_eq!(even_chunk(1), 2);
        assert_eq!(even_chunk(2), 2);
        assert_eq!(even_chunk(3), 4);
        assert_eq!(even_chunk(32), 32);
        assert_eq!(even_chunk(33), 34);
    }

    #[test]
    fn padding_does_not_change_scores() {
        // the module-doc promise: bucket padding after the completion is
        // never scored, so the same items at different seq_len produce
        // identical accuracy AND identical per-option scores (the causal
        // forward makes scored positions independent of trailing pad)
        let model = tiny_model(4, 2, true, 83);
        let items = gen_items(Task::Arith, 25, 4);
        let (acc_a, scores_a) =
            score_items_scored(&mut NativeEngine, &model, &items, 48, 16).unwrap();
        let (acc_b, scores_b) =
            score_items_scored(&mut NativeEngine, &model, &items, 64, 16).unwrap();
        assert_eq!(acc_a, acc_b);
        assert_eq!(scores_a, scores_b);
    }

    #[test]
    fn prepared_buffers_reuse_across_tasks() {
        // one PreparedItems + one EvalScratch carried across tasks (the
        // sweep's lane pattern) must match fresh per-task scoring
        let model = tiny_model(4, 2, false, 84);
        let mut prep = PreparedItems::new();
        let mut es = EvalScratch::new();
        for task in [Task::Copy, Task::Parity, Task::Copy, Task::Maj] {
            let items = gen_items(task, 20, 5);
            prep.prepare(&items, 64).unwrap();
            let acc = score_prepared_ws(&mut NativeEngine, &model, &prep, 8, &mut es).unwrap();
            let (want_acc, want_scores) =
                score_items_scored(&mut NativeEngine, &model, &items, 64, 8).unwrap();
            assert_eq!(acc, want_acc, "{task:?}");
            assert_eq!(es.scores, want_scores, "{task:?}");
            assert_eq!(
                mean_correct_lp(&prep, &es.scores),
                mean_correct_lp(&prep, &want_scores)
            );
        }
    }

    #[test]
    fn rejects_overlong_items() {
        let model = tiny_model(4, 2, false, 82);
        let items = gen_items(Task::Copy, 1, 3);
        assert!(score_items(&mut NativeEngine, &model, &items, 8, 4).is_err());
    }

    #[test]
    fn accuracy_percent() {
        let a = Accuracy { correct: 3, total: 4 };
        assert_eq!(a.percent(), 75.0);
        assert_eq!(Accuracy::default().percent(), 0.0);
    }
}
