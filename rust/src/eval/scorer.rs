//! Multiple-choice scorer: length-normalized option log-likelihood, exactly
//! the protocol the paper's harness (DCLM/lm-eval style) applies to
//! WinoGrande/ARC/PIQA/….
//!
//! For each item, both `prompt+option` strings are tokenized, padded to the
//! model's sequence length and scored in one batched forward pass per chunk;
//! the option with the higher mean per-token log-probability wins. Padding
//! sits *after* the completion and is never scored, so bucket padding cannot
//! change results (asserted by the padding-invariance test).

use anyhow::{bail, Result};

use super::tasks::{self, TaskItem};
use crate::model::native::target_logprobs;
use crate::model::ModelWeights;
use crate::runtime::Engine;

/// Accuracy over a set of items.
#[derive(Debug, Clone, Copy, Default)]
pub struct Accuracy {
    pub correct: usize,
    pub total: usize,
}

impl Accuracy {
    pub fn percent(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        100.0 * self.correct as f64 / self.total as f64
    }
}

/// Score one batch of (tokens, prompt_len, option_len) sequences; returns
/// the mean option log-probability for each.
fn score_batch(
    engine: &mut dyn Engine,
    model: &ModelWeights,
    seqs: &[(Vec<i32>, usize, usize)],
    seq_len: usize,
) -> Result<Vec<f64>> {
    let b = seqs.len();
    let mut tokens = Vec::with_capacity(b * seq_len);
    for (t, _, _) in seqs {
        tokens.extend_from_slice(t);
    }
    let logits = engine.logits(model, &tokens, b, seq_len)?;
    let lps = target_logprobs(&logits, &tokens, b, seq_len);
    let mut out = Vec::with_capacity(b);
    for (bi, (_, plen, olen)) in seqs.iter().enumerate() {
        // positions plen-1 .. plen+olen-2 predict the option tokens
        let mut sum = 0.0f64;
        for si in (*plen - 1)..(*plen + *olen - 1) {
            sum += lps[bi * seq_len + si] as f64;
        }
        out.push(sum / *olen as f64);
    }
    Ok(out)
}

/// Evaluate items; returns the accuracy. `batch` bounds the number of
/// sequences per forward pass (two per item).
pub fn score_items(
    engine: &mut dyn Engine,
    model: &ModelWeights,
    items: &[TaskItem],
    seq_len: usize,
    batch: usize,
) -> Result<Accuracy> {
    let pad = tasks::encode("\n")[0];
    // two sequences per item, interleaved
    let mut seqs: Vec<(Vec<i32>, usize, usize)> = Vec::with_capacity(items.len() * 2);
    for item in items {
        for opt in 0..2 {
            let toks = item.full_tokens(opt);
            if toks.len() > seq_len {
                bail!("item longer than seq_len: {} > {seq_len}", toks.len());
            }
            let plen = item.prompt_len();
            let olen = toks.len() - plen;
            let mut padded = toks;
            padded.resize(seq_len, pad);
            seqs.push((padded, plen, olen));
        }
    }
    let mut scores = Vec::with_capacity(seqs.len());
    for chunk in seqs.chunks(batch.max(2) / 2 * 2) {
        scores.extend(score_batch(engine, model, chunk, seq_len)?);
    }
    let mut acc = Accuracy::default();
    for (i, item) in items.iter().enumerate() {
        let pick = if scores[2 * i] >= scores[2 * i + 1] { 0 } else { 1 };
        if pick == item.correct {
            acc.correct += 1;
        }
        acc.total += 1;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::tasks::{gen_items, Task};
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    #[test]
    fn random_model_scores_near_chance() {
        let model = tiny_model(4, 2, false, 80);
        let items = gen_items(Task::Parity, 60, 1);
        let acc = score_items(&mut NativeEngine, &model, &items, 64, 16).unwrap();
        assert_eq!(acc.total, 60);
        // untrained model: accuracy must be within a wide band around 50%
        assert!(
            (20.0..=80.0).contains(&acc.percent()),
            "untrained accuracy {}",
            acc.percent()
        );
    }

    #[test]
    fn batch_size_does_not_change_results() {
        let model = tiny_model(4, 2, true, 81);
        let items = gen_items(Task::Copy, 30, 2);
        let a = score_items(&mut NativeEngine, &model, &items, 64, 4).unwrap();
        let b = score_items(&mut NativeEngine, &model, &items, 64, 60).unwrap();
        assert_eq!(a.correct, b.correct);
    }

    #[test]
    fn rejects_overlong_items() {
        let model = tiny_model(4, 2, false, 82);
        let items = gen_items(Task::Copy, 1, 3);
        assert!(score_items(&mut NativeEngine, &model, &items, 8, 4).is_err());
    }

    #[test]
    fn accuracy_percent() {
        let a = Accuracy { correct: 3, total: 4 };
        assert_eq!(a.percent(), 75.0);
        assert_eq!(Accuracy::default().percent(), 0.0);
    }
}
