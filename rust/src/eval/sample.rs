//! Seeded token sampling and the autoregressive generation loop
//! (`mergemoe generate`).
//!
//! A [`Sampler`] turns one logits row into a token id: greedy argmax,
//! temperature-scaled softmax sampling, and the standard truncation
//! filters — top-k (only the k highest-logit tokens are candidates, via
//! [`ops::top_k_order`]) and top-p (the minimal descending prefix of the
//! candidate distribution holding at least `p` of its probability mass).
//! Randomness comes from the caller's [`Rng`], so equal seeds reproduce
//! equal token sequences bit for bit — across runs *and* thread counts,
//! because the decode forward underneath is thread-invariant
//! (`tests/decode_consistency.rs` pins both).
//!
//! [`generate_into`] drives an [`Engine::decode_step`] loop over a growing
//! prefix: the native engine serves it from the KV cache (O(S) per token),
//! any other backend through the default re-prefill fallback — same tokens
//! either way. Generation stops cleanly at the model's trained context
//! window (`pos_emb` rows) and reports how many tokens were produced; it
//! never trips the forward pass's typed
//! [`ContextOverflow`](crate::model::native::ContextOverflow).
//!
//! The sampler and the loop follow the workspace discipline: every buffer
//! (candidate ordering, probabilities, the token vector, the KV slabs) is
//! caller- or self-owned and reused, so a warm generation allocates
//! nothing (`benches/bench_forward.rs` probes the loop under the counting
//! allocator).

use anyhow::{bail, Result};

use crate::model::native::ContextOverflow;
use crate::model::workspace::{KvScratch, Workspace};
use crate::model::ModelWeights;
use crate::runtime::Engine;
use crate::tensor::{ops, Tensor};
use crate::util::rng::Rng;

/// Index of the row maximum, ties broken toward the lower index — exactly
/// the head of [`ops::top_k_order`], so greedy decoding and a `top_k = 1`
/// sampler agree by construction.
pub fn argmax(row: &[f32]) -> usize {
    assert!(!row.is_empty(), "argmax of an empty row");
    let mut best = 0;
    for (i, v) in row.iter().enumerate().skip(1) {
        if v.total_cmp(&row[best]) == std::cmp::Ordering::Greater {
            best = i;
        }
    }
    best
}

/// Reusable token sampler over logits rows. Construction fixes the policy;
/// [`Sampler::sample`] draws tokens. Internal scratch (candidate order,
/// candidate probabilities) is retained across calls, so a warm sampler
/// never allocates.
#[derive(Debug, Clone, Default)]
pub struct Sampler {
    temperature: f32,
    top_k: usize,
    top_p: f32,
    probs: Vec<f32>,
    order: Vec<usize>,
}

impl Sampler {
    /// Deterministic argmax decoding (`temperature = 0`): [`Sampler::sample`]
    /// never touches the RNG.
    pub fn greedy() -> Sampler {
        Sampler::new(0.0, 0, 1.0)
    }

    /// Temperature sampling with optional truncation: `temperature <= 0`
    /// means greedy, `top_k = 0` disables the top-k filter, `top_p >= 1`
    /// disables the nucleus filter. The filters compose in the standard
    /// order — top-k restricts the candidate set, top-p then keeps the
    /// minimal high-probability prefix of it.
    pub fn new(temperature: f32, top_k: usize, top_p: f32) -> Sampler {
        Sampler { temperature, top_k, top_p, probs: Vec::new(), order: Vec::new() }
    }

    /// Draw one token id from a logits row. Greedy configurations return
    /// [`argmax`] without consuming randomness; sampling configurations
    /// consume exactly one `rng.f64()` draw per call, so a seeded stream
    /// replays the same token sequence on identical logits.
    pub fn sample(&mut self, row: &[f32], rng: &mut Rng) -> usize {
        assert!(!row.is_empty(), "sampling from an empty logits row");
        if self.temperature <= 0.0 {
            return argmax(row);
        }
        let k = if self.top_k == 0 { row.len() } else { self.top_k.min(row.len()) };
        ops::top_k_order(row, k, &mut self.order);
        // softmax over the candidates at temperature T, computed against the
        // candidate max (order[0]; positive 1/T preserves the logit order)
        let inv_t = 1.0 / self.temperature;
        let m = row[self.order[0]] * inv_t;
        self.probs.clear();
        let mut total = 0.0f64;
        for &i in &self.order {
            let p = (row[i] * inv_t - m).exp();
            self.probs.push(p);
            total += p as f64;
        }
        // nucleus: the shortest descending prefix with mass >= top_p·total
        let mut keep = self.order.len();
        if (self.top_p as f64) < 1.0 {
            let target = self.top_p as f64 * total;
            let mut mass = 0.0f64;
            for (n, &p) in self.probs.iter().enumerate() {
                mass += p as f64;
                if mass >= target {
                    keep = n + 1;
                    break;
                }
            }
        }
        let kept: f64 = self.probs[..keep].iter().map(|&p| p as f64).sum();
        // inverse-CDF draw over the kept prefix, in fixed descending order
        let r = rng.f64() * kept;
        let mut mass = 0.0f64;
        for (n, &p) in self.probs[..keep].iter().enumerate() {
            mass += p as f64;
            if r < mass {
                return self.order[n];
            }
        }
        self.order[keep - 1]
    }
}

/// What a generation run produced (the token ids themselves land in the
/// caller's buffer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GenerateStats {
    /// New tokens appended after the prompt.
    pub produced: usize,
    /// Whether the run stopped early because the next token would sit past
    /// the trained context window (`pos_emb` rows).
    pub hit_context_limit: bool,
}

/// Autoregressive generation through a caller-owned arena: decode the
/// prompt, then sample-and-extend until `max_new` tokens were produced or
/// the trained context window is full. `tokens` is cleared and receives
/// prompt + generated ids; `kv` is reset and left warm (its slabs cover the
/// whole run — a second call on the same buffers allocates nothing).
///
/// A prompt longer than the context window surfaces the forward pass's
/// typed [`ContextOverflow`](crate::model::native::ContextOverflow);
/// running *into* the window mid-generation is a clean stop with
/// [`GenerateStats::hit_context_limit`] set.
#[allow(clippy::too_many_arguments)]
pub fn generate_into(
    engine: &mut dyn Engine,
    model: &ModelWeights,
    prompt: &[i32],
    max_new: usize,
    sampler: &mut Sampler,
    rng: &mut Rng,
    kv: &mut KvScratch,
    ws: &mut Workspace,
    logits: &mut Tensor,
    tokens: &mut Vec<i32>,
) -> Result<GenerateStats> {
    if prompt.is_empty() {
        bail!("generate: empty prompt (the decode loop needs at least one token)");
    }
    let context = model.pos_emb.shape()[0];
    if prompt.len() > context {
        // the prompt alone cannot be decoded — typed, not a silent 0-token
        // "success" (a prompt exactly filling the window is the clean-stop
        // case below instead)
        return Err(ContextOverflow { pos: context, context }.into());
    }
    kv.reset();
    tokens.clear();
    tokens.extend_from_slice(prompt);
    let mut stats = GenerateStats { produced: 0, hit_context_limit: false };
    for _ in 0..max_new {
        if tokens.len() >= context {
            stats.hit_context_limit = true;
            break;
        }
        engine.decode_step(model, tokens, kv, ws, logits)?;
        let next = sampler.sample(logits.row(0), rng) as i32;
        tokens.push(next);
        stats.produced += 1;
    }
    Ok(stats)
}

/// Allocating wrapper around [`generate_into`]: spins up throwaway
/// buffers and returns the full token sequence. Results are bit-identical
/// to the arena path.
pub fn generate(
    engine: &mut dyn Engine,
    model: &ModelWeights,
    prompt: &[i32],
    max_new: usize,
    sampler: &mut Sampler,
    rng: &mut Rng,
) -> Result<(Vec<i32>, GenerateStats)> {
    let mut kv = KvScratch::new();
    let mut ws = Workspace::new();
    let mut logits = Tensor::default();
    let mut tokens = Vec::new();
    let stats = generate_into(
        engine, model, prompt, max_new, sampler, rng, &mut kv, &mut ws, &mut logits, &mut tokens,
    )?;
    Ok((tokens, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn softmax(row: &[f32], temp: f32) -> Vec<f64> {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = row.iter().map(|&v| (((v - m) / temp) as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        exps.into_iter().map(|e| e / z).collect()
    }

    fn random_row(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * 6.0).collect()
    }

    #[test]
    fn greedy_equals_argmax_and_skips_the_rng() {
        let mut rng = Rng::new(40);
        let mut s = Sampler::greedy();
        for _ in 0..50 {
            let row = random_row(&mut rng, 31);
            let mut order = Vec::new();
            ops::top_k_order(&row, 1, &mut order);
            let mut probe = Rng::new(1234);
            let before = probe.clone().next_u64();
            let got = s.sample(&row, &mut probe);
            assert_eq!(got, order[0], "greedy must equal top_k_order's head");
            assert_eq!(got, argmax(&row));
            assert_eq!(probe.next_u64(), before, "greedy must not consume randomness");
        }
    }

    #[test]
    fn same_seed_same_token_stream() {
        let mut rng = Rng::new(41);
        let row = random_row(&mut rng, 47);
        let mut a = Sampler::new(0.9, 12, 0.95);
        let mut b = Sampler::new(0.9, 12, 0.95);
        let mut ra = Rng::new(77);
        let mut rb = Rng::new(77);
        let xs: Vec<usize> = (0..200).map(|_| a.sample(&row, &mut ra)).collect();
        let ys: Vec<usize> = (0..200).map(|_| b.sample(&row, &mut rb)).collect();
        assert_eq!(xs, ys);
        let mut rc = Rng::new(78);
        let zs: Vec<usize> = (0..200).map(|_| a.sample(&row, &mut rc)).collect();
        assert_ne!(xs, zs, "a different seed should move some draw in 200");
    }

    #[test]
    fn top_k_never_emits_outside_the_k_best() {
        let mut rng = Rng::new(42);
        for &k in &[1usize, 3, 8] {
            let row = random_row(&mut rng, 40);
            let mut order = Vec::new();
            ops::top_k_order(&row, k, &mut order);
            let mut s = Sampler::new(1.3, k, 1.0);
            let mut draw = Rng::new(9);
            for _ in 0..300 {
                let t = s.sample(&row, &mut draw);
                assert!(order.contains(&t), "token {t} outside the top-{k} set");
            }
        }
    }

    #[test]
    fn top_p_nucleus_is_the_minimal_covering_prefix() {
        let mut rng = Rng::new(43);
        for case in 0..20u64 {
            let row = random_row(&mut rng, 30);
            let temp = 0.7f32;
            let top_p = 0.85f32;
            // Reference nucleus with the sampler's own candidate arithmetic
            // (f32 exponentials against the candidate max, f64 cumulation),
            // so the prefix boundary is bit-exact — no tolerance games at
            // the mass threshold.
            let inv_t = 1.0 / temp;
            let mut order = Vec::new();
            ops::top_k_order(&row, row.len(), &mut order);
            let m = row[order[0]] * inv_t;
            let exps: Vec<f32> = order.iter().map(|&i| (row[i] * inv_t - m).exp()).collect();
            let total: f64 = exps.iter().map(|&e| e as f64).sum();
            let target = top_p as f64 * total;
            let mut keep = order.len();
            let mut mass = 0.0f64;
            for (n, &e) in exps.iter().enumerate() {
                mass += e as f64;
                if mass >= target {
                    keep = n + 1;
                    break;
                }
            }
            // the covering property: the prefix holds >= p of the mass and
            // no shorter prefix does
            let covered: f64 = exps[..keep].iter().map(|&e| e as f64).sum();
            assert!(covered >= target, "case {case}: nucleus mass {covered} < {target}");
            if keep > 1 {
                let shorter: f64 = exps[..keep - 1].iter().map(|&e| e as f64).sum();
                assert!(shorter < target, "case {case}: prefix not minimal");
            }
            let nucleus = &order[..keep];
            // the sampler only ever emits nucleus members, and reaches every
            // non-negligible one in a long run
            let mut s = Sampler::new(temp, 0, top_p);
            let mut draw = Rng::new(case + 100);
            let mut seen = vec![false; row.len()];
            for _ in 0..2000 {
                let t = s.sample(&row, &mut draw);
                assert!(nucleus.contains(&t), "case {case}: token {t} outside the nucleus");
                seen[t] = true;
            }
            for (n, &i) in nucleus.iter().enumerate() {
                if exps[n] as f64 / covered > 0.05 {
                    assert!(seen[i], "case {case}: nucleus member {i} never drawn");
                }
            }
        }
    }

    #[test]
    fn temperature_to_zero_converges_to_greedy() {
        let mut rng = Rng::new(44);
        for _ in 0..30 {
            let mut row = random_row(&mut rng, 25);
            // pin a >= 0.5 logit gap under the max so the convergence is
            // exact, not statistical: at T <= 1e-3 every other token's
            // probability underflows to zero
            let best = argmax(&row);
            row[best] += 0.5;
            for &temp in &[1e-3f32, 1e-4] {
                let mut s = Sampler::new(temp, 0, 1.0);
                let mut draw = Rng::new(5);
                for _ in 0..50 {
                    assert_eq!(s.sample(&row, &mut draw), best);
                }
            }
        }
    }

    #[test]
    fn sampled_distribution_tracks_softmax() {
        // a coarse statistical check that unfiltered sampling follows the
        // temperature-scaled softmax (2% absolute tolerance on 20k draws)
        let row = vec![2.0f32, 1.0, 0.0, -1.0];
        let p = softmax(&row, 1.0);
        let mut s = Sampler::new(1.0, 0, 1.0);
        let mut draw = Rng::new(6);
        let mut counts = [0usize; 4];
        let n = 20_000;
        for _ in 0..n {
            counts[s.sample(&row, &mut draw)] += 1;
        }
        for i in 0..4 {
            let freq = counts[i] as f64 / n as f64;
            assert!((freq - p[i]).abs() < 0.02, "token {i}: freq {freq} vs p {}", p[i]);
        }
    }
}
