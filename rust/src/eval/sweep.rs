//! Evaluation sweeps: the paper's accuracy-vs-ratio comparison grid —
//! {compression method} × {ratio} × {task} — in one invocation
//! (`mergemoe sweep`). This is the machinery behind the headline claim:
//! MergeMoE must beat averaging/ZipIt/M-SMoE at the *same* compression
//! ratio (PAPER.md §5), and the method-ordering regression test in
//! `tests/eval_consistency.rs` keeps that ordering under test.
//!
//! Execution model:
//!
//! 1. **Prepare once.** Every task's items are tokenized and padded into a
//!    [`PreparedItems`] buffer up front; the buffers are shared read-only
//!    by every (model, task) cell.
//! 2. **Capture once, compress per variant.** One calibration capture of
//!    the uncompressed model (`capture_calibration`) serves every
//!    (method, ratio) variant through `compress_with_calib`; each merge is
//!    internally parallel (per cluster / per calibration chunk), so the
//!    variant loop stays serial.
//! 3. **Score the grid in parallel.** Independent (variant, task) cells fan
//!    out across the `util::par` worker pool via `par_items_with_slots`,
//!    one forked engine + one [`EvalScratch`] per lane — workspaces are
//!    never shared across threads (the `model::workspace` ownership rule).
//!    Per-cell scoring is strictly serial inside its lane and nested
//!    regions degrade, so sweep results are **bit-identical at every
//!    thread count** (`tests/eval_consistency.rs`). Engines that cannot
//!    fork (PJRT) run the cells serially on the calling thread.
//!
//! The outcome is a [`SweepReport`]: `exp::tables::sweep_table` renders the
//! accuracy-vs-ratio markdown table and `exp::report::save_sweep` persists
//! `SWEEP_<model>.json` + `SWEEP_<model>.md` for bench_diff-style
//! comparison across commits.

use anyhow::{bail, Context, Result};

use super::scorer::{self, PreparedItems};
use super::tasks::{gen_items, Task};
use super::Accuracy;
use crate::coordinator::{capture_calibration, compress_with_calib, CompressSpec};
use crate::merge::{Algorithm, GramBackend};
use crate::model::workspace::{EvalScratch, Workspace};
use crate::model::ModelWeights;
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::par;

/// The evaluation grid: every method × target expert count × task.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Compression methods to compare (each evaluated at every target).
    pub methods: Vec<Algorithm>,
    /// Target expert counts per merged layer — one compression ratio each.
    pub targets: Vec<usize>,
    /// Tasks every variant is evaluated on.
    pub tasks: Vec<Task>,
    /// Layer indices to merge.
    pub layers: Vec<usize>,
    /// Items per task.
    pub items: usize,
    pub seq_len: usize,
    /// Sequences per forward chunk (rounded up to even by the scorer).
    pub batch: usize,
    /// Calibration sequences per capture.
    pub n_calib_seqs: usize,
    /// Restrict calibration data to these tasks (None = uniform mixture).
    pub calib_tasks: Option<Vec<Task>>,
    pub seed: u64,
    /// Evaluate the uncompressed model as the first row.
    pub include_full: bool,
}

impl SweepSpec {
    pub fn new(
        methods: Vec<Algorithm>,
        targets: Vec<usize>,
        tasks: Vec<Task>,
        layers: Vec<usize>,
    ) -> SweepSpec {
        SweepSpec {
            methods,
            targets,
            tasks,
            layers,
            items: 100,
            seq_len: 64,
            batch: 32,
            n_calib_seqs: 64,
            calib_tasks: None,
            seed: 2026,
            include_full: true,
        }
    }
}

/// One (variant, task) cell of the grid.
#[derive(Debug, Clone)]
pub struct TaskCell {
    pub task: Task,
    pub acc: Accuracy,
    /// Mean log-probability of the correct option — the fidelity metric on
    /// the calibration distribution that the method-ordering regression
    /// test bands (oracle ≥ mergemoe ≥ average).
    pub mean_correct_lp: f64,
}

/// One compressed (or full) model variant with its per-task results.
#[derive(Debug, Clone)]
pub struct VariantResult {
    /// Row label: `"Full"` or the algorithm name.
    pub label: String,
    /// Target expert count (the original count for the full row).
    pub m: usize,
    pub params: usize,
    /// `params / params(full)`.
    pub ratio: f64,
    pub merge_seconds: f64,
    /// Mean per-layer output relative error of the merge (0 for Full).
    pub mean_layer_err: f64,
    /// One cell per task, in `SweepSpec::tasks` order.
    pub cells: Vec<TaskCell>,
}

impl VariantResult {
    /// Mean accuracy across the variant's tasks (the paper's "Avg" column).
    pub fn mean_percent(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        self.cells.iter().map(|c| c.acc.percent()).sum::<f64>() / self.cells.len() as f64
    }

    /// Mean correct-option log-probability across the variant's tasks.
    pub fn mean_correct_lp(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        self.cells.iter().map(|c| c.mean_correct_lp).sum::<f64>() / self.cells.len() as f64
    }

    /// The cell for `task`, if the sweep evaluated it.
    pub fn cell(&self, task: Task) -> Option<&TaskCell> {
        self.cells.iter().find(|c| c.task == task)
    }
}

/// Full sweep outcome (serialized as `SWEEP_<model>.json`).
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub model: String,
    pub items: usize,
    pub seq_len: usize,
    pub seed: u64,
    /// Thread budget the sweep ran under (results do not depend on it).
    pub threads: usize,
    /// Compute kernel the sweep ran on (`scalar`/`avx2`/`neon`; scalar vs
    /// SIMD results agree to tolerance, not bit-for-bit — recorded so
    /// cross-machine report diffs can tell kernel drift from science
    /// drift).
    pub kernel: String,
    pub n_calib_tokens: usize,
    pub wall_seconds: f64,
    /// Full first (if requested), then method-major per target in spec
    /// order.
    pub variants: Vec<VariantResult>,
}

impl SweepReport {
    /// The variant row for `(label, m)` — e.g. `("MergeMoE", 6)`.
    pub fn variant(&self, label: &str, m: usize) -> Option<&VariantResult> {
        self.variants.iter().find(|v| v.label == label && v.m == m)
    }

    /// Machine-readable record (`SWEEP_<model>.json`), shaped for
    /// bench_diff-style comparison: stable keys, accuracy in percent.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("items", Json::num(self.items as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("kernel", Json::str(&self.kernel)),
            ("n_calib_tokens", Json::num(self.n_calib_tokens as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            (
                "variants",
                Json::arr(self.variants.iter().map(|v| {
                    Json::obj(vec![
                        ("label", Json::str(&v.label)),
                        ("m", Json::num(v.m as f64)),
                        ("params", Json::num(v.params as f64)),
                        ("ratio", Json::num(v.ratio)),
                        ("merge_seconds", Json::num(v.merge_seconds)),
                        ("mean_layer_err", Json::num(v.mean_layer_err)),
                        ("mean_acc", Json::num(v.mean_percent())),
                        (
                            "tasks",
                            Json::Obj(
                                v.cells
                                    .iter()
                                    .map(|c| {
                                        (
                                            c.task.name().to_string(),
                                            Json::obj(vec![
                                                ("acc", Json::num(c.acc.percent())),
                                                ("correct", Json::num(c.acc.correct as f64)),
                                                ("total", Json::num(c.acc.total as f64)),
                                                (
                                                    "mean_correct_lp",
                                                    Json::num(c.mean_correct_lp),
                                                ),
                                            ]),
                                        )
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// A variant awaiting scoring. `model: None` is the uncompressed input
/// model (borrowed from the caller — no clone for the Full row).
struct Variant {
    label: String,
    m: usize,
    params: usize,
    merge_seconds: f64,
    mean_layer_err: f64,
    model: Option<ModelWeights>,
}

/// One scoring lane: a forked engine plus its private scratch (never
/// shared across threads).
struct Lane {
    engine: Box<dyn Engine + Send>,
    scratch: EvalScratch,
}

/// Run the whole grid. `gram` backs the MergeMoE solves; `engine` scores —
/// if it forks ([`Engine::fork`]), cells run across the worker pool.
pub fn run_sweep(
    model: &ModelWeights,
    spec: &SweepSpec,
    gram: &mut dyn GramBackend,
    engine: &mut dyn Engine,
) -> Result<SweepReport> {
    if spec.methods.is_empty() || spec.targets.is_empty() || spec.tasks.is_empty() {
        bail!("sweep needs at least one method, one target and one task");
    }
    let t0 = std::time::Instant::now();

    // (1) tokenize/pad every task once; shared read-only by all cells
    let mut preps: Vec<PreparedItems> = Vec::with_capacity(spec.tasks.len());
    for &task in &spec.tasks {
        let items = gen_items(task, spec.items, spec.seed);
        let mut p = PreparedItems::new();
        p.prepare(&items, spec.seq_len)
            .with_context(|| format!("preparing task {}", task.name()))?;
        preps.push(p);
    }

    // (2) one capture serves every variant; one workspace serves every solve
    let calib = capture_calibration(
        model,
        spec.n_calib_seqs,
        spec.calib_tasks.as_deref(),
        spec.seed,
    )?;
    let full_params = model.n_params();
    let mut variants: Vec<Variant> = Vec::new();
    if spec.include_full {
        variants.push(Variant {
            label: "Full".into(),
            m: model.cfg.n_experts,
            params: full_params,
            merge_seconds: 0.0,
            mean_layer_err: 0.0,
            model: None,
        });
    }
    let mut ws = Workspace::new();
    for &m in &spec.targets {
        for &alg in &spec.methods {
            let mut cs = CompressSpec::new(spec.layers.clone(), m, alg);
            cs.n_calib_seqs = spec.n_calib_seqs;
            cs.calib_tasks = spec.calib_tasks.clone();
            cs.seed = spec.seed;
            let (merged, rep) = compress_with_calib(model, &cs, gram, &calib, &mut ws)
                .with_context(|| format!("compressing to {m} experts via {}", alg.name()))?;
            let mean_err = rep.layers.iter().map(|l| l.output_rel_err).sum::<f64>()
                / rep.layers.len().max(1) as f64;
            variants.push(Variant {
                label: alg.name().to_string(),
                m,
                params: rep.params_after,
                merge_seconds: rep.merge_seconds,
                mean_layer_err: mean_err,
                model: Some(merged),
            });
        }
    }

    // (3) score the (variant, task) grid; cell i = (variant i/n_tasks,
    // task i%n_tasks)
    type CellOut = Option<Result<(Accuracy, f64)>>;
    let n_tasks = spec.tasks.len();
    let mut cells: Vec<CellOut> = Vec::new();
    cells.resize_with(variants.len() * n_tasks, || None);
    let score_cell = |vi: usize,
                      ti: usize,
                      eng: &mut dyn Engine,
                      es: &mut EvalScratch|
     -> Result<(Accuracy, f64)> {
        let mdl = variants[vi].model.as_ref().unwrap_or(model);
        let acc = scorer::score_prepared_ws(eng, mdl, &preps[ti], spec.batch, es)?;
        let lp = scorer::mean_correct_lp(&preps[ti], &es.scores);
        Ok((acc, lp))
    };
    // Fan cells out only when the grid can occupy the whole thread budget:
    // inside a lane, nested kernel regions degrade to serial, so a grid
    // *smaller* than the budget scores faster cell-by-cell with parallel
    // kernels (results are bit-identical either way).
    let mut lanes: Vec<Lane> = Vec::new();
    let want = par::max_threads();
    if want > 1 && cells.len() >= want {
        if let Some(first) = engine.fork() {
            lanes.push(Lane { engine: first, scratch: EvalScratch::new() });
            while lanes.len() < want {
                match engine.fork() {
                    Some(e) => lanes.push(Lane { engine: e, scratch: EvalScratch::new() }),
                    None => break,
                }
            }
        }
    }
    if lanes.len() > 1 {
        par::par_items_with_slots(true, &mut cells, &mut lanes, |i, cell, lane| {
            let (vi, ti) = (i / n_tasks, i % n_tasks);
            *cell = Some(score_cell(vi, ti, lane.engine.as_mut(), &mut lane.scratch));
        });
    } else {
        // non-forking engine (PJRT) or single-thread budget: every cell on
        // the calling thread through one scratch
        let mut es = EvalScratch::new();
        for (i, cell) in cells.iter_mut().enumerate() {
            let (vi, ti) = (i / n_tasks, i % n_tasks);
            *cell = Some(score_cell(vi, ti, &mut *engine, &mut es));
        }
    }

    // (4) assemble, in (variant, task) order
    let mut results: Vec<Vec<TaskCell>> = Vec::with_capacity(variants.len());
    results.resize_with(variants.len(), Vec::new);
    for (idx, out) in cells.into_iter().enumerate() {
        let (vi, ti) = (idx / n_tasks, idx % n_tasks);
        let (acc, lp) = out
            .expect("cell not scored")
            .with_context(|| {
                format!("scoring {} (m={}) on {}", variants[vi].label, variants[vi].m,
                        spec.tasks[ti].name())
            })?;
        results[vi].push(TaskCell { task: spec.tasks[ti], acc, mean_correct_lp: lp });
    }
    let variants_out = variants
        .into_iter()
        .zip(results)
        .map(|(v, cells)| VariantResult {
            label: v.label,
            m: v.m,
            params: v.params,
            ratio: v.params as f64 / full_params as f64,
            merge_seconds: v.merge_seconds,
            mean_layer_err: v.mean_layer_err,
            cells,
        })
        .collect();
    Ok(SweepReport {
        model: model.cfg.name.clone(),
        items: spec.items,
        seq_len: spec.seq_len,
        seed: spec.seed,
        threads: par::max_threads(),
        kernel: crate::kernel::name().to_string(),
        n_calib_tokens: calib.n_tokens(),
        wall_seconds: t0.elapsed().as_secs_f64(),
        variants: variants_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::NativeGram;
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    fn small_spec() -> SweepSpec {
        let mut spec = SweepSpec::new(
            vec![Algorithm::Average, Algorithm::MSmoe],
            vec![2],
            vec![Task::Copy, Task::Parity],
            vec![0, 1],
        );
        spec.items = 10;
        spec.n_calib_seqs = 4;
        spec.batch = 8;
        spec
    }

    #[test]
    fn sweep_covers_the_whole_grid() {
        let model = tiny_model(4, 2, false, 95);
        let rep =
            run_sweep(&model, &small_spec(), &mut NativeGram, &mut NativeEngine).unwrap();
        // Full + 2 methods × 1 target
        assert_eq!(rep.variants.len(), 3);
        assert_eq!(rep.variants[0].label, "Full");
        assert_eq!(rep.variants[0].ratio, 1.0);
        for v in &rep.variants {
            assert_eq!(v.cells.len(), 2);
            assert_eq!(v.cells[0].task, Task::Copy);
            assert_eq!(v.cells[1].task, Task::Parity);
            for c in &v.cells {
                assert_eq!(c.acc.total, 10);
                assert!(c.mean_correct_lp.is_finite() && c.mean_correct_lp < 0.0);
            }
        }
        // compressed variants really shrank
        assert!(rep.variants[1].ratio < 1.0);
        assert!(rep.variant("Average", 2).is_some());
        assert!(rep.variant("M-SMoE", 2).is_some());
        assert!(rep.variant("MergeMoE", 2).is_none());
    }

    #[test]
    fn sweep_reruns_are_identical() {
        let model = tiny_model(4, 2, true, 96);
        let spec = small_spec();
        let a = run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
        let b = run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
        for (va, vb) in a.variants.iter().zip(&b.variants) {
            assert_eq!(va.label, vb.label);
            assert_eq!(va.params, vb.params);
            for (ca, cb) in va.cells.iter().zip(&vb.cells) {
                assert_eq!(ca.acc, cb.acc, "{}/{}", va.label, ca.task.name());
                assert_eq!(
                    ca.mean_correct_lp, cb.mean_correct_lp,
                    "{}/{}", va.label, ca.task.name()
                );
            }
        }
    }

    #[test]
    fn sweep_json_has_stable_shape() {
        let model = tiny_model(4, 2, false, 97);
        let rep =
            run_sweep(&model, &small_spec(), &mut NativeGram, &mut NativeEngine).unwrap();
        let parsed = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), "tiny");
        let variants = parsed.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), rep.variants.len());
        let copy = variants[0].get("tasks").unwrap().get("copy").unwrap();
        assert!(copy.get("acc").unwrap().as_f64().unwrap() >= 0.0);
        assert!(copy.get("mean_correct_lp").unwrap().as_f64().unwrap() < 0.0);
    }

    #[test]
    fn sweep_rejects_empty_grid() {
        let model = tiny_model(4, 2, false, 98);
        let mut spec = small_spec();
        spec.tasks.clear();
        assert!(
            run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).is_err()
        );
    }
}
