//! Evaluation sweeps: the paper's headline evidence — the accuracy-vs-ratio
//! comparison grid (Tables 1–3) *and* the calibration-source ablation
//! (Table 4) — in one invocation (`mergemoe sweep`). A sweep evaluates
//! every {calibration source} × {compression method} × {ratio} × {task}
//! cell of a [`SweepSpec`]; the method-ordering regression test in
//! `tests/eval_consistency.rs` keeps the headline ordering (MergeMoE ≥
//! the baselines at equal ratio) under test.
//!
//! Execution model — a two-stage pipeline over the variant stream:
//!
//! 1. **Prepare once.** Every task's items are tokenized and padded into a
//!    [`PreparedItems`] buffer up front; the buffers are shared read-only
//!    by every (variant, task) cell.
//! 2. **Produce: capture per source, compress per variant.** One
//!    calibration capture of the uncompressed model per calibration source
//!    ([`crate::coordinator::capture_calibration_source`]) serves every
//!    (method, ratio) variant of that source through
//!    [`crate::coordinator::compress_with_calib`], reusing one merge
//!    workspace throughout. The produce stage is pinned to a single lane
//!    (its nested `par_*` regions degrade to serial inside
//!    [`par::pipeline`]).
//! 3. **Consume: score each variant as it lands.** Variants travel through
//!    a bounded [`par::Handoff`] (capacity 1), so compression of variant
//!    `k+1` overlaps with scoring of variant `k` while peak memory stays
//!    bounded to a couple of in-flight models. The consume stage fans a
//!    variant's task cells across the remaining pool lanes via
//!    [`par::par_items_with_slots`] — one forked engine + one
//!    [`EvalScratch`] per lane, never shared across threads (the
//!    `model::workspace` ownership rule).
//!
//! `threads = 1` (or a non-forking engine, e.g. PJRT) runs the exact
//! serial execution: all variants compressed first, then scored cell by
//! cell through one scratch on the calling thread. Because compression and
//! scoring are each bit-identical at every thread count, the pipelined and
//! serial paths produce **bit-identical reports** — pinned across
//! `--threads` 1/2/8 by `tests/eval_consistency.rs`.
//!
//! The outcome is a [`SweepReport`]: `exp::tables::sweep_markdown` renders
//! per-source accuracy tables and `exp::report::save_sweep` persists
//! `SWEEP_<model>.json` + `SWEEP_<model>.md` for bench_diff-style
//! comparison across commits.

#![warn(missing_docs)]

use anyhow::{bail, Context, Result};

use super::scorer::{self, PreparedItems};
use super::tasks::{gen_items, Task};
use super::Accuracy;
use crate::calib::CalibSource;
use crate::coordinator::{capture_calibration_source, compress_with_calib, CompressSpec};
use crate::merge::{Algorithm, GramBackend};
use crate::model::workspace::{EvalScratch, Workspace};
use crate::model::ModelWeights;
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::par;

/// Source label of the uncompressed "Full" row, which does not depend on
/// any calibration data. Per-source report sections repeat the Full row
/// under this label so every section reads like a paper table.
pub const FULL_SOURCE: &str = "-";

/// The evaluation grid: every calibration source × method × target expert
/// count × task.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Compression methods to compare (each evaluated at every target).
    pub methods: Vec<Algorithm>,
    /// Target expert counts per merged layer — one compression ratio each.
    pub targets: Vec<usize>,
    /// Tasks every variant is evaluated on.
    pub tasks: Vec<Task>,
    /// Layer indices to merge.
    pub layers: Vec<usize>,
    /// Items per task.
    pub items: usize,
    /// Token length every scored sequence is padded to.
    pub seq_len: usize,
    /// Sequences per forward chunk (rounded up to even by the scorer).
    pub batch: usize,
    /// Calibration sequences per capture.
    pub n_calib_seqs: usize,
    /// Restrict calibration data to these tasks (None = uniform mixture).
    /// Only consulted when [`SweepSpec::calib_sources`] is empty — it is
    /// the pre-source-axis spelling of a single-source sweep.
    pub calib_tasks: Option<Vec<Task>>,
    /// Calibration sources — the fourth sweep axis (Table 4's rows). One
    /// activation capture per source; every (method, ratio) variant is
    /// compressed once per source. Empty (the default) means one source
    /// derived from `calib_tasks`, reproducing the three-axis behaviour.
    pub calib_sources: Vec<CalibSource>,
    /// Seed for item generation and calibration sampling.
    pub seed: u64,
    /// Evaluate the uncompressed model as the first row.
    pub include_full: bool,
}

impl SweepSpec {
    /// A spec over the four explicit grid axes with the default sizing
    /// knobs (100 items, seq 64, batch 32, 64 calibration sequences,
    /// mixture calibration, Full row included).
    pub fn new(
        methods: Vec<Algorithm>,
        targets: Vec<usize>,
        tasks: Vec<Task>,
        layers: Vec<usize>,
    ) -> SweepSpec {
        SweepSpec {
            methods,
            targets,
            tasks,
            layers,
            items: 100,
            seq_len: 64,
            batch: 32,
            n_calib_seqs: 64,
            calib_tasks: None,
            calib_sources: Vec::new(),
            seed: 2026,
            include_full: true,
        }
    }

    /// The calibration sources this sweep will run: `calib_sources` when
    /// set, otherwise exactly one source derived from `calib_tasks`.
    pub fn sources(&self) -> Vec<CalibSource> {
        if !self.calib_sources.is_empty() {
            return self.calib_sources.clone();
        }
        vec![match &self.calib_tasks {
            Some(ts) => CalibSource::from_tasks(ts),
            None => CalibSource::mixture(),
        }]
    }
}

/// One (variant, task) cell of the grid.
#[derive(Debug, Clone)]
pub struct TaskCell {
    /// The evaluated task.
    pub task: Task,
    /// Multiple-choice accuracy on the task's items.
    pub acc: Accuracy,
    /// Mean log-probability of the correct option — the fidelity metric on
    /// the calibration distribution that the method-ordering regression
    /// test bands (oracle ≥ mergemoe ≥ average).
    pub mean_correct_lp: f64,
}

/// One compressed (or full) model variant with its per-task results.
#[derive(Debug, Clone)]
pub struct VariantResult {
    /// Calibration source label this variant was compressed against
    /// ([`FULL_SOURCE`] for the uncompressed row).
    pub source: String,
    /// Row label: `"Full"` or the algorithm name.
    pub label: String,
    /// Target expert count (the original count for the full row).
    pub m: usize,
    /// Parameter count after compression.
    pub params: usize,
    /// `params / params(full)`.
    pub ratio: f64,
    /// Wall-clock seconds the merge took (0 for Full).
    pub merge_seconds: f64,
    /// Mean per-layer output relative error of the merge (0 for Full).
    pub mean_layer_err: f64,
    /// One cell per task, in `SweepSpec::tasks` order.
    pub cells: Vec<TaskCell>,
}

impl VariantResult {
    /// Mean accuracy across the variant's tasks (the paper's "Avg" column).
    pub fn mean_percent(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        self.cells.iter().map(|c| c.acc.percent()).sum::<f64>() / self.cells.len() as f64
    }

    /// Mean correct-option log-probability across the variant's tasks.
    pub fn mean_correct_lp(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        self.cells.iter().map(|c| c.mean_correct_lp).sum::<f64>() / self.cells.len() as f64
    }

    /// The cell for `task`, if the sweep evaluated it.
    pub fn cell(&self, task: Task) -> Option<&TaskCell> {
        self.cells.iter().find(|c| c.task == task)
    }
}

/// Full sweep outcome (serialized as `SWEEP_<model>.json`).
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Model name the sweep ran on.
    pub model: String,
    /// Items per task.
    pub items: usize,
    /// Sequence length every scored item was padded to.
    pub seq_len: usize,
    /// Seed for item generation and calibration sampling.
    pub seed: u64,
    /// Thread budget the sweep ran under (results do not depend on it).
    pub threads: usize,
    /// Compute kernel the sweep ran on (`scalar`/`avx2`/`neon`; scalar vs
    /// SIMD results agree to tolerance, not bit-for-bit — recorded so
    /// cross-machine report diffs can tell kernel drift from science
    /// drift).
    pub kernel: String,
    /// Calibration source labels, in sweep order (the fourth axis).
    pub calib_sources: Vec<String>,
    /// Total calibration tokens captured, summed over sources.
    pub n_calib_tokens: usize,
    /// Wall-clock seconds for the whole sweep.
    pub wall_seconds: f64,
    /// Full first (if requested), then source-major, target-major,
    /// method-minor in spec order.
    pub variants: Vec<VariantResult>,
}

impl SweepReport {
    /// The first variant row for `(label, m)` — e.g. `("MergeMoE", 6)`.
    /// Unambiguous on single-source sweeps; multi-source callers use
    /// [`SweepReport::variant_for`].
    pub fn variant(&self, label: &str, m: usize) -> Option<&VariantResult> {
        self.variants.iter().find(|v| v.label == label && v.m == m)
    }

    /// The variant row for `(source, label, m)` — e.g.
    /// `("copy", "MergeMoE", 6)` for Table-4-style lookups.
    pub fn variant_for(&self, source: &str, label: &str, m: usize) -> Option<&VariantResult> {
        self.variants
            .iter()
            .find(|v| v.source == source && v.label == label && v.m == m)
    }

    /// Machine-readable record (`SWEEP_<model>.json`), shaped for
    /// bench_diff-style comparison: stable keys, accuracy in percent.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("items", Json::num(self.items as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("kernel", Json::str(&self.kernel)),
            (
                "calib_sources",
                Json::arr(self.calib_sources.iter().map(|s| Json::str(s))),
            ),
            ("n_calib_tokens", Json::num(self.n_calib_tokens as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            (
                "variants",
                Json::arr(self.variants.iter().map(|v| {
                    Json::obj(vec![
                        ("label", Json::str(&v.label)),
                        ("calib_source", Json::str(&v.source)),
                        ("m", Json::num(v.m as f64)),
                        ("params", Json::num(v.params as f64)),
                        ("ratio", Json::num(v.ratio)),
                        ("merge_seconds", Json::num(v.merge_seconds)),
                        ("mean_layer_err", Json::num(v.mean_layer_err)),
                        ("mean_acc", Json::num(v.mean_percent())),
                        (
                            "tasks",
                            Json::Obj(
                                v.cells
                                    .iter()
                                    .map(|c| {
                                        (
                                            c.task.name().to_string(),
                                            Json::obj(vec![
                                                ("acc", Json::num(c.acc.percent())),
                                                ("correct", Json::num(c.acc.correct as f64)),
                                                ("total", Json::num(c.acc.total as f64)),
                                                (
                                                    "mean_correct_lp",
                                                    Json::num(c.mean_correct_lp),
                                                ),
                                            ]),
                                        )
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// A variant awaiting scoring. `model: None` is the uncompressed input
/// model (borrowed from the caller — no clone for the Full row).
struct Variant {
    source: String,
    label: String,
    m: usize,
    params: usize,
    merge_seconds: f64,
    mean_layer_err: f64,
    model: Option<ModelWeights>,
}

/// One scoring lane: a forked engine plus its private scratch (never
/// shared across threads).
struct Lane {
    engine: Box<dyn Engine + Send>,
    scratch: EvalScratch,
}

/// The produce stage: capture calibration once per source, compress once
/// per (source, target, method), and hand each variant to `emit` in grid
/// order (Full first when requested). `emit` returning `false` means the
/// consumer is gone — stop compressing. Returns the total calibration
/// tokens captured.
fn produce_variants(
    model: &ModelWeights,
    spec: &SweepSpec,
    sources: &[CalibSource],
    gram: &mut dyn GramBackend,
    emit: &mut dyn FnMut(Variant) -> bool,
) -> Result<usize> {
    let mut total_tokens = 0usize;
    if spec.include_full {
        let full = Variant {
            source: FULL_SOURCE.to_string(),
            label: "Full".into(),
            m: model.cfg.n_experts,
            params: model.n_params(),
            merge_seconds: 0.0,
            mean_layer_err: 0.0,
            model: None,
        };
        if !emit(full) {
            return Ok(total_tokens);
        }
    }
    // one merge workspace serves every solve across all sources
    let mut ws = Workspace::new();
    for src in sources {
        let calib = capture_calibration_source(model, spec.n_calib_seqs, src, spec.seed)
            .with_context(|| format!("capturing calibration source {}", src.label))?;
        total_tokens += calib.n_tokens();
        for &m in &spec.targets {
            for &alg in &spec.methods {
                let mut cs = CompressSpec::new(spec.layers.clone(), m, alg);
                cs.n_calib_seqs = spec.n_calib_seqs;
                cs.calib_tasks = src.tasks.clone();
                cs.seed = spec.seed;
                let (merged, rep) = compress_with_calib(model, &cs, gram, &calib, &mut ws)
                    .with_context(|| {
                        format!(
                            "compressing to {m} experts via {} (calib {})",
                            alg.name(),
                            src.label
                        )
                    })?;
                let mean_err = rep.layers.iter().map(|l| l.output_rel_err).sum::<f64>()
                    / rep.layers.len().max(1) as f64;
                let variant = Variant {
                    source: src.label.clone(),
                    label: alg.name().to_string(),
                    m,
                    params: rep.params_after,
                    merge_seconds: rep.merge_seconds,
                    mean_layer_err: mean_err,
                    model: Some(merged),
                };
                if !emit(variant) {
                    return Ok(total_tokens);
                }
            }
        }
    }
    Ok(total_tokens)
}

/// Score one (variant, task) cell: accuracy plus mean correct-option
/// log-probability. The per-cell instruction sequence is identical on the
/// serial and pipelined paths — that is what makes the two bit-identical.
fn score_cell(
    eng: &mut dyn Engine,
    mdl: &ModelWeights,
    prep: &PreparedItems,
    batch: usize,
    es: &mut EvalScratch,
) -> Result<(Accuracy, f64)> {
    let acc = scorer::score_prepared_ws(eng, mdl, prep, batch, es)?;
    let lp = scorer::mean_correct_lp(prep, &es.scores);
    Ok((acc, lp))
}

/// Unwrap per-cell outcomes into [`TaskCell`]s, attaching grid coordinates
/// to any scoring error.
fn collect_cells(
    v: &Variant,
    tasks: &[Task],
    cells: Vec<Option<Result<(Accuracy, f64)>>>,
) -> Result<Vec<TaskCell>> {
    let mut out = Vec::with_capacity(tasks.len());
    for (ti, cell) in cells.into_iter().enumerate() {
        let (acc, lp) = cell.expect("cell not scored").with_context(|| {
            format!(
                "scoring {} (m={}, calib {}) on {}",
                v.label,
                v.m,
                v.source,
                tasks[ti].name()
            )
        })?;
        out.push(TaskCell { task: tasks[ti], acc, mean_correct_lp: lp });
    }
    Ok(out)
}

/// Score every task cell of `v` across the scoring lanes (serial within a
/// lane; [`par::par_items_with_slots`] keeps lane/scratch pairing fixed so
/// results are deterministic).
fn score_variant(
    full: &ModelWeights,
    v: &Variant,
    preps: &[PreparedItems],
    tasks: &[Task],
    batch: usize,
    lanes: &mut [Lane],
) -> Result<Vec<TaskCell>> {
    let mdl = v.model.as_ref().unwrap_or(full);
    let mut cells: Vec<Option<Result<(Accuracy, f64)>>> = Vec::new();
    cells.resize_with(tasks.len(), || None);
    // Fan the cells out only when they can occupy the scoring lanes: inside
    // a lane, nested kernel regions degrade to serial, so fewer tasks than
    // lanes score faster cell-by-cell with parallel kernels (results are
    // bit-identical either way — the serial path of the primitive runs
    // unpinned, so the kernels below it still use the pool).
    let fan = lanes.len() > 1 && tasks.len() >= lanes.len();
    par::par_items_with_slots(fan, &mut cells, lanes, |ti, cell, lane| {
        *cell = Some(score_cell(
            lane.engine.as_mut(),
            mdl,
            &preps[ti],
            batch,
            &mut lane.scratch,
        ));
    });
    collect_cells(v, tasks, cells)
}

/// Run the whole grid. `gram` backs the MergeMoE solves; `engine` scores —
/// when it forks ([`Engine::fork`]) and more than one thread is budgeted,
/// the sweep runs as a two-stage pipeline (compression of variant `k+1`
/// overlapping scoring of variant `k`); otherwise it runs the exact serial
/// execution. Both paths produce bit-identical reports.
pub fn run_sweep(
    model: &ModelWeights,
    spec: &SweepSpec,
    gram: &mut dyn GramBackend,
    engine: &mut dyn Engine,
) -> Result<SweepReport> {
    if spec.methods.is_empty() || spec.targets.is_empty() || spec.tasks.is_empty() {
        bail!("sweep needs at least one method, one target and one task");
    }
    let t0 = std::time::Instant::now();
    let sources = spec.sources();

    // (1) tokenize/pad every task once; shared read-only by all cells
    let mut preps: Vec<PreparedItems> = Vec::with_capacity(spec.tasks.len());
    for &task in &spec.tasks {
        let items = gen_items(task, spec.items, spec.seed);
        let mut p = PreparedItems::new();
        p.prepare(&items, spec.seq_len)
            .with_context(|| format!("preparing task {}", task.name()))?;
        preps.push(p);
    }
    let full_params = model.n_params();

    // Scoring lanes: the produce stage occupies one lane, so fork at most
    // `threads - 1` scoring engines. No forks (PJRT) or threads = 1 means
    // the serial path below.
    let want = par::max_threads();
    let mut lanes: Vec<Lane> = Vec::new();
    if want > 1 {
        if let Some(first) = engine.fork() {
            lanes.push(Lane { engine: first, scratch: EvalScratch::new() });
            while lanes.len() + 1 < want {
                match engine.fork() {
                    Some(e) => lanes.push(Lane { engine: e, scratch: EvalScratch::new() }),
                    None => break,
                }
            }
        }
    }

    // (2)+(3) produce (capture + compress) and consume (score), pipelined
    // when lanes exist, serial otherwise; identical results either way.
    let (rows, total_tokens) = if lanes.is_empty() {
        // the exact serial execution: every variant compressed first, then
        // every cell scored through one scratch on this thread
        let mut variants: Vec<Variant> = Vec::new();
        let total = produce_variants(model, spec, &sources, gram, &mut |v| {
            variants.push(v);
            true
        })?;
        let mut es = EvalScratch::new();
        let mut rows: Vec<(Variant, Vec<TaskCell>)> = Vec::with_capacity(variants.len());
        for mut v in variants {
            let cells = {
                let mdl = v.model.as_ref().unwrap_or(model);
                let mut raw: Vec<Option<Result<(Accuracy, f64)>>> =
                    Vec::with_capacity(spec.tasks.len());
                for prep in &preps {
                    raw.push(Some(score_cell(&mut *engine, mdl, prep, spec.batch, &mut es)));
                }
                collect_cells(&v, &spec.tasks, raw)?
            };
            v.model = None;
            rows.push((v, cells));
        }
        (rows, total)
    } else {
        let preps_ref = &preps;
        let tasks_ref = &spec.tasks;
        let lanes_ref = &mut lanes;
        let (produced, consumed) = par::pipeline(
            1,
            |tx: &par::Handoff<Variant>| {
                produce_variants(model, spec, &sources, gram, &mut |v| tx.push(v))
            },
            move |rx: &par::Handoff<Variant>| -> Result<Vec<(Variant, Vec<TaskCell>)>> {
                let mut rows = Vec::new();
                while let Some(mut v) = rx.pop() {
                    let cells =
                        score_variant(model, &v, preps_ref, tasks_ref, spec.batch, lanes_ref)?;
                    v.model = None; // free the merged weights before the next pop
                    rows.push((v, cells));
                }
                Ok(rows)
            },
        );
        let total = produced?;
        (consumed?, total)
    };

    // (4) assemble, in production order
    let variants_out = rows
        .into_iter()
        .map(|(v, cells)| VariantResult {
            source: v.source,
            label: v.label,
            m: v.m,
            params: v.params,
            ratio: v.params as f64 / full_params as f64,
            merge_seconds: v.merge_seconds,
            mean_layer_err: v.mean_layer_err,
            cells,
        })
        .collect();
    Ok(SweepReport {
        model: model.cfg.name.clone(),
        items: spec.items,
        seq_len: spec.seq_len,
        seed: spec.seed,
        threads: par::max_threads(),
        kernel: crate::kernel::name().to_string(),
        calib_sources: sources.iter().map(|s| s.label.clone()).collect(),
        n_calib_tokens: total_tokens,
        wall_seconds: t0.elapsed().as_secs_f64(),
        variants: variants_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::NativeGram;
    use crate::model::testutil::tiny_model;
    use crate::runtime::NativeEngine;

    fn small_spec() -> SweepSpec {
        let mut spec = SweepSpec::new(
            vec![Algorithm::Average, Algorithm::MSmoe],
            vec![2],
            vec![Task::Copy, Task::Parity],
            vec![0, 1],
        );
        spec.items = 10;
        spec.n_calib_seqs = 4;
        spec.batch = 8;
        spec
    }

    #[test]
    fn sweep_covers_the_whole_grid() {
        let model = tiny_model(4, 2, false, 95);
        let rep =
            run_sweep(&model, &small_spec(), &mut NativeGram, &mut NativeEngine).unwrap();
        // Full + 2 methods × 1 target (single derived source)
        assert_eq!(rep.calib_sources, vec!["mixture"]);
        assert_eq!(rep.variants.len(), 3);
        assert_eq!(rep.variants[0].label, "Full");
        assert_eq!(rep.variants[0].source, FULL_SOURCE);
        assert_eq!(rep.variants[0].ratio, 1.0);
        for v in &rep.variants {
            assert_eq!(v.cells.len(), 2);
            assert_eq!(v.cells[0].task, Task::Copy);
            assert_eq!(v.cells[1].task, Task::Parity);
            for c in &v.cells {
                assert_eq!(c.acc.total, 10);
                assert!(c.mean_correct_lp.is_finite() && c.mean_correct_lp < 0.0);
            }
        }
        // compressed variants really shrank and carry the derived source
        assert!(rep.variants[1].ratio < 1.0);
        assert_eq!(rep.variants[1].source, "mixture");
        assert!(rep.variant("Average", 2).is_some());
        assert!(rep.variant("M-SMoE", 2).is_some());
        assert!(rep.variant("MergeMoE", 2).is_none());
        assert!(rep.variant_for("mixture", "Average", 2).is_some());
        assert!(rep.variant_for("copy", "Average", 2).is_none());
    }

    #[test]
    fn sweep_source_axis_expands_the_grid() {
        let model = tiny_model(4, 2, false, 99);
        let mut spec = small_spec();
        spec.calib_sources =
            vec![CalibSource::mixture(), CalibSource::single(Task::Copy)];
        let rep = run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
        assert_eq!(rep.calib_sources, vec!["mixture", "copy"]);
        // Full + 2 sources × 2 methods × 1 target
        assert_eq!(rep.variants.len(), 5);
        // one capture per source
        assert_eq!(rep.n_calib_tokens, 2 * spec.n_calib_seqs * 64);
        for src in ["mixture", "copy"] {
            for label in ["Average", "M-SMoE"] {
                let v = rep.variant_for(src, label, 2);
                assert!(v.is_some(), "{src}/{label} missing");
                assert_eq!(v.unwrap().cells.len(), 2, "{src}/{label}");
            }
        }
        // variant order: Full, then source-major in spec order
        assert_eq!(rep.variants[1].source, "mixture");
        assert_eq!(rep.variants[3].source, "copy");
    }

    #[test]
    fn sweep_reruns_are_identical() {
        let model = tiny_model(4, 2, true, 96);
        let spec = small_spec();
        let a = run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
        let b = run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).unwrap();
        for (va, vb) in a.variants.iter().zip(&b.variants) {
            assert_eq!(va.label, vb.label);
            assert_eq!(va.source, vb.source);
            assert_eq!(va.params, vb.params);
            for (ca, cb) in va.cells.iter().zip(&vb.cells) {
                assert_eq!(ca.acc, cb.acc, "{}/{}", va.label, ca.task.name());
                assert_eq!(
                    ca.mean_correct_lp, cb.mean_correct_lp,
                    "{}/{}", va.label, ca.task.name()
                );
            }
        }
    }

    #[test]
    fn sweep_json_has_stable_shape() {
        let model = tiny_model(4, 2, false, 97);
        let rep =
            run_sweep(&model, &small_spec(), &mut NativeGram, &mut NativeEngine).unwrap();
        let parsed = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), "tiny");
        let sources = parsed.get("calib_sources").unwrap().as_arr().unwrap();
        assert_eq!(sources.len(), 1);
        assert_eq!(sources[0].as_str().unwrap(), "mixture");
        let variants = parsed.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), rep.variants.len());
        assert_eq!(
            variants[0].get("calib_source").unwrap().as_str().unwrap(),
            FULL_SOURCE
        );
        let copy = variants[0].get("tasks").unwrap().get("copy").unwrap();
        assert!(copy.get("acc").unwrap().as_f64().unwrap() >= 0.0);
        assert!(copy.get("mean_correct_lp").unwrap().as_f64().unwrap() < 0.0);
    }

    #[test]
    fn sweep_rejects_empty_grid() {
        let model = tiny_model(4, 2, false, 98);
        let mut spec = small_spec();
        spec.tasks.clear();
        assert!(
            run_sweep(&model, &spec, &mut NativeGram, &mut NativeEngine).is_err()
        );
    }
}
