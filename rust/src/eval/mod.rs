//! Evaluation harness: the seven synthetic multiple-choice benchmark tasks
//! (substitutes for WinoGrande / ARC / Hellaswag / PIQA / SQuAD / MRPC, see
//! DESIGN.md §2), the workspace-backed likelihood scorer that grades them,
//! the [`sweep`] subsystem that evaluates a whole
//! {calibration source × method × ratio × task} comparison grid in one
//! pipelined invocation (`mergemoe sweep`), and the seeded [`sample`]
//! generation loop behind `mergemoe generate`.

pub mod sample;
pub mod scorer;
pub mod sweep;
pub mod tasks;

pub use sample::{argmax, generate, generate_into, GenerateStats, Sampler};
pub use scorer::{score_items, score_items_scored, Accuracy, PreparedItems};
pub use sweep::{run_sweep, SweepReport, SweepSpec};
pub use tasks::{gen_items, Task, TaskItem, ALL_TASKS};
