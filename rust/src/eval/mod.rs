//! Evaluation harness: the seven synthetic multiple-choice benchmark tasks
//! (substitutes for WinoGrande / ARC / Hellaswag / PIQA / SQuAD / MRPC, see
//! DESIGN.md §2), the workspace-backed likelihood scorer that grades them,
//! and the [`sweep`] subsystem that evaluates a whole
//! {calibration source × method × ratio × task} comparison grid in one
//! pipelined invocation (`mergemoe sweep`).

pub mod scorer;
pub mod sweep;
pub mod tasks;

pub use scorer::{score_items, score_items_scored, Accuracy, PreparedItems};
pub use sweep::{run_sweep, SweepReport, SweepSpec};
pub use tasks::{gen_items, Task, TaskItem, ALL_TASKS};
