//! Evaluation harness: the seven synthetic multiple-choice benchmark tasks
//! (substitutes for WinoGrande / ARC / Hellaswag / PIQA / SQuAD / MRPC, see
//! DESIGN.md §2) and the likelihood-based scorer that grades them.

pub mod scorer;
pub mod tasks;

pub use scorer::{score_items, Accuracy};
pub use tasks::{gen_items, Task, TaskItem, ALL_TASKS};
