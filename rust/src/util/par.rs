//! Dependency-free data parallelism over `std::thread::scope`.
//!
//! This is the compute substrate every hot path shares: the tiled matmul
//! kernels parallelize over output rows, the native engine over sequences
//! and experts, the merge pipeline over clusters and calibration chunks, and
//! the triangular solves over right-hand-side columns.
//!
//! Design rules:
//!
//! * **One global thread-count knob.** [`max_threads`] resolves, in order:
//!   an explicit [`set_max_threads`] call (the `--threads` CLI flag), the
//!   `MERGEMOE_THREADS` environment variable, then the machine's available
//!   parallelism. `threads = 1` turns every primitive into a plain serial
//!   loop with no thread spawns.
//! * **No nested pools.** Worker closures run with a thread-local flag set;
//!   any `par_*` call made from inside a worker degrades to the serial path.
//!   Outer-level parallelism (per expert, per cluster) therefore composes
//!   with kernel-level parallelism without oversubscription.
//! * **Determinism.** Work is split into contiguous index blocks and every
//!   item is processed with the same per-item instruction sequence as the
//!   serial path, so results are bit-identical for every thread count.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = unresolved; resolved lazily on first use.
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static IN_POOL: Cell<bool> = Cell::new(false);
}

fn env_or_available() -> usize {
    match std::env::var("MERGEMOE_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// The worker-thread budget for parallel regions.
pub fn max_threads() -> usize {
    let n = MAX_THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let resolved = env_or_available();
    // Benign race: every racer computes the same value.
    MAX_THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Override the thread budget (the `--threads` CLI flag). Clamped to >= 1.
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// True while running inside a `par_*` worker (nested calls go serial).
pub fn in_parallel_region() -> bool {
    IN_POOL.with(|f| f.get())
}

/// Run `f` with the in-pool flag set, restoring it afterwards.
fn with_pool_flag<R>(f: impl FnOnce() -> R) -> R {
    IN_POOL.with(|flag| {
        let prev = flag.replace(true);
        let out = f();
        flag.set(prev);
        out
    })
}

/// Split `n` items into at most `parts` contiguous `(lo, hi)` blocks.
fn blocks(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        if len == 0 {
            break;
        }
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Below this many output elements, elementwise row ops (layernorm,
/// softmax, embed, transpose) run serially: a few flops per element cannot
/// amortize thread spawn/join.
pub const PAR_MIN_ELEMS: usize = 16 * 1024;

/// Below roughly this many flops, compute kernels (matmul family,
/// triangular solves, attention) run serially. Callers with a better cost
/// model pass `work >= PAR_MIN_FLOPS` through the `*_if` variants.
pub const PAR_MIN_FLOPS: usize = 256 * 1024;

/// Apply `f(chunk_index, chunk)` to every `chunk_len`-sized chunk of `data`
/// (the last chunk may be shorter), fanning contiguous chunk blocks out to
/// worker threads. This is the mutable-output primitive: matmul rows, tensor
/// rows, per-sequence attention slabs. Inputs smaller than
/// [`PAR_MIN_ELEMS`] run serially — use [`par_chunks_mut_if`] with a work
/// estimate when the per-element cost is far from O(1).
///
/// `chunk_len` must be non-zero unless `data` is empty.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let parallel = data.len() >= PAR_MIN_ELEMS;
    par_chunks_mut_if(parallel, data, chunk_len, f);
}

/// [`par_chunks_mut`] with an explicit fan-out decision: callers estimate
/// the total work (e.g. `2*m*k*n` flops for a matmul) and pass
/// `work >= PAR_MIN_FLOPS`, so tiny kernels skip thread spawn/join
/// entirely.
pub fn par_chunks_mut_if<T, F>(parallel: bool, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "par_chunks_mut: chunk_len must be > 0");
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let threads = max_threads().min(n_chunks);
    if !parallel || threads <= 1 || in_parallel_region() {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    let chunk_blocks = blocks(n_chunks, threads);
    // Slice `data` into per-thread sub-slices along chunk boundaries.
    let mut parts: Vec<(usize, &mut [T])> = Vec::with_capacity(chunk_blocks.len());
    let mut rest = data;
    for &(lo, hi) in &chunk_blocks {
        let elems = ((hi - lo) * chunk_len).min(rest.len());
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(elems);
        rest = tail;
        parts.push((lo, head));
    }
    let f = &f;
    std::thread::scope(|s| {
        let mut iter = parts.into_iter();
        // Keep the first block on the calling thread; spawn the rest.
        let first = iter.next();
        for (chunk0, slab) in iter {
            s.spawn(move || {
                with_pool_flag(|| {
                    for (ci, chunk) in slab.chunks_mut(chunk_len).enumerate() {
                        f(chunk0 + ci, chunk);
                    }
                })
            });
        }
        if let Some((chunk0, slab)) = first {
            with_pool_flag(|| {
                for (ci, chunk) in slab.chunks_mut(chunk_len).enumerate() {
                    f(chunk0 + ci, chunk);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, returning results in index order. The
/// read-only fan-out primitive: per-expert batches, per-cluster merges,
/// calibration chunk computation. Items are assumed coarse (whole expert
/// batches, 1024-row calibration chunks); use [`par_map_range_if`] when the
/// caller can tell the work is too small to fan out.
pub fn par_map_range<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    par_map_range_if(true, n, f)
}

/// [`par_map_range`] with an explicit fan-out decision (same contract as
/// [`par_chunks_mut_if`]).
pub fn par_map_range_if<R, F>(parallel: bool, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = max_threads().min(n);
    if !parallel || threads <= 1 || in_parallel_region() {
        return (0..n).map(f).collect();
    }
    let idx_blocks = blocks(n, threads);
    let f = &f;
    let mut block_results: Vec<Vec<R>> = Vec::with_capacity(idx_blocks.len());
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(idx_blocks.len());
        let mut iter = idx_blocks.into_iter();
        let first = iter.next();
        for (lo, hi) in iter {
            handles.push(s.spawn(move || with_pool_flag(|| (lo..hi).map(f).collect::<Vec<R>>())));
        }
        if let Some((lo, hi)) = first {
            block_results.push(with_pool_flag(|| (lo..hi).map(f).collect::<Vec<R>>()));
        }
        for h in handles {
            block_results.push(h.join().expect("parallel worker panicked"));
        }
    });
    block_results.into_iter().flatten().collect()
}

/// Map `f(index, &item)` over a slice in parallel, preserving order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_range(items.len(), |i| f(i, &items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_cover_range_exactly() {
        for n in [0usize, 1, 2, 7, 16, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let bs = blocks(n, parts);
                let mut next = 0;
                for &(lo, hi) in &bs {
                    assert_eq!(lo, next);
                    assert!(hi > lo);
                    next = hi;
                }
                assert_eq!(next, n);
                assert_eq!(bs.iter().map(|&(l, h)| h - l).sum::<usize>(), n);
                assert!(bs.len() <= parts.max(1));
            }
        }
    }

    #[test]
    fn par_chunks_mut_visits_every_chunk_once() {
        // force the parallel path even though the input is tiny
        for force in [true, false] {
            let mut data = vec![0u32; 103];
            par_chunks_mut_if(force, &mut data, 10, |ci, chunk| {
                for v in chunk.iter_mut() {
                    *v += 1 + ci as u32;
                }
            });
            // chunk i covers [10i, 10i+10): value = 1 + i
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, 1 + (i / 10) as u32, "force={force} index {i}");
            }
        }
        // empty input is a no-op even with chunk_len 0
        let mut empty: Vec<u32> = Vec::new();
        par_chunks_mut(&mut empty, 0, |_, _| panic!("must not be called"));
    }

    #[test]
    fn par_map_range_ordered_and_complete() {
        let out = par_map_range(1000, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        assert!(par_map_range(0, |i| i).is_empty());
    }

    #[test]
    fn par_map_matches_serial_map() {
        let items: Vec<i64> = (0..257).collect();
        let par: Vec<i64> = par_map(&items, |i, &x| x * 3 + i as i64);
        let ser: Vec<i64> = items.iter().enumerate().map(|(i, &x)| x * 3 + i as i64).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn nested_calls_degrade_to_serial() {
        // A nested par_map_range inside a worker must not deadlock or spawn;
        // results stay correct either way.
        let out = par_map_range(8, |i| par_map_range(8, move |j| i * 8 + j));
        for (i, inner) in out.iter().enumerate() {
            for (j, v) in inner.iter().enumerate() {
                assert_eq!(*v, i * 8 + j);
            }
        }
    }
}
