//! Dependency-free data parallelism on a **persistent worker pool**.
//!
//! This is the compute substrate every hot path shares: the tiled matmul
//! kernels parallelize over output rows, the native engine over sequences
//! and experts, the merge pipeline over clusters and calibration chunks, and
//! the triangular solves over right-hand-side columns.
//!
//! ## Pool lifecycle
//!
//! PR 1 spawned and joined OS threads inside every parallel region; that
//! fixed tax (tens of microseconds per region) dominated small-shape kernels
//! and single-token serving latency. Regions now run on a process-wide pool:
//!
//! * **Lazy init.** No threads exist until the first parallel region; the
//!   first region that wants `n` lanes spawns `n - 1` workers (named
//!   `mergemoe-par-*`). Later regions reuse them; the pool only ever grows,
//!   up to the largest thread count requested.
//! * **Parking.** Idle workers block on a condvar — zero CPU between
//!   regions. Submitting a region publishes a job (a lifetime-erased
//!   closure plus an atomic block cursor) and wakes the workers; the
//!   *calling thread participates too*, so `threads = n` means at most `n`
//!   lanes touch a region even when the pool holds more workers.
//! * **Work distribution.** A region is split into at most
//!   [`MAX_BLOCKS`] contiguous index blocks; lanes claim blocks from an
//!   atomic cursor. Block *boundaries* depend only on the thread knob, never
//!   on claim order, so scheduling jitter cannot change results.
//! * **Shutdown.** Workers live for the process by default (they are
//!   parked, not spinning). [`shutdown_pool`] parks the pool permanently —
//!   joins every worker — for orderly teardown or tests; the next parallel
//!   region lazily respawns.
//!
//! Design rules (unchanged from PR 1):
//!
//! * **One global thread-count knob.** [`max_threads`] resolves, in order:
//!   an explicit [`set_max_threads`] call (the `--threads` CLI flag), the
//!   `MERGEMOE_THREADS` environment variable, then the machine's available
//!   parallelism. `threads = 1` turns every primitive into a plain serial
//!   loop that never touches the pool.
//! * **No nested pools.** Lane closures run with a thread-local flag set;
//!   any `par_*` call made from inside a lane degrades to the serial path.
//!   Outer-level parallelism (per expert, per cluster) therefore composes
//!   with kernel-level parallelism without oversubscription.
//! * **Determinism.** Work is split into contiguous index blocks and every
//!   item is processed with the same per-item instruction sequence as the
//!   serial path, so results are bit-identical for every thread count.
//! * **Zero steady-state allocation.** After the workers exist and the job
//!   queue has warmed its capacity, submitting a region allocates nothing:
//!   the job lives on the caller's stack and block tables live in a
//!   fixed-size array.

#![warn(missing_docs)]

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// 0 = unresolved; resolved lazily on first use.
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static IN_POOL: Cell<bool> = Cell::new(false);
}

fn env_or_available() -> usize {
    match std::env::var("MERGEMOE_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// The worker-thread budget for parallel regions.
pub fn max_threads() -> usize {
    let n = MAX_THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let resolved = env_or_available();
    // Benign race: every racer computes the same value.
    MAX_THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Override the thread budget (the `--threads` CLI flag). Clamped to >= 1.
/// Raising it takes effect on the next parallel region (the pool grows
/// lazily); lowering it simply leaves the extra workers parked.
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// True while running inside a `par_*` lane (nested calls go serial).
pub fn in_parallel_region() -> bool {
    IN_POOL.with(|f| f.get())
}

/// Run `f` with the in-pool flag set, restoring it afterwards.
fn with_pool_flag<R>(f: impl FnOnce() -> R) -> R {
    IN_POOL.with(|flag| {
        let prev = flag.replace(true);
        let out = f();
        flag.set(prev);
        out
    })
}

// ---------------------------------------------------------------------------
// The pool.
// ---------------------------------------------------------------------------

/// Hard cap on blocks per region (and therefore on lanes per region). Keeps
/// the block table on the caller's stack (4 KiB) while comfortably covering
/// every machine this serves; machines with even more cores still use every
/// worker across *concurrent* regions.
pub const MAX_BLOCKS: usize = 256;

/// One parallel region, living on the submitting thread's stack. Workers
/// reach it through a raw address published in the pool queue; the submitter
/// does not return until every block has finished **and** no worker still
/// holds the address, so the borrow the `run` pointer erases can never
/// dangle.
struct Job {
    /// Lifetime-erased `&dyn Fn(block_index)`; only dereferenced by lanes
    /// that claimed a block below `n_blocks`.
    run: *const (dyn Fn(usize) + Sync),
    n_blocks: usize,
    /// Next unclaimed block (may overshoot `n_blocks`; claimers that read
    /// past the end just leave).
    next: AtomicUsize,
    /// Blocks not yet finished; 0 ⇒ all work done.
    remaining: AtomicUsize,
    /// Workers currently executing (or about to execute) this job. Pins the
    /// stack slot: the submitter waits for 0 before returning.
    visitors: AtomicUsize,
    panicked: AtomicBool,
    /// First lane panic's payload, re-raised on the submitting thread so
    /// the original message/location survives (scoped threads did the same).
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct PoolState {
    /// Addresses of live jobs with (potentially) unclaimed blocks.
    queue: Vec<usize>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
    shutdown: bool,
}

struct Pool {
    state: Mutex<PoolState>,
    /// Signals workers: new job queued, or shutdown requested.
    work_cv: Condvar,
    /// Signals submitters: a worker left a job (visitor count dropped).
    done_cv: Condvar,
}

static POOL: Pool = Pool {
    state: Mutex::new(PoolState {
        queue: Vec::new(),
        handles: Vec::new(),
        workers: 0,
        shutdown: false,
    }),
    work_cv: Condvar::new(),
    done_cv: Condvar::new(),
};

/// Number of live pool workers (0 until the first parallel region).
pub fn pool_size() -> usize {
    POOL.state.lock().unwrap().workers
}

/// Serializes [`shutdown_pool`] callers: a second caller must not reset the
/// shutdown flag while the first is still joining (a worker could observe
/// the reset, re-park, and leave the first join hanging forever).
static SHUTDOWN_LOCK: Mutex<()> = Mutex::new(());

/// Join every pool worker. Call only when no parallel region is active
/// (orderly teardown, tests); the next region lazily respawns the pool.
/// Concurrent callers are serialized — the second becomes a no-op.
pub fn shutdown_pool() {
    let _serialize = SHUTDOWN_LOCK.lock().unwrap();
    let handles = {
        let mut st = POOL.state.lock().unwrap();
        st.shutdown = true;
        std::mem::take(&mut st.handles)
    };
    POOL.work_cv.notify_all();
    for h in handles {
        let _ = h.join();
    }
    let mut st = POOL.state.lock().unwrap();
    st.shutdown = false;
    st.workers = 0;
}

/// Claim and run blocks of `job` until the cursor is exhausted. Runs with
/// the in-pool flag set so nested `par_*` calls degrade to serial. Panics in
/// the closure are caught and recorded; the submitter re-raises.
fn execute_blocks(job: &Job) {
    with_pool_flag(|| loop {
        let b = job.next.fetch_add(1, Ordering::Relaxed);
        if b >= job.n_blocks {
            break;
        }
        // SAFETY: `b < n_blocks` means the submitter is still inside
        // `run_region`, so the closure behind `run` is alive.
        let run = unsafe { &*job.run };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| run(b))) {
            job.panicked.store(true, Ordering::Relaxed);
            let mut slot = job.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        // AcqRel: RMWs on `remaining` form a release sequence, so whoever
        // observes 0 also observes every lane's writes to the output data.
        job.remaining.fetch_sub(1, Ordering::AcqRel);
    });
}

fn worker_loop() {
    let mut st = POOL.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        // Drop fully-claimed jobs; queued addresses are valid because a
        // submitter only frees its job after removing it here (which needs
        // this lock) and seeing its visitor count reach zero.
        st.queue.retain(|&p| {
            let j = unsafe { &*(p as *const Job) };
            j.next.load(Ordering::Relaxed) < j.n_blocks
        });
        if let Some(&p) = st.queue.first() {
            let job = unsafe { &*(p as *const Job) };
            job.visitors.fetch_add(1, Ordering::Relaxed);
            drop(st);
            execute_blocks(job);
            st = POOL.state.lock().unwrap();
            job.visitors.fetch_sub(1, Ordering::Release);
            POOL.done_cv.notify_all();
        } else {
            st = POOL.work_cv.wait(st).unwrap();
        }
    }
}

/// Run `run(0..n_blocks)` across the pool plus the calling thread. `threads`
/// is the lane budget the caller derived from [`max_threads`] — the pool
/// grows to `threads - 1` workers if smaller. Callers guarantee
/// `n_blocks >= 1` and must not call this from inside a parallel region.
fn run_region(n_blocks: usize, threads: usize, run: &(dyn Fn(usize) + Sync)) {
    // SAFETY (lifetime erasure): the raw pointer outlives no one — this
    // function does not return until `remaining == 0` (all dereference
    // sites are done) and `visitors == 0` (no worker still holds `&job`).
    let run_static = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(run)
    };
    let job = Job {
        run: run_static,
        n_blocks,
        next: AtomicUsize::new(0),
        remaining: AtomicUsize::new(n_blocks),
        visitors: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        panic_payload: Mutex::new(None),
    };
    let addr = &job as *const Job as usize;
    let workers;
    {
        let mut st = POOL.state.lock().unwrap();
        let want = threads.saturating_sub(1);
        while st.workers < want {
            // A transient spawn failure (EAGAIN under pids/memory limits)
            // must not panic while holding the pool mutex — that would
            // poison it for the whole process. Run with the lanes we have
            // (zero workers still completes: the submitter claims every
            // block itself) and let a later region retry the growth.
            match std::thread::Builder::new()
                .name(format!("mergemoe-par-{}", st.workers))
                .spawn(worker_loop)
            {
                Ok(h) => {
                    st.handles.push(h);
                    st.workers += 1;
                }
                Err(e) => {
                    crate::warnlog!("pool worker spawn failed ({e}); running degraded");
                    break;
                }
            }
        }
        st.queue.push(addr);
        workers = st.workers;
    }
    // Wake only as many workers as the region has claimable blocks (the
    // submitter takes one lane itself): a 2-block region on a big pool must
    // not thundering-herd every parked worker through the mutex. Workers
    // that are busy re-scan the queue between jobs, so an unconsumed
    // notify_one is never a lost job.
    let wake = n_blocks.saturating_sub(1);
    if wake >= workers {
        POOL.work_cv.notify_all();
    } else {
        for _ in 0..wake {
            POOL.work_cv.notify_one();
        }
    }
    execute_blocks(&job);
    {
        let mut st = POOL.state.lock().unwrap();
        st.queue.retain(|&p| p != addr);
        while job.remaining.load(Ordering::Acquire) != 0
            || job.visitors.load(Ordering::Acquire) != 0
        {
            st = POOL.done_cv.wait(st).unwrap();
        }
    }
    if job.panicked.load(Ordering::Relaxed) {
        // Re-raise the first lane's payload so the original panic message
        // and location reach the submitting thread (matching what
        // std::thread::scope's join propagation used to surface).
        match job.panic_payload.lock().unwrap().take() {
            Some(payload) => std::panic::resume_unwind(payload),
            None => panic!("parallel worker panicked"),
        }
    }
}

/// Split `n` items into at most `parts` contiguous `(lo, hi)` blocks,
/// writing them into `buf`. Returns the number of blocks (≤ [`MAX_BLOCKS`]).
fn blocks_into(n: usize, parts: usize, buf: &mut [(usize, usize); MAX_BLOCKS]) -> usize {
    let parts = parts.clamp(1, MAX_BLOCKS).min(n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut count = 0;
    let mut lo = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        if len == 0 {
            break;
        }
        buf[count] = (lo, lo + len);
        count += 1;
        lo += len;
    }
    count
}

// ---------------------------------------------------------------------------
// Public primitives.
// ---------------------------------------------------------------------------

/// Below this many output elements, elementwise row ops (layernorm,
/// softmax, embed, transpose) run serially: a few flops per element cannot
/// amortize even a pool dispatch.
pub const PAR_MIN_ELEMS: usize = 16 * 1024;

/// Below roughly this many flops, compute kernels (matmul family,
/// triangular solves, attention) run serially. Callers with a better cost
/// model pass `work >= PAR_MIN_FLOPS` through the `*_if` variants.
pub const PAR_MIN_FLOPS: usize = 256 * 1024;

/// Apply `f(chunk_index, chunk)` to every `chunk_len`-sized chunk of `data`
/// (the last chunk may be shorter), fanning contiguous chunk blocks out to
/// pool lanes. This is the mutable-output primitive: matmul rows, tensor
/// rows, per-sequence attention slabs. Inputs smaller than
/// [`PAR_MIN_ELEMS`] run serially — use [`par_chunks_mut_if`] with a work
/// estimate when the per-element cost is far from O(1).
///
/// `chunk_len` must be non-zero unless `data` is empty.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let parallel = data.len() >= PAR_MIN_ELEMS;
    par_chunks_mut_if(parallel, data, chunk_len, f);
}

/// [`par_chunks_mut`] with an explicit fan-out decision: callers estimate
/// the total work (e.g. `2*m*k*n` flops for a matmul) and pass
/// `work >= PAR_MIN_FLOPS`, so tiny kernels never touch the pool.
pub fn par_chunks_mut_if<T, F>(parallel: bool, data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "par_chunks_mut: chunk_len must be > 0");
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let threads = max_threads().min(n_chunks);
    if !parallel || threads <= 1 || in_parallel_region() {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    let mut bbuf = [(0usize, 0usize); MAX_BLOCKS];
    let nb = blocks_into(n_chunks, threads, &mut bbuf);
    let chunk_blocks = &bbuf[..nb];
    let base = data.as_mut_ptr() as usize;
    let total = data.len();
    let f_ref = &f;
    run_region(nb, threads, &|bi| {
        let (lo, hi) = chunk_blocks[bi];
        let start = lo * chunk_len;
        let end = (hi * chunk_len).min(total);
        // SAFETY: blocks are disjoint chunk ranges of `data`, which outlives
        // the region; `T: Send` licenses touching it from a pool lane.
        let slab =
            unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(start), end - start) };
        for (ci, chunk) in slab.chunks_mut(chunk_len).enumerate() {
            f_ref(lo + ci, chunk);
        }
    });
}

/// Two-slice lockstep variant: chunk `ci` of `a` (length `a_chunk`) and
/// chunk `ci` of `b` (length `b_chunk`) are handed to `f` together. The
/// serving hot path uses this to pair each output slab with its private
/// scratch slab (attention: one context row-block + one scores row per
/// sequence) without allocating inside the region. Both slices must cover
/// the same number of chunks.
pub fn par_chunks2_mut_if<T, U, F>(
    parallel: bool,
    a: &mut [T],
    a_chunk: usize,
    b: &mut [U],
    b_chunk: usize,
    f: F,
) where
    T: Send,
    U: Send,
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    if a.is_empty() {
        assert!(b.is_empty(), "par_chunks2_mut: chunk counts differ (a empty, b not)");
        return;
    }
    assert!(
        a_chunk > 0 && b_chunk > 0,
        "par_chunks2_mut: chunk lengths must be > 0"
    );
    let n_chunks = (a.len() + a_chunk - 1) / a_chunk;
    let nb_b = (b.len() + b_chunk - 1) / b_chunk;
    assert_eq!(n_chunks, nb_b, "par_chunks2_mut: chunk counts differ");
    let threads = max_threads().min(n_chunks);
    if !parallel || threads <= 1 || in_parallel_region() {
        for (ci, (ca, cb)) in a.chunks_mut(a_chunk).zip(b.chunks_mut(b_chunk)).enumerate() {
            f(ci, ca, cb);
        }
        return;
    }
    let mut bbuf = [(0usize, 0usize); MAX_BLOCKS];
    let nb = blocks_into(n_chunks, threads, &mut bbuf);
    let chunk_blocks = &bbuf[..nb];
    let a_base = a.as_mut_ptr() as usize;
    let a_total = a.len();
    let b_base = b.as_mut_ptr() as usize;
    let b_total = b.len();
    let f_ref = &f;
    run_region(nb, threads, &|bi| {
        let (lo, hi) = chunk_blocks[bi];
        let (s1, e1) = (lo * a_chunk, (hi * a_chunk).min(a_total));
        let (s2, e2) = (lo * b_chunk, (hi * b_chunk).min(b_total));
        // SAFETY: disjoint chunk ranges per block, same argument as
        // `par_chunks_mut_if`, applied to each slice independently.
        let sa = unsafe { std::slice::from_raw_parts_mut((a_base as *mut T).add(s1), e1 - s1) };
        let sb = unsafe { std::slice::from_raw_parts_mut((b_base as *mut U).add(s2), e2 - s2) };
        for (ci, (ca, cb)) in sa.chunks_mut(a_chunk).zip(sb.chunks_mut(b_chunk)).enumerate() {
            f_ref(lo + ci, ca, cb);
        }
    });
}

/// Run `f(i)` for every `i in 0..n` in parallel, returning nothing — the
/// side-effect fan-out primitive. Unlike [`par_map_range_if`] it allocates
/// **nothing** (no per-block result vectors), so it is safe inside the
/// zero-alloc hot paths: the scatter-accumulate GEMM epilogue fans input
/// rows over disjoint output rows through it, and the SYRK mirror fans
/// strictly-upper row copies. Same determinism contract as every other
/// primitive: block boundaries depend only on `n` and the thread knob.
pub fn par_for_range_if<F>(parallel: bool, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = max_threads().min(n);
    if !parallel || threads <= 1 || in_parallel_region() {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let mut bbuf = [(0usize, 0usize); MAX_BLOCKS];
    let nb = blocks_into(n, threads, &mut bbuf);
    let idx_blocks = &bbuf[..nb];
    let f_ref = &f;
    run_region(nb, threads, &|bi| {
        let (lo, hi) = idx_blocks[bi];
        for i in lo..hi {
            f_ref(i);
        }
    });
}

/// Map `f` over `0..n` in parallel, returning results in index order. The
/// read-only fan-out primitive: per-expert batches, per-cluster merges,
/// calibration chunk computation. Items are assumed coarse (whole expert
/// batches, 1024-row calibration chunks); use [`par_map_range_if`] when the
/// caller can tell the work is too small to fan out.
pub fn par_map_range<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    par_map_range_if(true, n, f)
}

/// [`par_map_range`] with an explicit fan-out decision (same contract as
/// [`par_chunks_mut_if`]).
pub fn par_map_range_if<R, F>(parallel: bool, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = max_threads().min(n);
    if !parallel || threads <= 1 || in_parallel_region() {
        return (0..n).map(f).collect();
    }
    let mut bbuf = [(0usize, 0usize); MAX_BLOCKS];
    let nb = blocks_into(n, threads, &mut bbuf);
    let idx_blocks = &bbuf[..nb];
    let mut block_results: Vec<Option<Vec<R>>> = Vec::with_capacity(nb);
    block_results.resize_with(nb, || None);
    {
        let slots = block_results.as_mut_ptr() as usize;
        let f_ref = &f;
        run_region(nb, threads, &|bi| {
            let (lo, hi) = idx_blocks[bi];
            let out: Vec<R> = (lo..hi).map(f_ref).collect();
            // SAFETY: slot `bi` is written by exactly one block; the vec
            // outlives the region.
            unsafe {
                *(slots as *mut Option<Vec<R>>).add(bi) = Some(out);
            }
        });
    }
    block_results
        .into_iter()
        .flat_map(|s| s.expect("parallel block result missing"))
        .collect()
}

/// Fan `items` across at most `slots.len()` lanes: contiguous item blocks
/// are paired one-to-one with scratch slots (the lockstep
/// [`par_chunks2_mut_if`] underneath), so each lane owns exactly one slot
/// for its whole block — the one-workspace-per-lane ownership rule the
/// evaluation sweep runs on. `f(i, item, slot)` sees every item exactly
/// once, with `i` the item's global index; block boundaries depend only on
/// `items.len()` and `slots.len()`, never on scheduling, and a slot's state
/// must not affect results (it is scratch), so outputs are deterministic.
/// With `parallel == false`, one slot, or from inside a nested region,
/// everything runs serially.
pub fn par_items_with_slots<T, S, F>(parallel: bool, items: &mut [T], slots: &mut [S], f: F)
where
    T: Send,
    S: Send,
    F: Fn(usize, &mut T, &mut S) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    assert!(!slots.is_empty(), "par_items_with_slots: need at least one slot");
    let lanes = slots.len().min(n);
    let per = (n + lanes - 1) / lanes;
    let n_blocks = (n + per - 1) / per;
    par_chunks2_mut_if(parallel, items, per, &mut slots[..n_blocks], 1, |bi, chunk, slot| {
        for (ci, item) in chunk.iter_mut().enumerate() {
            f(bi * per + ci, item, &mut slot[0]);
        }
    });
}

/// Map `f(index, &item)` over a slice in parallel, preserving order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_range(items.len(), |i| f(i, &items[i]))
}

// ---------------------------------------------------------------------------
// Bounded handoff: the two-stage pipeline primitive.
// ---------------------------------------------------------------------------

/// A bounded producer→consumer handoff queue — the channel inside
/// [`pipeline`].
///
/// Single producer, single consumer, strict FIFO: items arrive at the
/// consumer in exactly the order they were pushed, so a pipeline's output
/// order (and therefore its results) never depends on scheduling. The
/// capacity bound is what makes the pipeline a *pipeline* rather than a
/// buffer: a producer that runs ahead of the consumer by more than
/// `capacity` items blocks, bounding peak memory to a handful of in-flight
/// items (the evaluation sweep hands whole compressed models through this,
/// so the bound is load-bearing).
///
/// Shutdown is two-sided: the producer side is *closed* when the produce
/// stage finishes (pops drain the queue, then return `None`), and the
/// consumer side is *abandoned* when the consume stage finishes (pushes
/// stop blocking and return `false`). [`pipeline`] wires both transitions
/// up automatically, including on panic, so neither side can strand the
/// other.
pub struct Handoff<T> {
    inner: Mutex<HandoffInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct HandoffInner<T> {
    queue: VecDeque<T>,
    /// 0 = unbounded (the serial execution mode buffers everything).
    capacity: usize,
    closed: bool,
    abandoned: bool,
}

impl<T: Send> Handoff<T> {
    /// A handoff holding at most `capacity` queued items; `0` means
    /// unbounded ([`pipeline`]'s serial mode, where the producer runs to
    /// completion before the consumer starts).
    pub fn new(capacity: usize) -> Handoff<T> {
        Handoff {
            inner: Mutex::new(HandoffInner {
                queue: VecDeque::new(),
                capacity,
                closed: false,
                abandoned: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Queue `item`, blocking while the handoff is full. Returns `false`
    /// (dropping `item`) once the consumer is gone — the producer should
    /// stop producing and return.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.abandoned {
                return false;
            }
            if st.capacity == 0 || st.queue.len() < st.capacity {
                st.queue.push_back(item);
                drop(st);
                self.not_empty.notify_one();
                return true;
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Dequeue the next item in production order, blocking while the
    /// handoff is empty and the producer is still running. Returns `None`
    /// once the producer has finished and the queue is drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(v);
            }
            if st.closed || st.abandoned {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Producer side finished (or died): wake the consumer to drain the
    /// queue and observe end-of-stream.
    fn close(&self) {
        let mut st = self.inner.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Consumer side finished (or died): blocked and future pushes return
    /// `false` instead of waiting forever.
    fn abandon(&self) {
        let mut st = self.inner.lock().unwrap();
        st.abandoned = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Closes the handoff when dropped — attached to the produce stage so that
/// a panicking (or early-returning) producer can never leave the consumer
/// blocked in [`Handoff::pop`].
struct CloseOnDrop<'a, T: Send>(&'a Handoff<T>);

impl<T: Send> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// The serial execution of [`pipeline`]: an unbounded buffer, the producer
/// run to completion, then the consumer — exactly the pre-pipeline order,
/// which is what the `threads = 1` bit-identity contract pins.
fn pipeline_serial<T, PR, CR>(
    produce: impl FnOnce(&Handoff<T>) -> PR,
    consume: impl FnOnce(&Handoff<T>) -> CR,
) -> (PR, CR)
where
    T: Send,
{
    let h = Handoff::new(0);
    let pr = {
        let _close = CloseOnDrop(&h);
        with_pool_flag(|| produce(&h))
    };
    let cr = consume(&h);
    (pr, cr)
}

/// Two-stage bounded-handoff pipeline: `produce` runs on the calling
/// thread, `consume` on one dedicated overlap thread, connected by a
/// [`Handoff`] holding at most `capacity` in-flight items (clamped to
/// ≥ 1). The evaluation sweep is the motivating consumer: one lane
/// compresses variant `k+1` while the remaining lanes score variant `k`.
///
/// Contract:
///
/// * **Stage roles.** The produce stage is pinned to a single lane — it
///   runs with the in-pool flag set, so any `par_*` call it makes degrades
///   to serial. The consume stage runs unpinned and may fan work across
///   the pool (e.g. via [`par_items_with_slots`]). Total concurrency is
///   therefore bounded by `1 + ` whatever the consumer uses: at most one
///   lane beyond the thread budget during overlap windows, and only while
///   the producer is actually computing rather than blocked in `push`.
/// * **Determinism.** Items arrive in production order regardless of
///   timing, and with `threads = 1` (or from inside a nested region) the
///   stages run back to back on the calling thread with an unbounded
///   buffer — the exact serial execution. Stages whose per-item work is
///   deterministic therefore produce bit-identical results at every
///   thread count (`tests/eval_consistency.rs` pins this for the sweep).
/// * **Errors.** Each stage returns its own value; recoverable errors
///   travel through `PR`/`CR` (the sweep threads `anyhow::Result`s
///   through both). A consume stage that returns early (error or
///   otherwise) makes subsequent pushes return `false`, telling the
///   producer to stop; a produce stage that returns early closes the
///   handoff, letting the consumer drain what exists and finish.
/// * **Panics.** A panic in either stage propagates to the caller —
///   producer panics unwind directly (the handoff closes on the way out,
///   so the consumer finishes rather than hanging), consumer panics are
///   re-raised after the producer returns. Neither can deadlock the
///   other.
pub fn pipeline<T, PR, CR, P, C>(capacity: usize, produce: P, consume: C) -> (PR, CR)
where
    T: Send,
    CR: Send,
    P: FnOnce(&Handoff<T>) -> PR,
    C: FnOnce(&Handoff<T>) -> CR + Send,
{
    if max_threads() <= 1 || in_parallel_region() {
        return pipeline_serial(produce, consume);
    }
    let h = Handoff::new(capacity.max(1));
    std::thread::scope(|s| {
        let handoff = &h;
        let consumer = std::thread::Builder::new()
            .name("mergemoe-pipe".into())
            .spawn_scoped(s, move || {
                let out = catch_unwind(AssertUnwindSafe(|| consume(handoff)));
                // Normal return or panic: a producer blocked in `push`
                // must observe that the consumer is gone.
                handoff.abandon();
                out
            })
            .expect("spawning the pipeline consumer thread");
        let pr = {
            let _close = CloseOnDrop(handoff);
            with_pool_flag(|| produce(handoff))
        };
        // The consumer catches its own unwind, so join() itself never
        // fails; a consumer panic is re-raised here with its original
        // payload (same policy as `run_region`).
        match consumer.join().expect("pipeline consumer thread vanished") {
            Ok(cr) => (pr, cr),
            Err(payload) => std::panic::resume_unwind(payload),
        }
    })
}

// ---------------------------------------------------------------------------
// Bounded MPMC work queue: the collector→lanes primitive.
// ---------------------------------------------------------------------------

/// A bounded multi-producer multi-consumer work queue — [`Handoff`]'s
/// sibling for the scoring server's continuous batcher, where one collector
/// thread feeds N compute lanes.
///
/// Differences from [`Handoff`]: any number of threads may push or pop, and
/// shutdown is one-sided and *public* — [`WorkQueue::close`] is the owner's
/// explicit end-of-stream signal (pops drain what is queued, then return
/// `None`; pushes at or after close return `false`). Items leave in FIFO
/// order by lock acquisition: the queue itself never reorders, but which
/// *consumer* wins a pop is scheduling-dependent — callers that need
/// deterministic results must make them independent of consumer identity
/// (the serving lanes do: per-request scores are independent of
/// batch-to-lane assignment; see ARCHITECTURE.md).
pub struct WorkQueue<T> {
    inner: Mutex<WorkQueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct WorkQueueInner<T> {
    queue: VecDeque<T>,
    capacity: usize,
    closed: bool,
}

impl<T: Send> WorkQueue<T> {
    /// A queue holding at most `capacity` items (clamped to ≥ 1).
    pub fn new(capacity: usize) -> WorkQueue<T> {
        WorkQueue {
            inner: Mutex::new(WorkQueueInner {
                queue: VecDeque::new(),
                capacity: capacity.max(1),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Queue `item`, blocking while the queue is full. Returns `false`
    /// (dropping `item`) once the queue is closed — the producer should
    /// stop producing.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.queue.len() < st.capacity {
                st.queue.push_back(item);
                drop(st);
                self.not_empty.notify_one();
                return true;
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Dequeue the next item in push order, blocking while the queue is
    /// empty and open. Returns `None` once the queue is closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// End-of-stream: consumers drain what is queued and then observe
    /// `None`; blocked and future pushes return `false`. Idempotent.
    pub fn close(&self) {
        let mut st = self.inner.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued (advisory: racy the instant it returns).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Whether the queue is currently empty (advisory, like [`WorkQueue::len`]).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_cover_range_exactly() {
        for n in [0usize, 1, 2, 7, 16, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let mut buf = [(0usize, 0usize); MAX_BLOCKS];
                let count = blocks_into(n, parts, &mut buf);
                let bs = &buf[..count];
                let mut next = 0;
                for &(lo, hi) in bs {
                    assert_eq!(lo, next);
                    assert!(hi > lo);
                    next = hi;
                }
                assert_eq!(next, n);
                assert_eq!(bs.iter().map(|&(l, h)| h - l).sum::<usize>(), n);
                assert!(bs.len() <= parts.max(1).min(MAX_BLOCKS));
            }
        }
    }

    #[test]
    fn par_chunks_mut_visits_every_chunk_once() {
        // force the parallel path even though the input is tiny
        for force in [true, false] {
            let mut data = vec![0u32; 103];
            par_chunks_mut_if(force, &mut data, 10, |ci, chunk| {
                for v in chunk.iter_mut() {
                    *v += 1 + ci as u32;
                }
            });
            // chunk i covers [10i, 10i+10): value = 1 + i
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, 1 + (i / 10) as u32, "force={force} index {i}");
            }
        }
        // empty input is a no-op even with chunk_len 0
        let mut empty: Vec<u32> = Vec::new();
        par_chunks_mut(&mut empty, 0, |_, _| panic!("must not be called"));
    }

    #[test]
    fn par_chunks2_mut_pairs_lockstep_chunks() {
        for force in [true, false] {
            let mut a = vec![0u32; 60]; // 6 chunks of 10
            let mut b = vec![0u32; 18]; // 6 chunks of 3
            par_chunks2_mut_if(force, &mut a, 10, &mut b, 3, |ci, ca, cb| {
                for v in ca.iter_mut() {
                    *v = ci as u32 + 1;
                }
                for v in cb.iter_mut() {
                    *v = 10 * (ci as u32 + 1);
                }
            });
            for (i, v) in a.iter().enumerate() {
                assert_eq!(*v, (i / 10) as u32 + 1, "force={force} a[{i}]");
            }
            for (i, v) in b.iter().enumerate() {
                assert_eq!(*v, 10 * ((i / 3) as u32 + 1), "force={force} b[{i}]");
            }
        }
        // ragged tails on both sides
        let mut a = vec![0u32; 25]; // chunks 10,10,5
        let mut b = vec![0u32; 7]; // chunks 3,3,1
        par_chunks2_mut_if(true, &mut a, 10, &mut b, 3, |ci, ca, cb| {
            for v in ca.iter_mut() {
                *v = ci as u32;
            }
            for v in cb.iter_mut() {
                *v = ci as u32;
            }
        });
        assert_eq!(a[24], 2);
        assert_eq!(b[6], 2);
    }

    #[test]
    fn par_items_with_slots_visits_every_item_once() {
        for force in [true, false] {
            for (n, n_slots) in [(10usize, 3usize), (3, 8), (1, 1), (7, 7), (64, 4)] {
                let mut items = vec![0u32; n];
                // each slot stamps its identity so we can verify block-wise
                // pairing: a slot is touched by one contiguous block only
                let mut slots: Vec<u32> = (1..=n_slots as u32).collect();
                par_items_with_slots(force, &mut items, &mut slots, |i, item, slot| {
                    *item = (i as u32 + 1) * 1000 + *slot;
                });
                let lanes = n_slots.min(n);
                let per = (n + lanes - 1) / lanes;
                for (i, v) in items.iter().enumerate() {
                    let expect_slot = (i / per) as u32 + 1;
                    assert_eq!(
                        *v,
                        (i as u32 + 1) * 1000 + expect_slot,
                        "force={force} n={n} slots={n_slots} item {i}"
                    );
                }
            }
            // empty input is a no-op
            let mut none: Vec<u32> = Vec::new();
            let mut slots = vec![0u32; 2];
            par_items_with_slots(force, &mut none, &mut slots, |_, _, _| {
                panic!("must not be called")
            });
        }
    }

    #[test]
    fn par_for_range_visits_every_index_once() {
        use std::sync::atomic::AtomicU32;
        for force in [true, false] {
            let marks: Vec<AtomicU32> = (0..137).map(|_| AtomicU32::new(0)).collect();
            par_for_range_if(force, marks.len(), |i| {
                marks[i].fetch_add(1 + i as u32, Ordering::Relaxed);
            });
            for (i, m) in marks.iter().enumerate() {
                assert_eq!(m.load(Ordering::Relaxed), 1 + i as u32, "force={force} index {i}");
            }
            // n = 0 is a no-op
            par_for_range_if(force, 0, |_| panic!("must not be called"));
        }
    }

    #[test]
    fn par_map_range_ordered_and_complete() {
        let out = par_map_range(1000, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        assert!(par_map_range(0, |i| i).is_empty());
    }

    #[test]
    fn par_map_matches_serial_map() {
        let items: Vec<i64> = (0..257).collect();
        let par: Vec<i64> = par_map(&items, |i, &x| x * 3 + i as i64);
        let ser: Vec<i64> = items.iter().enumerate().map(|(i, &x)| x * 3 + i as i64).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn nested_calls_degrade_to_serial() {
        // A nested par_map_range inside a lane must not deadlock or spawn;
        // results stay correct either way.
        let out = par_map_range(8, |i| par_map_range(8, move |j| i * 8 + j));
        for (i, inner) in out.iter().enumerate() {
            for (j, v) in inner.iter().enumerate() {
                assert_eq!(*v, i * 8 + j);
            }
        }
    }

    #[test]
    fn pool_workers_persist_across_regions() {
        // Persistence: workers never retire between regions, so the pool
        // size is monotonically non-decreasing (nothing in the lib tests
        // calls shutdown_pool). The *strict* no-growth bound lives in
        // tests/par_consistency.rs under the serialized thread knob —
        // here, concurrent lib tests may legally raise the knob and grow
        // the pool mid-loop.
        let n = max_threads().max(64);
        let warm = par_map_range(n, |i| i + 1);
        assert_eq!(warm[n - 1], n);
        let mut high_water = pool_size();
        let mut data = vec![0u64; 4096];
        for round in 0..200 {
            par_chunks_mut_if(true, &mut data, 64, |ci, c| {
                for v in c.iter_mut() {
                    *v += 1 + (ci as u64 % 3);
                }
            });
            let now = pool_size();
            assert!(
                now >= high_water,
                "round {round}: pool shrank from {high_water} to {now}"
            );
            high_water = now;
        }
    }

    #[test]
    fn concurrent_regions_from_many_threads() {
        // Several user threads submitting regions at once must all complete
        // with correct results (jobs queue up and share the pool).
        std::thread::scope(|s| {
            for t in 0..4u64 {
                s.spawn(move || {
                    for round in 0..50u64 {
                        let mut data = vec![0u64; 512];
                        par_chunks_mut_if(true, &mut data, 16, |ci, c| {
                            for v in c.iter_mut() {
                                *v = t * 1000 + round + ci as u64;
                            }
                        });
                        for (i, v) in data.iter().enumerate() {
                            assert_eq!(*v, t * 1000 + round + (i / 16) as u64);
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut data = vec![0u32; 100];
            par_chunks_mut_if(true, &mut data, 10, |ci, _c| {
                if ci == 3 {
                    panic!("intentional test panic");
                }
            });
        }));
        let payload = result.expect_err("panic in a lane must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(
            msg.contains("intentional test panic"),
            "original panic payload must survive re-raising, got {msg:?}"
        );
        // the pool keeps working after a panicked region
        let mut data = vec![0u32; 100];
        par_chunks_mut_if(true, &mut data, 10, |_ci, c| {
            for v in c.iter_mut() {
                *v += 1;
            }
        });
        assert!(data.iter().all(|&v| v == 1));
    }

    // The pipeline tests below are knob-agnostic: they pass whether the
    // runner executes the serial or the overlapped mode (thread-knob-forced
    // coverage — overlap evidence, consumer-exit unblocking, serial-vs-
    // pipelined bit-identity — lives in tests/eval_consistency.rs, which
    // serializes access to the global knob).

    #[test]
    fn pipeline_preserves_production_order() {
        let (pushed, got) = pipeline(
            2,
            |tx| {
                let mut n = 0u32;
                for i in 0..57u32 {
                    if tx.push(i) {
                        n += 1;
                    }
                }
                n
            },
            |rx| {
                let mut got = Vec::new();
                while let Some(v) = rx.pop() {
                    got.push(v);
                }
                got
            },
        );
        assert_eq!(pushed, 57);
        assert_eq!(got, (0..57).collect::<Vec<u32>>());
    }

    #[test]
    fn pipeline_handles_empty_and_single_item_streams() {
        for n in [0usize, 1] {
            let (_, consumed) = pipeline(
                1,
                move |tx| {
                    for i in 0..n {
                        tx.push(i);
                    }
                },
                |rx| {
                    let mut c = 0usize;
                    while rx.pop().is_some() {
                        c += 1;
                    }
                    c
                },
            );
            assert_eq!(consumed, n);
        }
    }

    #[test]
    fn pipeline_producer_panic_propagates_without_hanging_consumer() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            pipeline(
                1,
                |tx| {
                    tx.push(1u32);
                    panic!("producer boom");
                },
                |rx| {
                    let mut sum = 0u32;
                    while let Some(v) = rx.pop() {
                        sum += v;
                    }
                    sum
                },
            );
        }));
        let payload = result.expect_err("producer panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("producer boom"), "payload survived: {msg:?}");
    }

    #[test]
    fn pipeline_consumer_panic_propagates_without_hanging_producer() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            pipeline(
                1,
                |tx| {
                    // If the dead consumer did not unblock pushes, this
                    // loop would hang instead of seeing `false`.
                    for i in 0..10_000u32 {
                        if !tx.push(i) {
                            break;
                        }
                    }
                },
                |_rx| -> u32 { panic!("consumer boom") },
            );
        }));
        let payload = result.expect_err("consumer panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("consumer boom"), "payload survived: {msg:?}");
    }

    #[test]
    fn work_queue_is_fifo_with_a_single_consumer() {
        let q = WorkQueue::new(8);
        for i in 0..5u32 {
            assert!(q.push(i));
        }
        assert_eq!(q.len(), 5);
        q.close();
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4], "close drains in push order");
        assert!(q.is_empty());
        assert!(q.pop().is_none(), "pop after drain stays None");
    }

    #[test]
    fn work_queue_push_after_close_returns_false() {
        let q = WorkQueue::new(2);
        assert!(q.push(1u32));
        q.close();
        assert!(!q.push(2u32));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn work_queue_multi_consumer_covers_every_item_exactly_once() {
        let q = std::sync::Arc::new(WorkQueue::new(4));
        let seen = std::sync::Arc::new(Mutex::new(Vec::new()));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                let seen = seen.clone();
                std::thread::spawn(move || {
                    while let Some(v) = q.pop() {
                        seen.lock().unwrap().push(v);
                    }
                })
            })
            .collect();
        for i in 0..100u32 {
            assert!(q.push(i), "no consumer abandons an open queue");
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        let mut got = seen.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn work_queue_close_wakes_blocked_producers_and_consumers() {
        // blocked consumer (empty queue) observes None on close
        let q = std::sync::Arc::new(WorkQueue::<u32>::new(1));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        // blocked producer (full queue) observes false on close
        assert!(q.push(7), "open queue accepts a push");
        let q3 = q.clone();
        let producer = std::thread::spawn(move || {
            let mut accepted = 0u32;
            // fill until blocked-then-closed: the final push must return
            // false rather than hang
            loop {
                if !q3.push(1000) {
                    return accepted;
                }
                accepted += 1;
            }
        });
        // close only once the consumer has taken its one item and the
        // producer has refilled the queue — i.e. the producer is provably
        // blocked in push — so the wake-on-close is what ends it
        let t0 = std::time::Instant::now();
        while !(consumer.is_finished() && q.len() == 1) {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "producer/consumer never reached the blocked state"
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        q.close();
        let _ = consumer.join().unwrap();
        let accepted = producer.join().unwrap();
        assert!(accepted >= 1, "an open queue with capacity accepts pushes");
    }
}
