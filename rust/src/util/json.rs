//! Minimal but complete JSON parser/serializer (serde is unavailable in the
//! offline build). Used for the artifact manifest, model configs, metrics
//! dumps and experiment reports.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); numbers are stored as f64, which is lossless for
//! every integer this project serializes (|n| < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]` (shapes in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------------- parse / serialize ----------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value().context("parsing json")?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected eof"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs are not produced by any of our
                            // writers; map lone surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence starting at c.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated utf-8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().with_context(|| format!("bad number {txt:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": true, "d": null}, "e": "hi\nthere"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap(), true);
        assert_eq!(v.get("e").unwrap().as_str().unwrap(), "hi\nthere");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -1, 3.25, 1e3, 2.5e-2]").unwrap();
        let xs: Vec<f64> = v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(xs, vec![0.0, -1.0, 3.25, 1000.0, 0.025]);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café — ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café — ✓");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn integer_serialization_is_exact() {
        let v = Json::Num(123456789.0);
        assert_eq!(v.to_string(), "123456789");
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[8, 64, 64]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![8, 64, 64]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }
}
